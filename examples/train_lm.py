"""End-to-end training driver example: train a small LM for a few hundred
steps with checkpoint/restart in the loop (kill-resume demonstrated).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the same launcher as production (repro.launch.train); the reduced
internlm2 config (~2M params) keeps this CPU-friendly.  Scale up with
--arch/--no-reduced on real hardware.
"""

import argparse
import tempfile

from repro.launch.train import main as train_main


def run(steps: int = 200) -> None:
    with tempfile.TemporaryDirectory() as d:
        half = steps // 2
        print(f"--- phase 1: train {half} steps, checkpointing into {d}")
        out1 = train_main([
            "--arch", "internlm2_1_8b", "--reduced",
            "--steps", str(half),
            "--global-batch", "8", "--seq-len", "64",
            "--checkpoint-dir", d, "--checkpoint-interval", "20",
            "--log-every", "20",
        ])
        print("--- phase 2: simulate a restart (--resume picks up the latest "
              "checkpoint) and train to completion")
        out2 = train_main([
            "--arch", "internlm2_1_8b", "--reduced",
            "--steps", str(steps),
            "--global-batch", "8", "--seq-len", "64",
            "--checkpoint-dir", d, "--checkpoint-interval", "20",
            "--resume", "--log-every", "20",
        ])
        print(f"loss: start {out1['first_loss']:.3f} -> "
              f"after restart+finish {out2['final_loss']:.3f}")
        assert out2["final_loss"] < out1["first_loss"], "training must learn"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    run(ap.parse_args().steps)
