"""Priority serving example: two streams, one latency-critical and one
batch, sharing a single accelerator through the paper's server.

Shows the paper's core claim operationally: with priority-queue arbitration
(+ suspension instead of busy-wait), the high-priority stream's latency is
protected from the low-priority stream's load.

Run:  PYTHONPATH=src python examples/serve_priority.py
"""

import threading

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine, StreamSpec


def main() -> None:
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    engine = ServeEngine(cfg, params, max_seq=64, ordering="priority")

    assert engine.admit(StreamSpec("interactive", priority=10, period_ms=400,
                                   deadline_ms=400, prefill_ms=30,
                                   decode_ms=8, decode_steps=4)).admitted
    assert engine.admit(StreamSpec("batch", priority=1, period_ms=2000,
                                   deadline_ms=2000, prefill_ms=60,
                                   decode_ms=8, decode_steps=16)).admitted

    lat: dict[str, list] = {"interactive": [], "batch": []}

    def batch_worker():
        rng = np.random.RandomState(0)
        for _ in range(4):
            prompt = rng.randint(0, cfg.vocab_size, (1, 16)).astype(np.int32)
            r = engine.generate("batch", prompt, steps=16)
            lat["batch"].extend(r.decode_latencies_s)

    def interactive_worker():
        rng = np.random.RandomState(1)
        for _ in range(8):
            prompt = rng.randint(0, cfg.vocab_size, (1, 4)).astype(np.int32)
            r = engine.generate("interactive", prompt, steps=4)
            lat["interactive"].extend(r.decode_latencies_s)

    threads = [threading.Thread(target=batch_worker),
               threading.Thread(target=interactive_worker)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for name, xs in lat.items():
        ms = np.asarray(xs) * 1e3
        print(f"{name:12s} decode p50 {np.percentile(ms, 50):6.1f} ms  "
              f"p99 {np.percentile(ms, 99):6.1f} ms  n={len(ms)}")
    print(f"server handled {engine.server.stats.completed} requests, "
          f"max queue {engine.server.stats.max_queue_len}")
    engine.close()


if __name__ == "__main__":
    main()
