"""Quickstart: the paper's server-based accelerator access control in 60
seconds.

1. Schedulability analysis (the paper's §5.2) on a tiny task system.
2. The executable AcceleratorServer arbitrating real JAX work by priority.
3. A reduced-config LM served through it with admission control.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import threading
import time

import jax
import numpy as np

from repro.core import server_analysis, simulator
from repro.core.server_runtime import AcceleratorServer
from repro.core.task_model import GpuSegment, System, Task


def analysis_demo():
    print("=== 1. schedulability analysis (paper Eqs 1-6) ===")
    tasks = [
        Task("vision", C=5, T=50, D=50, priority=3, core=0,
             segments=(GpuSegment(e=12.0, m=1.0),)),
        Task("planner", C=8, T=100, D=100, priority=2, core=0,
             segments=(GpuSegment(e=20.0, m=2.0),)),
        Task("logger", C=10, T=200, D=200, priority=1, core=1),
    ]
    system = System(tasks=tasks, num_cores=2, epsilon=0.05, server_core=1)
    res = server_analysis.analyze(system)
    for t in tasks:
        print(f"  {t.name:8s} WCRT bound {res.wcrt(t.name):7.2f} ms "
              f"(deadline {t.D:.0f}) -> {'OK' if res.wcrt(t.name) <= t.D else 'MISS'}")
    sim = simulator.simulate(system, mode="server", horizon_ms=600)
    for t in tasks:
        print(f"  {t.name:8s} simulated worst response {sim.wcrt(t.name):7.2f} ms")
    assert res.schedulable


def server_demo():
    print("=== 2. AcceleratorServer: priority arbitration of JAX work ===")
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (256, 256))
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()  # warm the cache

    order = []
    gate = threading.Event()
    with AcceleratorServer(ordering="priority") as srv:
        srv.submit(lambda: gate.wait(2.0), name="blocker")
        time.sleep(0.02)
        reqs = [srv.submit(
            lambda p=p: (order.append(p), jax.block_until_ready(f(x)))[0],
            priority=p, name=f"matmul-p{p}") for p in (1, 3, 2)]
        gate.set()
        for r in reqs:
            r.wait(timeout=10)
    print(f"  completion order by priority: {order} (expected [3, 2, 1])")
    assert order == [3, 2, 1]


def serving_demo():
    print("=== 3. LM serving with admission control ===")
    from repro.configs.registry import get_config
    from repro.models import model as M
    from repro.serving.engine import ServeEngine, StreamSpec

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    engine = ServeEngine(cfg, params, max_seq=32)
    ok = engine.admit(StreamSpec("chat", priority=2, period_ms=1000,
                                 deadline_ms=1000, prefill_ms=50, decode_ms=10,
                                 decode_steps=4))
    print(f"  admit 'chat': {ok.admitted}")
    hog = engine.admit(StreamSpec("hog", priority=1, period_ms=100,
                                  deadline_ms=100, prefill_ms=95, decode_ms=20,
                                  decode_steps=4))
    print(f"  admit 'hog' (saturating): {hog.admitted} ({hog.reason})")
    res = engine.generate("chat", np.array([[1, 2, 3]], np.int32), steps=4)
    print(f"  generated tokens: {res.tokens}, prefill "
          f"{res.prefill_latency_s*1e3:.1f} ms")
    engine.close()
    assert ok.admitted and not hog.admitted


if __name__ == "__main__":
    analysis_demo()
    server_demo()
    serving_demo()
    print("quickstart OK")
