"""Paper reproduction example: one point of Figure 9 + a Figure-7-style
execution trace, end to end.

Run:  PYTHONPATH=src python examples/rt_schedulability_repro.py
"""

import random

from benchmarks.case_study import table1_tasks
from repro.core import fmlp_analysis, mpcp_analysis, server_analysis, simulator
from repro.core.allocation import allocate
from repro.core.task_model import System
from repro.core.taskset_gen import GenParams, generate_taskset


def schedulability_point(n_sets: int = 200) -> None:
    print(f"=== Figure 9 point: 30% GPU tasks, N_P=4, {n_sets} tasksets ===")
    rng = random.Random(42)
    params = GenParams(num_cores=4, pct_gpu_tasks=(0.3, 0.3))
    wins = {"server": 0, "mpcp": 0, "fmlp": 0}
    for _ in range(n_sets):
        tasks = generate_taskset(params, rng)
        sync_sys = allocate(tasks, 4, approach="sync")
        wins["mpcp"] += mpcp_analysis.analyze(sync_sys).schedulable
        wins["fmlp"] += fmlp_analysis.analyze(sync_sys).schedulable
        server_sys = allocate(tasks, 4, approach="server", epsilon=0.05)
        wins["server"] += server_analysis.analyze(server_sys).schedulable
    for k, v in wins.items():
        print(f"  {k:8s} {100.0 * v / n_sets:5.1f}% schedulable")
    assert wins["server"] >= max(wins["mpcp"], wins["fmlp"]), \
        "the paper's headline: server-based dominates at practical settings"


def case_study_trace() -> None:
    print("=== Figure 7: case-study trace (one hyperperiod, 3000 ms) ===")
    tasks = table1_tasks()
    server_sys = System(tasks=tasks, num_cores=2, epsilon=0.045, server_core=1)
    res = simulator.simulate(server_sys, mode="server", horizon_ms=3000,
                             trace=True)
    sync_sys = System(tasks=tasks, num_cores=2, epsilon=0.0)
    res_sync = simulator.simulate(sync_sys, mode="mpcp", horizon_ms=3000)
    print(f"  {'task':12s} {'sync(MPCP)':>12s} {'server':>10s}")
    for t in tasks:
        print(f"  {t.name:12s} {res_sync.wcrt(t.name):10.2f}ms "
              f"{res.wcrt(t.name):8.2f}ms")
    slices = [s for s in res.trace if s.start_ms < 300]
    print(f"  first 300 ms of the server-mode trace ({len(slices)} slices):")
    for s in slices[:12]:
        print(f"    core{s.core} {s.name:14s} [{s.start_ms:7.2f}, "
              f"{s.end_ms:7.2f}] {s.kind}")


if __name__ == "__main__":
    schedulability_point()
    case_study_trace()
    print("repro example OK")
