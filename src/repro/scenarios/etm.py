"""Pluggable execution-time models: what does each released job cost?

A model prices one job of a task — its normal-segment CPU time ``C`` and
its GPU segments — given the task's DECLARED worst case.  The invariant
every registered model MUST keep (and :func:`check_within_declared`
verifies): per-job costs never exceed the declared WCET, segment by
segment, and the segment count is unchanged.  The analyses price the
declared worst case, and Eqs (1)-(6) are monotone non-decreasing in every
C/G input, so any execution within declared costs is dominated by the
declared-cost bound — exactly the argument calibrated admission already
leans on (``analysis/cost_model.StepCostModel.recost``).

The ``measured`` model closes the loop to real timings: it prices each GPU
segment from a :class:`~repro.analysis.cost_model.StepCostModel` cell
surface — the per-shape-cell Welford aggregates of real timed device calls
— at ``min(declared, safety * predicted)``, so simulated executions run at
the speeds the hardware was actually measured at while the declared bound
stays a sound ceiling.

Registering a new model::

    @ETM.register("my_etm")
    class MyEtm:
        def __init__(self, **config_params): ...
        def costs(self, task, job_index, rng) -> tuple[float, tuple[GpuSegment, ...]]: ...
"""

from __future__ import annotations

import math
from dataclasses import replace
from typing import Mapping, Sequence

from repro.core.task_model import GpuSegment, Task

from .registry import Registry

__all__ = ["ETM", "check_within_declared"]

ETM = Registry("execution-time model")


def check_within_declared(task: Task, C: float,
                          segments: Sequence[GpuSegment]) -> None:
    """Raise if a job's costs exceed the task's declared worst case."""
    if C > task.C + 1e-9:
        raise ValueError(f"{task.name}: job C={C} > declared {task.C}")
    if len(segments) != task.eta:
        raise ValueError(
            f"{task.name}: {len(segments)} segments != declared eta={task.eta}")
    for k, (got, decl) in enumerate(zip(segments, task.segments)):
        if got.e > decl.e + 1e-9 or got.m > decl.m + 1e-9:
            raise ValueError(
                f"{task.name} segment {k}: job ({got.e}, {got.m}) exceeds "
                f"declared ({decl.e}, {decl.m})")


def _scaled(task: Task, scale: float) -> tuple[float, tuple[GpuSegment, ...]]:
    if not (0.0 < scale <= 1.0):
        raise ValueError(f"{task.name}: ETM scale {scale} outside (0, 1]")
    if scale == 1.0:
        return task.C, task.segments
    return (task.C * scale,
            tuple(replace(s, e=s.e * scale, m=s.m * scale)
                  for s in task.segments))


@ETM.register("constant")
class Constant:
    """Every job runs exactly at the declared WCET (the paper's §6.3
    experiments; the legacy simulator's only behavior)."""

    def costs(self, task: Task, job_index: int, rng):
        return task.C, task.segments


@ETM.register("table")
class Table:
    """Per-task scale table: job cost = declared * scales[name] (clamped to
    (0, 1]); tasks absent from the table run at ``default`` scale."""

    def __init__(self, scales: Mapping[str, float] | None = None,
                 default: float = 1.0):
        self.scales = dict(scales or {})
        self.default = default

    def costs(self, task: Task, job_index: int, rng):
        return _scaled(task, self.scales.get(task.name, self.default))


@ETM.register("uniform")
class Uniform:
    """Per-job random scale drawn U[frac]: actual execution times vary
    between ``frac[0]`` and ``frac[1]`` of the declared worst case."""

    def __init__(self, frac: tuple[float, float] = (0.5, 1.0)):
        lo, hi = frac
        if not (0.0 < lo <= hi <= 1.0):
            raise ValueError(f"need 0 < lo <= hi <= 1, got {frac}")
        self.frac = (lo, hi)

    def costs(self, task: Task, job_index: int, rng):
        return _scaled(task, rng.uniform(*self.frac))


@ETM.register("measured")
class Measured:
    """GPU segments priced from MEASURED step costs: each segment runs at
    ``min(declared, safety * cost_model.predict(cell))`` — the same
    calibrated re-pricing rule as ``StepCostModel.recost`` — so the
    simulated trace executes at the speeds real timed device calls ran at
    (committed in BENCH_cost_model.json or ingested live from
    ``ServerPool.cell_stats()``).

    ``cell`` names the shape cell every segment of every task maps to;
    ``cells`` optionally overrides per task name.  An unmeasured phase
    predicts ``inf`` and degrades to the declared cost — an empty model is
    exactly the ``constant`` ETM.  Normal-segment CPU time stays declared
    (the cost model prices device calls, not client CPU)."""

    def __init__(self, cost_model=None, cell: Sequence = ("decode", 4, 64),
                 cells: Mapping[str, Sequence] | None = None,
                 safety: float = 1.2):
        if cost_model is None:
            raise ValueError(
                "etm 'measured' needs a StepCostModel: pass cost_model= to "
                "scenario build()/run() (e.g. ingested from "
                "ServerPool.cell_stats() or loaded from BENCH_cost_model.json)")
        self.cost_model = cost_model
        self.cell = tuple(cell)
        self.cells = {k: tuple(v) for k, v in (cells or {}).items()}
        self.safety = safety

    def costs(self, task: Task, job_index: int, rng):
        if not task.segments:
            return task.C, task.segments
        cell = self.cells.get(task.name, self.cell)
        pred_ms = self.cost_model.predict(*cell) * self.safety * 1e3
        segs = []
        for seg in task.segments:
            if not pred_ms < seg.total or not math.isfinite(pred_ms):
                segs.append(seg)
                continue
            scale = pred_ms / seg.total
            segs.append(replace(seg, e=seg.e * scale, m=seg.m * scale))
        return task.C, tuple(segs)
