"""Pluggable arrival models: when does each task release jobs?

Every model emits a list of absolute release instants (float ms) for one
task over the simulation horizon.  The one invariant every registered model
MUST keep — and :func:`check_min_separation` verifies — is the sporadic
task model's contract: consecutive releases of a task are separated by at
least its minimum inter-arrival time ``T``.  The schedulability analyses
(Eqs (1)-(6) and the MPCP/FMLP+ baselines) assume exactly that and nothing
more about arrivals, so any model registered here is automatically inside
the workload class the bounds claim to cover; richer traffic shapes
(bursts, diurnal swells, flash crowds, recorded traces) only modulate gaps
UPWARD from ``T``.

Releases are computed by integer-nanosecond accumulation, matching the
simulator's internal clock, so the ``periodic`` model replays the legacy
``simulate()`` release loop bit-for-bit (the golden-replay property test
pins this).

Registering a new model::

    @ARRIVALS.register("my_arrivals")
    class MyArrivals:
        def __init__(self, **config_params): ...
        def releases(self, task, horizon_ms, rng) -> list[float]: ...
"""

from __future__ import annotations

import json
import math
import pathlib
import re
import zlib
from typing import Mapping, Sequence

from repro.core.task_model import Task

from .registry import Registry

__all__ = ["ARRIVALS", "check_min_separation"]

ARRIVALS = Registry("arrival model")

_NS = 1_000_000  # ns per ms, the simulator's clock resolution


def _ns(ms: float) -> int:
    return int(round(ms * _NS))


def check_min_separation(task: Task, releases: Sequence[float]) -> None:
    """Raise if ``releases`` violates the sporadic contract (gap < T)."""
    for a, b in zip(releases, releases[1:]):
        if b - a < task.T - 1e-6:
            raise ValueError(
                f"{task.name}: inter-arrival {b - a:.6f} ms < T={task.T} ms "
                f"(arrival models must respect the sporadic minimum gap)")


@ARRIVALS.register("periodic")
class Periodic:
    """Strictly periodic releases: t = offset + k*T (the paper's §6.3
    synchronous-release experiments; ``offset_ms`` per-task phasing)."""

    def __init__(self, offset_ms: float = 0.0):
        self.offset_ms = offset_ms

    def releases(self, task: Task, horizon_ms: float, rng) -> list[float]:
        t, step, horizon = _ns(self.offset_ms), _ns(task.T), _ns(horizon_ms)
        out = []
        while t < horizon:
            out.append(t / _NS)
            t += step
        return out


@ARRIVALS.register("sporadic")
class Sporadic:
    """Sporadic releases: each gap is T * (1 + U[slack]) — the legal
    worst case (slack=(0,0)) up to arbitrarily lazy arrivals."""

    def __init__(self, slack: tuple[float, float] = (0.0, 0.5),
                 offset_ms: float = 0.0):
        lo, hi = slack
        if lo < 0 or hi < lo:
            raise ValueError(f"need 0 <= lo <= hi slack, got {slack}")
        self.slack = (lo, hi)
        self.offset_ms = offset_ms

    def releases(self, task: Task, horizon_ms: float, rng) -> list[float]:
        t, horizon = _ns(self.offset_ms), _ns(horizon_ms)
        out = []
        while t < horizon:
            out.append(t / _NS)
            t += _ns(task.T * (1.0 + rng.uniform(*self.slack)))
        return out


@ARRIVALS.register("bursty")
class Bursty:
    """Two-state MMPP-style bursts: a Markov chain alternates between a
    BURST state (back-to-back legal arrivals, gap = T) and an IDLE state
    (gap = T * idle_factor).  ``p_exit``/``p_enter`` are the per-arrival
    transition probabilities out of burst / into burst; a flash crowd is
    the limit of long idle dwell followed by a long burst dwell
    (small p_enter, small p_exit)."""

    def __init__(self, p_enter: float = 0.15, p_exit: float = 0.3,
                 idle_factor: float = 4.0, start_bursting: bool = False):
        for name, p in (("p_enter", p_enter), ("p_exit", p_exit)):
            if not (0.0 < p <= 1.0):
                raise ValueError(f"{name} must be in (0, 1], got {p}")
        if idle_factor < 1.0:
            raise ValueError(
                f"idle_factor must be >= 1 (gap >= T), got {idle_factor}")
        self.p_enter, self.p_exit = p_enter, p_exit
        self.idle_factor = idle_factor
        self.start_bursting = start_bursting

    def releases(self, task: Task, horizon_ms: float, rng) -> list[float]:
        t, horizon = 0, _ns(horizon_ms)
        bursting = self.start_bursting
        out = []
        while t < horizon:
            out.append(t / _NS)
            if bursting:
                gap = task.T
                if rng.random() < self.p_exit:
                    bursting = False
            else:
                gap = task.T * self.idle_factor
                if rng.random() < self.p_enter:
                    bursting = True
            t += _ns(gap)
        return out


@ARRIVALS.register("diurnal")
class Diurnal:
    """Slow sinusoidal load modulation: the gap multiplier swings between 1
    (peak traffic, gap = T) and 1 + amplitude (trough) over ``cycles`` full
    periods of the horizon — the compressed diurnal curve."""

    def __init__(self, cycles: float = 2.0, amplitude: float = 2.0,
                 phase: float = 0.0):
        if cycles <= 0:
            raise ValueError(f"cycles must be > 0, got {cycles}")
        if amplitude < 0:
            raise ValueError(f"amplitude must be >= 0, got {amplitude}")
        self.cycles, self.amplitude, self.phase = cycles, amplitude, phase

    def releases(self, task: Task, horizon_ms: float, rng) -> list[float]:
        t, horizon = 0, _ns(horizon_ms)
        out = []
        while t < horizon:
            out.append(t / _NS)
            # load(x) in [0,1]: 1 at the daily peak, 0 at the trough
            x = (t / horizon) * self.cycles + self.phase
            load = 0.5 * (1.0 + math.sin(2.0 * math.pi * x))
            gap = task.T * (1.0 + self.amplitude * (1.0 - load))
            t += _ns(gap)
        return out


@ARRIVALS.register("trace")
class TraceDriven:
    """Replay recorded release instants.

    Two sources, one required: ``releases_ms`` maps task name to absolute
    release times (ms) inline; ``path`` loads a JSONL trace file — one
    ``{"at_ms": <float>, "task": "<key>"}`` event per line (lines without
    ``at_ms`` are metadata and skipped).  Relative paths resolve against
    the checked-in corpus at ``repro/scenarios/traces/``.

    ``assign`` maps generated tasks onto trace keys: ``"by_name"`` (the
    default) requires exact name matches, tasks absent from the trace fall
    back to periodic releases; ``"round_robin"`` deals the sorted trace
    keys out by each task's numeric suffix (``tau7`` -> keys[7 % n]), so
    any generated taskset replays a fixed corpus.

    ``normalize=True`` rescales each task's recorded gaps so its MINIMUM
    gap equals its declared ``T`` (events shifted to start at 0) — the
    trace contributes its burst *shape* while the sporadic contract holds
    by construction.  Without it, the raw instants must already respect
    every task's T: the minimum-gap check is validated at generation time,
    and a trace that violates it is outside what the analysis covers and
    is rejected loudly."""

    def __init__(self, releases_ms: Mapping[str, Sequence[float]] | None
                 = None, path: str | None = None,
                 assign: str = "by_name", normalize: bool = False):
        if (releases_ms is None) == (path is None):
            raise ValueError("give exactly one of releases_ms= or path=")
        if assign not in ("by_name", "round_robin"):
            raise ValueError(f"unknown assign mode {assign!r}")
        if path is not None:
            releases_ms = _load_trace(path)
        self.releases_ms = {k: tuple(float(x) for x in v)
                            for k, v in releases_ms.items()}
        self.assign = assign
        self.normalize = normalize

    def _key_for(self, task: Task) -> str | None:
        if self.assign == "by_name":
            return task.name if task.name in self.releases_ms else None
        keys = sorted(self.releases_ms)
        if not keys:
            return None
        m = re.search(r"(\d+)$", task.name)
        idx = int(m.group(1)) if m else zlib.crc32(task.name.encode())
        return keys[idx % len(keys)]

    def releases(self, task: Task, horizon_ms: float, rng) -> list[float]:
        key = self._key_for(task)
        if key is None:
            return Periodic().releases(task, horizon_ms, rng)
        rec = sorted(self.releases_ms[key])
        if self.normalize and len(rec) > 1:
            min_gap = min(b - a for a, b in zip(rec, rec[1:]))
            if min_gap <= 0:
                raise ValueError(
                    f"trace key {key!r} has duplicate instants; cannot "
                    "normalize")
            scale = task.T / min_gap
            rec = [(r - rec[0]) * scale for r in rec]
        out = [r for r in rec if r < horizon_ms]
        check_min_separation(task, out)
        return out


def _load_trace(path: str) -> dict[str, list[float]]:
    """Parse a JSONL arrival trace into {task_key: [at_ms, ...]}."""
    p = pathlib.Path(path)
    if not p.is_absolute():
        p = pathlib.Path(__file__).parent / "traces" / p
    out: dict[str, list[float]] = {}
    with open(p) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            if "at_ms" not in ev:
                continue  # metadata line
            out.setdefault(str(ev["task"]), []).append(float(ev["at_ms"]))
    if not out:
        raise ValueError(f"trace {p} holds no events")
    return out
