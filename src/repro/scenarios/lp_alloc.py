"""LP-based allocation baseline (the rtos_sim ``planning/lp_solver`` idea).

``core.allocation.allocate_pool`` packs greedily (worst-fit decreasing at
the device level, WFD/FFD/BFD at the core level).  This module solves the
same two-level assignment as a makespan LP instead:

    minimize  z
    s.t.      sum_b x[i,b] = 1                for every item i
              sum_i u_i * x[i,b] <= z         for every bin b
              0 <= x[i,b] <= 1

relaxed to fractional x, solved with ``scipy.optimize.linprog`` (HiGHS),
then rounded deterministically: items in decreasing utilization go to
their largest-fraction bin, followed by a local-search repair (move the
smallest movable item off the most-loaded bin while that lowers the max
load).  The LP optimum ``z*`` is a true lower bound on ANY integral
packing's max load, so the benchmark can report how far both the heuristic
and the rounded-LP packing sit from optimal — the comparison
``BENCH_scenarios.json`` carries.

scipy is gated: when unavailable, :func:`lp_pack` falls back to worst-fit
decreasing (flagged via ``HAVE_SCIPY`` and the returned ``PackResult``)
so the scenario engine degrades instead of importing-erroring.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.allocation import SERVER_NAME, AllocationError
from repro.core.task_model import System, Task, server_utilization

try:  # gated: the container may lack scipy; degrade to the WFD heuristic
    from scipy.optimize import linprog

    HAVE_SCIPY = True
except ImportError:  # pragma: no cover - exercised only without scipy
    linprog = None
    HAVE_SCIPY = False

__all__ = ["HAVE_SCIPY", "PackResult", "lp_pack", "allocate_lp"]


@dataclass(frozen=True)
class PackResult:
    """One bin-packing outcome: assignment plus the LP lower bound."""

    assignment: dict[str, int]   # item name -> bin
    max_load: float              # achieved max bin load
    lp_bound: float              # fractional optimum z* (<= any packing)
    used_lp: bool                # False = WFD fallback (scipy missing)


def _wfd(items: list[tuple[str, float]], num_bins: int) -> dict[str, int]:
    load = [0.0] * num_bins
    out: dict[str, int] = {}
    for name, u in sorted(items, key=lambda kv: (-kv[1], kv[0])):
        b = min(range(num_bins), key=lambda c: load[c])
        load[b] += u
        out[name] = b
    return out


def _loads(items: list[tuple[str, float]], assignment: dict[str, int],
           num_bins: int) -> list[float]:
    load = [0.0] * num_bins
    for name, u in items:
        load[assignment[name]] += u
    return load


def _repair(items: list[tuple[str, float]], assignment: dict[str, int],
            num_bins: int) -> None:
    """Deterministic local search: while moving one item from the most
    loaded bin to the least loaded strictly lowers the max load, do it
    (smallest sufficient item first)."""
    util = dict(items)
    for _ in range(4 * len(items) + 4):
        load = _loads(items, assignment, num_bins)
        hi = max(range(num_bins), key=lambda b: (load[b], -b))
        lo = min(range(num_bins), key=lambda b: (load[b], b))
        if load[hi] - load[lo] <= 1e-12:
            return
        movable = sorted(
            (name for name, b in assignment.items() if b == hi),
            key=lambda n: (util[n], n))
        for name in movable:
            if max(load[hi] - util[name], load[lo] + util[name]) < load[hi] - 1e-12:
                assignment[name] = lo
                break
        else:
            return


def lp_pack(items: list[tuple[str, float]], num_bins: int) -> PackResult:
    """Pack (name, utilization) items onto ``num_bins`` bins, minimizing the
    max bin load via the LP relaxation + deterministic rounding."""
    if num_bins < 1:
        raise AllocationError(f"need >= 1 bin, got {num_bins}")
    if not items:
        return PackResult({}, 0.0, 0.0, used_lp=HAVE_SCIPY)
    names = [n for n, _ in items]
    if len(set(names)) != len(names):
        raise AllocationError("duplicate item names in packing input")
    if num_bins == 1 or not HAVE_SCIPY:
        assignment = ({n: 0 for n in names} if num_bins == 1
                      else _wfd(items, num_bins))
        load = _loads(items, assignment, num_bins)
        bound = (sum(u for _, u in items) / num_bins if num_bins == 1
                 else max(sum(u for _, u in items) / num_bins,
                          max(u for _, u in items)))
        return PackResult(assignment, max(load), bound, used_lp=False)

    n, m = len(items), num_bins
    # variables: x[i*m + b] for each item/bin, then z last
    nvar = n * m + 1
    c = [0.0] * (n * m) + [1.0]
    a_eq, b_eq = [], []
    for i in range(n):
        row = [0.0] * nvar
        for b in range(m):
            row[i * m + b] = 1.0
        a_eq.append(row)
        b_eq.append(1.0)
    a_ub, b_ub = [], []
    for b in range(m):
        row = [0.0] * nvar
        for i, (_, u) in enumerate(items):
            row[i * m + b] = u
        row[-1] = -1.0
        a_ub.append(row)
        b_ub.append(0.0)
    bounds = [(0.0, 1.0)] * (n * m) + [(0.0, None)]
    res = linprog(c, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=b_eq,
                  bounds=bounds, method="highs")
    if not res.success:  # pragma: no cover - LP above is always feasible
        assignment = _wfd(items, m)
        load = _loads(items, assignment, m)
        return PackResult(assignment, max(load), 0.0, used_lp=False)

    lp_bound = float(res.x[-1])
    # deterministic rounding: decreasing utilization, largest fraction wins,
    # ties to the emptier bin
    assignment: dict[str, int] = {}
    load = [0.0] * m
    order = sorted(range(n), key=lambda i: (-items[i][1], items[i][0]))
    for i in order:
        name, u = items[i]
        fracs = res.x[i * m:(i + 1) * m]
        b = max(range(m), key=lambda bb: (fracs[bb], -(load[bb] + u)))
        assignment[name] = b
        load[b] += u
    _repair(items, assignment, m)
    return PackResult(assignment, max(_loads(items, assignment, m)),
                      lp_bound, used_lp=True)


def allocate_lp(
    tasks: list[Task],
    num_devices: int,
    cores_per_device: int,
    *,
    epsilon: float = 0.0,
) -> System:
    """Two-level LP allocation for a multi-accelerator server pool — the
    drop-in baseline for ``core.allocation.allocate_pool`` (same System
    shape out: core-disjoint device partitions, one server core each).

    Level 1 packs GPU-using tasks onto devices by accelerator utilization
    G_i/T_i via :func:`lp_pack`, then spreads CPU-only tasks across devices
    by CPU utilization the same way.  Level 2 LP-packs each device's tasks
    plus its Eq (8) server pseudo-task onto its private core group.
    """
    if num_devices < 1:
        raise AllocationError(f"need >= 1 device, got {num_devices}")
    gpu = [t for t in tasks if t.uses_gpu]
    cpu_only = [t for t in tasks if not t.uses_gpu]

    dev_pack = lp_pack([(t.name, t.G / t.T) for t in gpu], num_devices)
    by_device: list[list[Task]] = [[] for _ in range(num_devices)]
    dev_cpu_load = [0.0] * num_devices
    for t in gpu:
        d = dev_pack.assignment[t.name]
        by_device[d].append(t)
        dev_cpu_load[d] += t.C / t.T
    for t in sorted(cpu_only, key=lambda t: (-(t.C / t.T), t.name)):
        d = min(range(num_devices), key=lambda i: (dev_cpu_load[i], i))
        dev_cpu_load[d] += t.C / t.T
        by_device[d].append(t)

    placed: list[Task] = []
    server_cores: list[int] = []
    for d in range(num_devices):
        mine = by_device[d]
        items = [(t.name, t.C / t.T) for t in mine]
        items.append((SERVER_NAME, server_utilization(mine, epsilon)))
        pack = lp_pack(items, cores_per_device)
        offset = d * cores_per_device
        placed.extend(
            t.with_core(pack.assignment[t.name] + offset).with_device(d)
            for t in mine)
        server_cores.append(pack.assignment[SERVER_NAME] + offset)
    return System(
        tasks=placed,
        num_cores=num_devices * cores_per_device,
        epsilon=epsilon,
        server_cores=tuple(server_cores),
    )
