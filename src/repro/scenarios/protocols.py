"""Pluggable accelerator-access protocols: how is the GPU arbitrated?

One registry entry couples the three faces of a protocol that must stay in
lockstep for property tests to mean anything:

  * the SIMULATOR mode executing its exact semantics
    (``core.simulator.simulate(mode=...)``),
  * the ANALYSIS producing the response-time bound the simulation is
    property-tested against (bound >= simulated WCRT),
  * the ALLOCATION approach ("server" packs C/T plus the Eq (8) server
    pseudo-task; "sync" packs (C+G)/T busy-wait demand).

The server family's queue ordering reuses ``dispatch.policy`` keys
(priority / fifo / edf) — the same single definition of request order the
executable runtime uses.  The synchronization-based baselines
(``core.mpcp_analysis`` / ``core.fmlp_analysis``) are first-class entries,
so every sweep and matrix cell compares the paper's approach against them
through one code path.

Multi-accelerator systems decompose per device partition exactly as
``server_analysis.analyze_pool`` argues (partitioned routing keeps each
server's queue private); sync protocols model one global mutex and are
single-device only.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core import fmlp_analysis, mpcp_analysis, server_analysis
from repro.core.task_model import System

from .registry import Registry

__all__ = ["PROTOCOLS", "Protocol"]

PROTOCOLS = Registry("protocol")


def _per_device(analyze_one: Callable[[System], server_analysis.AnalysisResult]):
    """Lift a single-accelerator analysis to a pool: analyze each device's
    core-disjoint subsystem and merge (the ``analyze_pool`` decomposition;
    ``System.subsystem`` raises if partitions share a core)."""

    def analyze(system: System):
        if system.num_gpus <= 1:
            return analyze_one(system)
        res = server_analysis.PoolAnalysisResult()
        for d in range(system.num_gpus):
            sub = analyze_one(system.subsystem(d))
            res.per_device[d] = sub
            res.response_times.update(sub.response_times)
            res.gpu_handling.update(sub.gpu_handling)
            res.schedulable = res.schedulable and sub.schedulable
        return res

    return analyze


@dataclass(frozen=True)
class Protocol:
    """One registered protocol: simulator mode + analysis + allocation."""

    name: str
    approach: str          # "server" | "sync" (allocation/packing semantics)
    sim_mode: str          # core.simulator mode string
    ordering: str          # dispatch.policy queue-ordering key
    pool_capable: bool     # multi-accelerator partitions supported?
    analyze: Callable[[System], object] = field(repr=False)

    def __post_init__(self) -> None:
        if self.approach not in ("server", "sync"):
            raise ValueError(f"unknown approach {self.approach!r}")


def _register(name: str, **kw):
    proto = Protocol(name=name, **kw)
    PROTOCOLS.register(name, lambda proto=proto: proto)
    return proto


_register(
    "server",
    approach="server", sim_mode="server", ordering="priority",
    pool_capable=True,
    analyze=lambda system: (server_analysis.analyze_pool(system)
                            if system.num_gpus > 1
                            else server_analysis.analyze(system)),
)

_register(
    "server_fifo",
    approach="server", sim_mode="server_fifo", ordering="fifo",
    pool_capable=True,
    analyze=_per_device(server_analysis.analyze_fifo_server),
)

_register(
    "server_edf",
    approach="server", sim_mode="server_edf", ordering="edf",
    pool_capable=True,
    analyze=_per_device(server_analysis.analyze_edf_server),
)

# Batched dispatch: same per-request analysis — coalescing only lets
# same-shape requests JOIN the head's device call, so the unbatched bound
# still dominates (see analyze_pool's soundness note).
_register(
    "server_batched",
    approach="server", sim_mode="server_batched", ordering="priority",
    pool_capable=True,
    analyze=lambda system: (server_analysis.analyze_pool(system)
                            if system.num_gpus > 1
                            else server_analysis.analyze(system)),
)

_register(
    "mpcp",
    approach="sync", sim_mode="mpcp", ordering="priority",
    pool_capable=False,
    analyze=mpcp_analysis.analyze,
)

_register(
    "fmlp",
    approach="sync", sim_mode="fmlp", ordering="fifo",
    pool_capable=False,
    analyze=fmlp_analysis.analyze,
)
