"""Registry-driven scenario engine: a scenario is a config, not a code path.

This package turns every axis the simulator/analysis pair used to hard-code
into a string-keyed registry (ROADMAP item 4, modeled on the rtos_sim
exemplar):

  =============  =====================================  ========================
  axis           registry (module)                      built-in keys
  =============  =====================================  ========================
  arrivals       ``ARRIVALS``   (:mod:`.arrivals`)      periodic, sporadic,
                                                        bursty, diurnal, trace
  exec times     ``ETM``        (:mod:`.etm`)           constant, table,
                                                        uniform, measured
  overheads      ``OVERHEADS``  (:mod:`.overheads`)     constant, zero, scaled,
                                                        measured
  protocols      ``PROTOCOLS``  (:mod:`.protocols`)     server, server_fifo,
                                                        server_edf,
                                                        server_batched,
                                                        mpcp, fmlp
  schedulers     ``SCHEDULERS`` (:mod:`.schedulers`)    rm, dm, given
  scenarios      ``SCENARIOS``  (:mod:`.matrix`)        the CI matrix presets
  =============  =====================================  ========================

WRITING A SCENARIO
------------------

1. Describe the run as data — a frozen :class:`Scenario`::

       from repro.scenarios import Scenario, run

       scn = Scenario(
           name="my_experiment",
           seed=42,
           taskset={"num_cores": 4, "num_tasks": (8, 12)},  # GenParams kwargs
           arrivals=("bursty", {"p_enter": 0.1, "idle_factor": 4.0}),
           etm=("uniform", {"frac": (0.6, 1.0)}),
           protocol="server_batched",
           scheduler="rm",
           num_devices=2, cores_per_device=2,
           allocator="lp",            # or "wfd"/"ffd"/"bfd"
       )
       result = run(scn)              # -> ScenarioResult
       result.schedulable, result.any_miss
       result.bounds["tau3"], result.wcrt["tau3"]   # bound >= wcrt, always

   Registry specs are either a bare key (``"periodic"``) or
   ``(key, params)``; unknown keys fail at construction with the list of
   alternatives.  Every random draw derives from ``seed`` through named
   sub-streams, so the same config + seed replays bit-identically.

2. Or reuse a preset from the CI matrix::

       from repro.scenarios import SCENARIOS
       scn = SCENARIOS.create("flash_crowd", seed=3)

3. ADDING A GENERATOR: register a class under the axis's registry and keep
   that axis's one invariant (each module's docstring states it)::

       from repro.scenarios import ARRIVALS

       @ARRIVALS.register("pareto")
       class Pareto:
           def __init__(self, alpha=1.5): self.alpha = alpha
           def releases(self, task, horizon_ms, rng) -> list[float]:
               ...  # consecutive gaps MUST stay >= task.T

   Invariants (what keeps the property tests meaningful):

   * arrivals: inter-release gaps >= T — the sporadic contract the
     analyses assume (``check_min_separation`` enforces it at build).
   * etm: per-job costs <= the declared WCET, same segment count — the
     bounds are monotone in costs, so declared-cost analysis dominates
     (``check_within_declared`` enforces it per job).
   * protocols: the simulator mode and the analysis must describe the SAME
     semantics; new protocols need a bound-dominance property test.

4. CLI: ``python -m benchmarks.run --scenario flash_crowd`` resolves the
   name through the registry; ``benchmarks/scenario_matrix.py`` prices the
   whole matrix into BENCH_scenarios.json; ``make test-scenarios`` runs
   the CI-sized property pass (bound >= simulated WCRT on every cell).
"""

from .arrivals import ARRIVALS
from .etm import ETM
from .lp_alloc import allocate_lp, lp_pack
from .matrix import CI_MATRIX, SCENARIOS, default_cost_model
from .overheads import OVERHEADS
from .protocols import PROTOCOLS, Protocol
from .registry import Registry, RegistryError
from .scenario import (
    BuiltScenario,
    Scenario,
    ScenarioResult,
    build,
    rng_stream,
    run,
)
from .schedulers import SCHEDULERS

__all__ = [
    "ARRIVALS",
    "ETM",
    "OVERHEADS",
    "PROTOCOLS",
    "SCENARIOS",
    "SCHEDULERS",
    "CI_MATRIX",
    "BuiltScenario",
    "Protocol",
    "Registry",
    "RegistryError",
    "Scenario",
    "ScenarioResult",
    "allocate_lp",
    "build",
    "default_cost_model",
    "lp_pack",
    "rng_stream",
    "run",
]
