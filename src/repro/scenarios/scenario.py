"""The frozen :class:`Scenario` config and its build()/run() entry points.

A scenario fully describes one experiment as DATA — registry keys plus
parameters — instead of a code path:

    Scenario(
        name="flash_crowd",
        seed=7,
        taskset={"num_cores": 2, "num_tasks": (4, 8)},     # GenParams kwargs
        arrivals=("bursty", {"p_enter": 0.05, "p_exit": 0.2}),
        etm=("uniform", {"frac": (0.6, 1.0)}),
        overheads="constant",
        protocol="server_batched",
        scheduler="rm",
        num_devices=2, cores_per_device=2,
        allocator="wfd",                                    # or "lp"
    )

``build()`` resolves every key through its registry and returns a
:class:`BuiltScenario` (system + release trace + per-job cost hooks +
analysis); ``run()`` additionally simulates and pairs every task's
analysis bound with its simulated WCRT.  All randomness — taskset
generation, arrival gaps, per-job execution times, fault instants — is
derived from the scenario's single ``seed`` through named sub-streams, so
the same config + seed replays a bit-identical trace (property-tested).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

from repro.core import server_analysis, simulator
from repro.core.allocation import allocate, allocate_pool
from repro.core.faults import DeviceFault, seeded_device_faults
from repro.core.migration import StreamMigration, seeded_stream_migrations
from repro.core.task_model import GpuSegment, System, Task
from repro.core.taskset_gen import GenParams, generate_taskset

from .arrivals import ARRIVALS, check_min_separation
from .etm import ETM, check_within_declared
from .lp_alloc import allocate_lp
from .overheads import OVERHEADS
from .protocols import PROTOCOLS, Protocol
from .registry import RegistryError
from .schedulers import SCHEDULERS

__all__ = ["Scenario", "BuiltScenario", "ScenarioResult", "build", "run",
           "rng_stream"]

Spec = tuple[str, dict]

# registry entries that receive the build-time cost model automatically
_NEEDS_COST_MODEL = {"measured"}


def rng_stream(seed: int, label: str) -> random.Random:
    """One named deterministic sub-stream of the scenario seed.  String
    seeding is version-stable in CPython, so every consumer (taskset
    generation, each task's arrivals, each task's per-job costs, faults)
    draws from its own reproducible stream regardless of call order."""
    return random.Random(f"{seed}/{label}")


def _spec(x: Any) -> Spec:
    """Normalize a registry spec: "key" or (key, params) -> (key, dict)."""
    if isinstance(x, str):
        return (x, {})
    key, params = x
    return (str(key), dict(params or {}))


@dataclass(frozen=True)
class Scenario:
    """A complete, declarative description of one run.

    Fields (all registry keys resolve at ``build()`` time):

    * ``taskset`` — ``GenParams`` kwargs for the §6.3 generator.
    * ``arrivals`` / ``etm`` / ``overheads`` — registry key or
      ``(key, params)`` pairs.
    * ``protocol`` — access-control protocol (simulator mode + analysis +
      allocation approach in lockstep).
    * ``scheduler`` — priority-assignment policy.
    * ``num_devices`` / ``cores_per_device`` — pool shape (sync protocols
      are single-device; ``cores_per_device=None`` uses the generator's
      ``num_cores``).
    * ``allocator`` — packing heuristic ("wfd"/"ffd"/"bfd") or "lp" (the
      LP-relaxation baseline).
    * ``num_faults`` — replayed device-death schedule (server protocols,
      pools only), seeded from the scenario seed.
    * ``num_migrations`` — replayed planned-migration schedule (work
      stealing / consolidation at the analysis level; server protocols,
      pools only), seeded from the scenario seed;
      ``migration_cost_scale`` prices each move relative to the largest
      GPU segment (see ``core.migration.seeded_stream_migrations``).
    """

    name: str
    seed: int = 0
    taskset: Mapping[str, Any] = field(default_factory=dict)
    arrivals: Any = "periodic"
    etm: Any = "constant"
    overheads: Any = "constant"
    protocol: str = "server"
    scheduler: str = "rm"
    num_devices: int = 1
    cores_per_device: int | None = None
    allocator: str = "wfd"
    horizon_periods: float = 3.0
    batch_max: int = 4
    num_faults: int = 0
    fault_detect_ms: float = 1.0
    fault_recovery_scale: float = 1.0
    num_migrations: int = 0
    migration_cost_scale: float = 0.25
    trace: bool = False

    def __post_init__(self) -> None:
        object.__setattr__(self, "taskset", dict(self.taskset))
        for fld in ("arrivals", "etm", "overheads"):
            object.__setattr__(self, fld, _spec(getattr(self, fld)))
        for registry, key in ((ARRIVALS, self.arrivals[0]),
                              (ETM, self.etm[0]),
                              (OVERHEADS, self.overheads[0]),
                              (PROTOCOLS, self.protocol),
                              (SCHEDULERS, self.scheduler)):
            if key not in registry:
                raise RegistryError(
                    f"scenario {self.name!r}: unknown {registry.kind} "
                    f"{key!r}; available: {registry.available()}")
        if self.num_devices < 1:
            raise ValueError(f"{self.name}: num_devices must be >= 1")
        if self.num_faults < 0:
            raise ValueError(f"{self.name}: num_faults must be >= 0")
        if self.num_faults >= self.num_devices and self.num_faults > 0:
            raise ValueError(
                f"{self.name}: cannot kill {self.num_faults} of "
                f"{self.num_devices} devices")
        if self.num_migrations < 0:
            raise ValueError(f"{self.name}: num_migrations must be >= 0")
        if self.num_migrations and self.num_devices < 2:
            raise ValueError(
                f"{self.name}: migration replay needs >= 2 devices")
        if self.num_migrations and self.num_faults:
            raise ValueError(
                f"{self.name}: fault and migration replay are separate "
                "phase systems; use one per scenario")

    def config(self) -> dict:
        """JSON-able echo of the full config (the BENCH_*.json convention)."""
        return {
            "name": self.name,
            "seed": self.seed,
            "taskset": {k: list(v) if isinstance(v, tuple) else v
                        for k, v in self.taskset.items()},
            "arrivals": [self.arrivals[0], self.arrivals[1]],
            "etm": [self.etm[0],
                    {k: v for k, v in self.etm[1].items()
                     if k != "cost_model"}],
            "overheads": [self.overheads[0],
                          {k: v for k, v in self.overheads[1].items()
                           if k != "cost_model"}],
            "protocol": self.protocol,
            "scheduler": self.scheduler,
            "num_devices": self.num_devices,
            "cores_per_device": self.cores_per_device,
            "allocator": self.allocator,
            "horizon_periods": self.horizon_periods,
            "batch_max": self.batch_max,
            "num_faults": self.num_faults,
            "fault_detect_ms": self.fault_detect_ms,
            "num_migrations": self.num_migrations,
            "migration_cost_scale": self.migration_cost_scale,
        }


@dataclass
class BuiltScenario:
    """Everything needed to simulate and analyze one scenario."""

    scenario: Scenario
    protocol: Protocol
    system: System
    horizon_ms: float
    releases: dict[str, list[float]]
    etm: Callable[[Task, int], tuple[float, tuple[GpuSegment, ...]]]
    faults: list[DeviceFault]
    migrations: list[StreamMigration] = field(default_factory=list)

    def simulate(self, *, trace: bool | None = None) -> simulator.SimResult:
        return simulator.simulate(
            self.system,
            mode=self.protocol.sim_mode,
            horizon_ms=self.horizon_ms,
            trace=self.scenario.trace if trace is None else trace,
            batch_max=self.scenario.batch_max,
            faults=self.faults or None,
            migrations=self.migrations or None,
            releases=self.releases,
            etm=self.etm,
        )

    def analyze(self):
        """The protocol's response-time bounds; a replayed-fault scenario
        prices the recovery-augmented bound, a replayed-migration scenario
        the migration-delay-augmented one."""
        if self.faults:
            return server_analysis.analyze_pool_under_faults(
                self.system, self.faults)
        if self.migrations:
            return server_analysis.analyze_pool_under_migrations(
                self.system, self.migrations)
        return self.protocol.analyze(self.system)


@dataclass
class ScenarioResult:
    """One run's outcome: per-task analysis bound vs simulated WCRT."""

    scenario: Scenario
    system: System
    analysis: object
    sim: simulator.SimResult
    bounds: dict[str, float]
    wcrt: dict[str, float]
    schedulable: bool
    any_miss: bool

    def summary(self) -> dict:
        """One JSON cell for BENCH_scenarios.json: config echo + per-task
        bound/WCRT pairs (ms)."""
        per_task = [
            {"task": name,
             "device": next(t.device for t in self.system.tasks
                            if t.name == name),
             "bound_ms": None if math.isinf(b) else round(b, 6),
             "wcrt_ms": round(self.wcrt.get(name, 0.0), 6)}
            for name, b in sorted(self.bounds.items())
        ]
        finite = [(b["bound_ms"], b["wcrt_ms"]) for b in per_task
                  if b["bound_ms"] is not None]
        return {
            "scenario": self.scenario.name,
            "config": self.scenario.config(),
            "num_tasks": len(self.system.tasks),
            "schedulable": self.schedulable,
            "any_miss": self.any_miss,
            "max_wcrt_ms": round(max(self.wcrt.values(), default=0.0), 6),
            "min_bound_slack_ms": (
                round(min(b - w for b, w in finite), 6) if finite else None),
            "per_task": per_task,
        }


def build(scenario: Scenario, *, tasks: list[Task] | None = None,
          cost_model=None) -> BuiltScenario:
    """Resolve every registry key and construct the runnable scenario.

    ``tasks`` overrides the generated taskset (case studies); ``cost_model``
    is injected into 'measured' ETM/overhead specs (a
    ``analysis.cost_model.StepCostModel``, e.g. ingested from
    ``ServerPool.cell_stats()`` or loaded from BENCH_cost_model.json).
    """
    params = GenParams(**scenario.taskset)
    if tasks is None:
        tasks = generate_taskset(params, rng_stream(scenario.seed, "taskset"))
    tasks = SCHEDULERS.create(scenario.scheduler).assign(list(tasks))
    proto: Protocol = PROTOCOLS.create(scenario.protocol)

    ov_key, ov_params = scenario.overheads
    if ov_key in _NEEDS_COST_MODEL:
        ov_params = {"cost_model": cost_model, **ov_params}
    epsilon = OVERHEADS.create(ov_key, **ov_params).epsilon(params.epsilon_ms)

    if proto.approach == "sync":
        if scenario.num_devices != 1:
            raise ValueError(
                f"{scenario.name}: protocol {proto.name!r} models one global "
                f"mutex; num_devices must be 1")
        system = allocate(tasks, params.num_cores, approach="sync")
    else:
        cores = scenario.cores_per_device or params.num_cores
        if scenario.num_devices > 1 and not proto.pool_capable:
            raise ValueError(
                f"{scenario.name}: protocol {proto.name!r} is not pool-capable")
        if scenario.allocator == "lp":
            system = allocate_lp(tasks, scenario.num_devices, cores,
                                 epsilon=epsilon)
        elif scenario.num_devices > 1:
            system = allocate_pool(tasks, scenario.num_devices, cores,
                                   epsilon=epsilon,
                                   heuristic=scenario.allocator)
        else:
            system = allocate(tasks, cores, approach="server",
                              epsilon=epsilon, heuristic=scenario.allocator)

    horizon_ms = scenario.horizon_periods * max(t.T for t in system.tasks)

    arr_key, arr_params = scenario.arrivals
    arrival_model = ARRIVALS.create(arr_key, **arr_params)
    releases: dict[str, list[float]] = {}
    for t in system.tasks:
        rel = arrival_model.releases(
            t, horizon_ms, rng_stream(scenario.seed, f"arrivals/{t.name}"))
        check_min_separation(t, rel)  # guard custom models too
        releases[t.name] = rel

    etm_key, etm_params = scenario.etm
    if etm_key in _NEEDS_COST_MODEL:
        etm_params = {"cost_model": cost_model, **etm_params}
    etm_model = ETM.create(etm_key, **etm_params)
    etm_rngs = {t.name: rng_stream(scenario.seed, f"etm/{t.name}")
                for t in system.tasks}

    def etm_fn(task: Task, job_index: int):
        C, segs = etm_model.costs(task, job_index, etm_rngs[task.name])
        check_within_declared(task, C, segs)
        return C, segs

    faults: list[DeviceFault] = []
    if scenario.num_faults:
        if proto.approach != "server":
            raise ValueError(
                f"{scenario.name}: fault replay needs a server protocol")
        faults = seeded_device_faults(
            system, scenario.seed, num_faults=scenario.num_faults,
            horizon_ms=horizon_ms, detect_ms=scenario.fault_detect_ms,
            recovery_scale=scenario.fault_recovery_scale)

    migrations: list[StreamMigration] = []
    if scenario.num_migrations:
        if proto.approach != "server":
            raise ValueError(
                f"{scenario.name}: migration replay needs a server protocol")
        migrations = seeded_stream_migrations(
            system, scenario.seed, num_migrations=scenario.num_migrations,
            horizon_ms=horizon_ms, cost_scale=scenario.migration_cost_scale)

    return BuiltScenario(
        scenario=scenario, protocol=proto, system=system,
        horizon_ms=horizon_ms, releases=releases, etm=etm_fn, faults=faults,
        migrations=migrations)


def run(scenario: Scenario, *, tasks: list[Task] | None = None,
        cost_model=None) -> ScenarioResult:
    """Build, analyze, and simulate one scenario; pair every task's bound
    with its simulated WCRT."""
    built = build(scenario, tasks=tasks, cost_model=cost_model)
    analysis = built.analyze()
    sim = built.simulate()
    bounds = {t.name: analysis.wcrt(t.name) for t in built.system.tasks}
    wcrt = {t.name: sim.wcrt(t.name) for t in built.system.tasks}
    return ScenarioResult(
        scenario=scenario, system=built.system, analysis=analysis, sim=sim,
        bounds=bounds, wcrt=wcrt,
        schedulable=bool(getattr(analysis, "schedulable", False)),
        any_miss=sim.any_miss)
