"""Named scenario presets and the CI scenario matrix.

Each entry is a factory returning a :class:`Scenario`; ``**overrides``
replace any field (``SCENARIOS.create("flash_crowd", seed=3)``).  The CI
matrix (``CI_MATRIX``) is the set `make test-scenarios` property-tests and
``benchmarks/scenario_matrix.py`` prices into BENCH_scenarios.json:

  * ``diurnal_load`` — sinusoidally modulated arrivals over the horizon.
  * ``flash_crowd`` — MMPP bursts: long idle dwell, then back-to-back
    legal arrivals, under the batched server.
  * ``adversarial_long_context`` — heavy-tailed GPU segment splits (one
    dominant long-context segment per task) at high GPU ratio: maximizes
    the lower-priority blocking term the server bound charges.
  * ``multi_tenant_inversion`` — bimodal utilizations, wide period spread:
    big low-RM-priority tenants park long segments in front of
    latency-sensitive tasks — the priority-inversion attempt the
    priority-ordered server queue (and its Eq (3) blocking term) absorbs.
  * ``replayed_fault`` — a seeded device death mid-horizon on a 3-device
    pool; the recovery-augmented bound prices it.
  * ``replayed_migration`` — a seeded work-stealing/consolidation schedule
    on a 3-device pool; the migration-delay-augmented bound prices it.
  * ``trace_replay`` — arrivals replayed from the checked-in JSONL corpus
    (``scenarios/traces/``), dealt round-robin onto the generated taskset
    and normalized to each task's T.
  * ``measured_costs`` — per-job GPU costs priced from the committed
    BENCH_cost_model.json cell surfaces (real timings) instead of
    declared worst cases.
  * ``edf_server`` / ``fifo_server`` — the alternative queue orderings.
  * ``sync_mpcp`` / ``sync_fmlp`` — the synchronization-based baselines as
    first-class cells.
  * ``lp_allocated`` — the LP-relaxation allocation baseline on a pool.
"""

from __future__ import annotations

import json
import pathlib

from .registry import Registry
from .scenario import Scenario

__all__ = ["SCENARIOS", "CI_MATRIX", "default_cost_model"]

SCENARIOS = Registry("scenario")

# small-but-nonempty tasksets: CI cells simulate in well under a second each
_SMALL = {"num_cores": 2, "num_tasks": (4, 7), "epsilon_ms": 0.05,
          "pct_gpu_tasks": (0.3, 0.6)}
_POOL = {"num_cores": 2, "num_tasks": (6, 10), "epsilon_ms": 0.05,
         "pct_gpu_tasks": (0.3, 0.6)}


def _preset(name: str, **defaults):
    def factory(**overrides):
        return Scenario(**{"name": name, **defaults, **overrides})

    SCENARIOS.register(name, factory)
    return factory


_preset(
    "diurnal_load",
    taskset=_SMALL,
    arrivals=("diurnal", {"cycles": 2.0, "amplitude": 2.0}),
    etm=("uniform", {"frac": (0.7, 1.0)}),
    protocol="server",
)

_preset(
    "flash_crowd",
    taskset=_POOL,
    arrivals=("bursty", {"p_enter": 0.08, "p_exit": 0.25, "idle_factor": 5.0}),
    protocol="server_batched",
    num_devices=2, cores_per_device=2,
)

_preset(
    "adversarial_long_context",
    taskset={**_SMALL, "gpu_ratio": (0.25, 0.3), "num_segments": (1, 2),
             "seg_split": "heavy"},
    arrivals=("sporadic", {"slack": (0.0, 0.2)}),
    protocol="server",
)

_preset(
    "multi_tenant_inversion",
    taskset={**_SMALL, "period_ms": (20.0, 800.0),
             "bimodal_large_fraction": 0.3, "util_large": (0.2, 0.4),
             "gpu_ratio": (0.2, 0.3)},
    arrivals="periodic",
    protocol="server",
)

_preset(
    "replayed_fault",
    taskset=_POOL,
    protocol="server_batched",
    num_devices=3, cores_per_device=2,
    num_faults=1, fault_detect_ms=1.0,
)

_preset(
    "replayed_migration",
    taskset=_POOL,
    protocol="server_batched",
    num_devices=3, cores_per_device=2,
    num_migrations=2, migration_cost_scale=0.25,
)

_preset(
    "trace_replay",
    taskset=_POOL,
    arrivals=("trace", {"path": "bursty_pool.jsonl",
                        "assign": "round_robin", "normalize": True}),
    protocol="server_batched",
    num_devices=2, cores_per_device=2,
)

_preset(
    "measured_costs",
    taskset=_SMALL,
    etm=("measured", {"cell": ("decode", 4, 64), "safety": 1.2}),
    protocol="server",
)

_preset(
    "edf_server",
    taskset=_SMALL,
    arrivals=("sporadic", {"slack": (0.0, 0.3)}),
    protocol="server_edf",
    scheduler="dm",
)

_preset(
    "fifo_server",
    taskset=_SMALL,
    protocol="server_fifo",
)

_preset(
    "sync_mpcp",
    taskset=_SMALL,
    protocol="mpcp",
)

_preset(
    "sync_fmlp",
    taskset=_SMALL,
    protocol="fmlp",
)

_preset(
    "lp_allocated",
    taskset=_POOL,
    protocol="server",
    num_devices=2, cores_per_device=2,
    allocator="lp",
)

CI_MATRIX = (
    "diurnal_load",
    "flash_crowd",
    "adversarial_long_context",
    "multi_tenant_inversion",
    "replayed_fault",
    "replayed_migration",
    "trace_replay",
    "measured_costs",
    "edf_server",
    "fifo_server",
    "sync_mpcp",
    "sync_fmlp",
    "lp_allocated",
)


def default_cost_model(path: str | None = None):
    """A ``StepCostModel`` for 'measured' cells: loads the committed
    BENCH_cost_model.json measured-cell surfaces (real timings from the
    calibration benchmark) when available, else falls back to a small
    synthetic surface so the matrix runs everywhere."""
    from repro.analysis.cost_model import StepCostModel

    model = StepCostModel()
    candidates = ([pathlib.Path(path)] if path else [
        pathlib.Path(__file__).resolve().parents[3]
        / "benchmarks" / "BENCH_cost_model.json",
        pathlib.Path("benchmarks/BENCH_cost_model.json"),
    ])
    for p in candidates:
        try:
            data = json.loads(p.read_text())
        except (OSError, ValueError):
            continue
        for cell in data.get("cells", ()):
            key = tuple(cell["cell"])
            for _ in range(max(int(cell.get("timed", 1)), 1)):
                model.observe(key, float(cell["measured_s"]))
        if model.cells:
            return model
    # synthetic fallback: a plausible CPU-JAX-shaped surface
    for rows in (1, 2, 4, 8):
        for width in (1, 4, 16, 64):
            model.observe(("decode", rows, width),
                          8e-4 + 2e-5 * rows + 1e-6 * rows * width)
    return model
