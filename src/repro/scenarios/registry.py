"""Small string-keyed plugin registries (the rtos_sim idiom).

Every pluggable axis of the scenario engine — arrival models, execution-time
models, overhead models, protocols, schedulers, named scenarios — is one
:class:`Registry`: factories register under a short string key, configs name
the key plus keyword parameters, and :meth:`Registry.create` instantiates.
Unknown keys fail loudly with the list of registered alternatives, so a typo
in a scenario config is a one-line error instead of a silent default.
"""

from __future__ import annotations

from typing import Any, Callable, Iterator

__all__ = ["Registry", "RegistryError"]


class RegistryError(KeyError):
    """Unknown registry key (carries the available alternatives)."""

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return self.args[0]


class Registry:
    """A string-keyed factory table.

    >>> ARRIVALS = Registry("arrival model")
    >>> @ARRIVALS.register("periodic")
    ... class Periodic: ...
    >>> ARRIVALS.create("periodic")
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._factories: dict[str, Callable[..., Any]] = {}

    def register(self, name: str, factory: Callable[..., Any] | None = None):
        """Register ``factory`` under ``name``; usable as a decorator."""
        if name in self._factories:
            raise ValueError(f"duplicate {self.kind} key {name!r}")

        def _add(f: Callable[..., Any]):
            self._factories[name] = f
            return f

        return _add if factory is None else _add(factory)

    def create(self, name: str, /, **params) -> Any:
        """Instantiate the factory registered under ``name``."""
        try:
            factory = self._factories[name]
        except KeyError:
            raise RegistryError(
                f"unknown {self.kind} {name!r}; available: {self.available()}"
            ) from None
        return factory(**params)

    def get(self, name: str) -> Callable[..., Any]:
        """The raw factory (without instantiating it)."""
        try:
            return self._factories[name]
        except KeyError:
            raise RegistryError(
                f"unknown {self.kind} {name!r}; available: {self.available()}"
            ) from None

    def available(self) -> list[str]:
        return sorted(self._factories)

    def __contains__(self, name: object) -> bool:
        return name in self._factories

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._factories))

    def __len__(self) -> int:
        return len(self._factories)
