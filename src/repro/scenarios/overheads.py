"""Pluggable overhead models: what does one server invocation cost?

The paper folds all server-side CPU overhead into a single bound ``eps``
(Lemma 1: 2*eps extra CPU per request).  An overhead model maps the
taskset generator's base epsilon to the value the built ``System`` carries
— the analyses and the simulator both consume ``System.epsilon``, so one
knob moves both sides in lockstep and bound-dominance is preserved by
construction.

The ``measured`` model closes the loop to real timings the same way the
``measured`` ETM does: epsilon becomes the fitted per-call dispatch
intercept of a :class:`~repro.analysis.cost_model.StepCostModel` — the
runtime analogue of the paper's eps (see ``dispatch_overhead_s``).
"""

from __future__ import annotations

import math

from .registry import Registry

__all__ = ["OVERHEADS"]

OVERHEADS = Registry("overhead model")


@OVERHEADS.register("constant")
class Constant:
    """A fixed epsilon: the explicit ``epsilon_ms`` when given, else the
    generator's base value passes through unchanged."""

    def __init__(self, epsilon_ms: float | None = None):
        if epsilon_ms is not None and epsilon_ms < 0:
            raise ValueError(f"epsilon_ms must be >= 0, got {epsilon_ms}")
        self.epsilon_ms = epsilon_ms

    def epsilon(self, base_ms: float) -> float:
        return base_ms if self.epsilon_ms is None else self.epsilon_ms


@OVERHEADS.register("zero")
class Zero:
    """Idealized zero-overhead server (the eps -> 0 limit the paper's
    Fig. 13 sensitivity sweep approaches)."""

    def epsilon(self, base_ms: float) -> float:
        return 0.0


@OVERHEADS.register("scaled")
class Scaled:
    """Base epsilon scaled by ``factor`` (the Fig. 13 eps-sensitivity axis)."""

    def __init__(self, factor: float = 1.0):
        if factor < 0:
            raise ValueError(f"factor must be >= 0, got {factor}")
        self.factor = factor

    def epsilon(self, base_ms: float) -> float:
        return base_ms * self.factor


@OVERHEADS.register("measured")
class MeasuredIntercept:
    """Epsilon = the cost model's fitted per-call dispatch intercept (the
    measured analogue of the paper's eps), floored at the generator's base
    value so the bound never claims less overhead than the paper assumes."""

    def __init__(self, cost_model=None, phase: str = "decode",
                 floor_at_base: bool = True):
        if cost_model is None:
            raise ValueError(
                "overheads 'measured' needs a StepCostModel: pass "
                "cost_model= to scenario build()/run()")
        self.cost_model = cost_model
        self.phase = phase
        self.floor_at_base = floor_at_base

    def epsilon(self, base_ms: float) -> float:
        eps_ms = self.cost_model.dispatch_overhead_s(self.phase) * 1e3
        if not math.isfinite(eps_ms):
            return base_ms  # unmeasured phase: keep the declared overhead
        return max(eps_ms, base_ms) if self.floor_at_base else eps_ms
