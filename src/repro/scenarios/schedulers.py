"""Pluggable CPU schedulers: how are task priorities assigned?

The execution substrate is partitioned fixed-priority scheduling (the
paper's setting), so a "scheduler" here is a priority-assignment policy
over the generated taskset; the server's QUEUE ordering (priority / FIFO /
EDF) is the protocol's axis and reuses ``dispatch.policy.request_key``
verbatim — one definition of request order across the runtime, the
simulator, and the scenario engine.

Registering a new policy::

    @SCHEDULERS.register("my_order")
    class MyOrder:
        def assign(self, tasks) -> list[Task]: ...   # unique priorities
"""

from __future__ import annotations

from repro.core.dispatch.policy import ORDERINGS
from repro.core.task_model import Task
from repro.core.taskset_gen import assign_rm_priorities

from .registry import Registry

__all__ = ["SCHEDULERS", "ORDERINGS"]

SCHEDULERS = Registry("scheduler")


@SCHEDULERS.register("rm")
class RateMonotonic:
    """Rate-Monotonic: shorter period = higher priority (the paper's
    assignment, arbitrary tie-break by index)."""

    def assign(self, tasks: list[Task]) -> list[Task]:
        return assign_rm_priorities(tasks)


@SCHEDULERS.register("dm")
class DeadlineMonotonic:
    """Deadline-Monotonic: shorter relative deadline = higher priority
    (optimal for constrained deadlines; coincides with RM when D = T)."""

    def assign(self, tasks: list[Task]) -> list[Task]:
        order = sorted(range(len(tasks)), key=lambda k: (tasks[k].D, k))
        out = list(tasks)
        n = len(tasks)
        for rank, k in enumerate(order):
            out[k] = out[k].with_priority(n - rank)
        return out


@SCHEDULERS.register("given")
class AsGiven:
    """Keep the priorities the taskset already carries (case studies with
    hand-assigned priorities); validates uniqueness."""

    def assign(self, tasks: list[Task]) -> list[Task]:
        prios = [t.priority for t in tasks]
        if len(set(prios)) != len(prios):
            raise ValueError("scheduler 'given' needs unique task priorities")
        return list(tasks)
