"""Serving driver: the paper's server-based access control, live.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b \
        --streams 3 --requests 5 --steps 8

Starts one ServeEngine (AcceleratorServer + analysis-driven admission),
admits N prioritized streams, runs their generation jobs concurrently from
client threads (which suspend between segments — never busy-wait), and
reports per-stream latency percentiles + the admission decisions.
"""

from __future__ import annotations

import argparse
import threading

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine, StreamSpec


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--streams", type=int, default=3)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--ordering", default="priority",
                    choices=["priority", "fifo", "edf"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    engine = ServeEngine(cfg, params, max_seq=64, ordering=args.ordering)

    results: dict[str, list] = {}
    decisions = {}
    threads = []
    for i in range(args.streams):
        name = f"stream{i}"
        spec = StreamSpec(name=name, priority=args.streams - i,
                          period_ms=500.0, deadline_ms=500.0,
                          prefill_ms=40.0, decode_ms=10.0,
                          decode_steps=args.steps)
        decisions[name] = engine.admit(spec)
        if not decisions[name].admitted:
            print(f"{name}: REJECTED ({decisions[name].reason})")
            continue

        def work(name=name, seed=i):
            rng = np.random.RandomState(seed)
            out = []
            for _ in range(args.requests):
                prompt = rng.randint(0, cfg.vocab_size, (1, 8)).astype(np.int32)
                out.append(engine.generate(name, prompt, steps=args.steps))
            results[name] = out

        threads.append(threading.Thread(target=work))

    for t in threads:
        t.start()
    for t in threads:
        t.join()

    report = {}
    for name, runs in sorted(results.items()):
        pre = [r.prefill_latency_s * 1e3 for r in runs]
        dec = [d * 1e3 for r in runs for d in r.decode_latencies_s]
        report[name] = {"prefill_p50_ms": float(np.percentile(pre, 50)),
                        "decode_p50_ms": float(np.percentile(dec, 50)),
                        "decode_p99_ms": float(np.percentile(dec, 99))}
        print(f"{name}: prefill p50 {report[name]['prefill_p50_ms']:.1f}ms  "
              f"decode p50 {report[name]['decode_p50_ms']:.1f}ms  "
              f"p99 {report[name]['decode_p99_ms']:.1f}ms")
    print(f"server completed {engine.server.stats.completed} requests, "
          f"max queue {engine.server.stats.max_queue_len}")
    engine.close()
    return report


if __name__ == "__main__":
    main()
