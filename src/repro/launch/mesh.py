"""Production mesh construction.

Built lazily via functions so importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; smoke
tests must keep seeing 1 device).
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh, *, multi_pod: bool = False, fsdp: bool = True,
               shard_seq: bool = False) -> ShardingRules:
    return ShardingRules(
        mesh=mesh,
        batch_axes=("pod", "data") if multi_pod else ("data",),
        model_axis="model",
        fsdp=fsdp,
        shard_seq=shard_seq,
    )


def make_debug_mesh(n: int, *, axes=("data", "model"), shape=None):
    """Small host-device mesh for tests (requires
    xla_force_host_platform_device_count set before jax init)."""
    devs = jax.devices()[:n]
    if shape is None:
        shape = (1, n)
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devs).reshape(shape), axes)
