"""Step builders shared by launchers and the dry-run: jitted train / prefill
/ decode steps with explicit in/out shardings for the production mesh.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model as M
from repro.training import optimizer as opt
from repro.training import train_step as ts


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cache_shapes, rules: shd.ShardingRules, *, batch: int, seq: int):
    """PartitionSpec tree for a decode cache.

    Per leaf: the sequence dim (== seq) shards over the model axis (over
    ALL axes when batch == 1, long-context); the batch dim (== batch) over
    the DP axes; state-like leaves without a sequence dim shard their first
    model-divisible channel/head dim over the model axis."""
    model_size = rules.mesh.shape[rules.model_axis]
    batch_axes = rules.batch()

    def spec(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd <= 1:
            return P()
        out = [None] * nd
        si = next((i for i in range(1, nd) if seq > 1 and shape[i] == seq), None)
        bi = next((i for i in range(1, nd)
                   if batch > 1 and rules.shard_batch
                   and shape[i] == batch and i != si), None)
        if si is not None:
            out[si] = ((*rules.batch_axes, rules.model_axis)
                       if (batch == 1 or not rules.shard_batch)
                       else rules.seq_axes if len(rules.seq_axes) > 1
                       else rules.seq_axes[0])
        if bi is not None:
            out[bi] = batch_axes
        if si is None:
            start = (bi + 1) if bi is not None else 1
            for i in range(start, nd):
                if i != bi and shape[i] % model_size == 0 and shape[i] >= model_size:
                    out[i] = rules.model_axis
                    break
        return P(*out)

    return jax.tree.map(spec, cache_shapes)


def logits_pspec(rules: shd.ShardingRules, *, batch: int, vocab: int):
    b = rules.batch() if batch > 1 else None
    model_size = rules.mesh.shape[rules.model_axis]
    v = rules.model_axis if vocab % model_size == 0 else None
    return P(b, None, v)


def build_train_step(cfg, rules, settings: ts.TrainSettings, batch_shapes):
    return ts.build_train_step(cfg, settings, rules, batch_shapes)


def build_prefill(cfg, rules: shd.ShardingRules, *, max_seq: int, batch: int,
                  batch_shapes):
    mesh = rules.mesh

    def fn(params, batch_):
        with shd.use_rules(rules):
            logits, cache, _ = M.apply(cfg, params, {**batch_, "max_seq": max_seq},
                                       mode="prefill")
            return logits, cache

    params_shape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                  jax.random.PRNGKey(0))
    pspecs = shd.param_specs(params_shape, rules)
    bspecs = ts.batch_specs(cfg, batch_shapes, rules)
    out_shape = jax.eval_shape(fn, params_shape, batch_shapes)
    cspecs = cache_pspecs(out_shape[1], rules, batch=batch, seq=max_seq)
    return jax.jit(
        fn,
        in_shardings=(_named(pspecs, mesh), _named(bspecs, mesh)),
        out_shardings=(NamedSharding(mesh, logits_pspec(rules, batch=batch, vocab=cfg.vocab_size)),
                       _named(cspecs, mesh)),
    )


def build_decode(cfg, rules: shd.ShardingRules, *, max_seq: int, batch: int,
                 batch_shapes, cache_shapes):
    mesh = rules.mesh

    def fn(params, batch_, cache):
        with shd.use_rules(rules):
            logits, cache, _ = M.apply(cfg, params, batch_, mode="decode",
                                       cache=cache)
            return logits, cache

    params_shape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                  jax.random.PRNGKey(0))
    pspecs = shd.param_specs(params_shape, rules)
    bspecs = ts.batch_specs(cfg, batch_shapes, rules)
    cspecs = cache_pspecs(cache_shapes, rules, batch=batch, seq=max_seq)
    return jax.jit(
        fn,
        in_shardings=(_named(pspecs, mesh), _named(bspecs, mesh),
                      _named(cspecs, mesh)),
        out_shardings=(NamedSharding(mesh, logits_pspec(rules, batch=batch, vocab=cfg.vocab_size)),
                       _named(cspecs, mesh)),
        donate_argnums=(2,),
    )
