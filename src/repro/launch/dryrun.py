import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # compile-only dry-run: keep native bf16 dots (TPU semantics) instead of
    # the CPU runtime's f32 legalization, which otherwise duplicates bf16
    # caches/weights as f32 loop carries and poisons the roofline terms
    "--xla_cpu_strict_dot_conv_math=false"
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), record
memory/cost analysis + roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_405b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
The roofline table (§Roofline) reads the single-pod artifacts.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.analysis import roofline  # noqa: E402
from repro.configs.registry import ARCH_IDS, SHAPES, get_config, shapes_for  # noqa: E402
from repro.launch import mesh as mesh_mod  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.training import optimizer as opt  # noqa: E402
from repro.training import train_step as ts  # noqa: E402

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _sds_tree(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               settings: ts.TrainSettings | None = None,
               shard_seq: bool = False, fsdp: bool = True,
               variant: str = "baseline"):
    """Lower+compile one cell; returns (compiled, lowered, meta).

    ``variant`` names a repro.models.perf.VARIANTS entry (the §Perf
    hillclimb knobs); "baseline" is the naive configuration the roofline
    table was recorded with."""
    import dataclasses

    from repro.models import perf

    flags = perf.VARIANTS[variant]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_model = mesh.shape["model"]
    # sequence-parallel activations only when the seq divides the model axis
    shard_seq = ((shard_seq or flags.shard_seq) and shape.kind == "train"
                 and shape.seq_len % n_model == 0)
    rules = mesh_mod.make_rules(mesh, multi_pod=multi_pod, shard_seq=shard_seq,
                                fsdp=fsdp)
    if flags.moe_decode == "tp_data" and shape.kind == "decode" and cfg.is_moe:
        rules = dataclasses.replace(rules, expert_ff_fsdp=True)
    if flags.serve_2d and shape.kind == "decode":
        rules = dataclasses.replace(
            rules, shard_batch=False,
            seq_axes=(*rules.batch_axes, rules.model_axis))
    perf.set_flags(flags)

    params_shape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                  jax.random.PRNGKey(0))
    batch_shapes = M.input_specs(cfg, shape)

    if shape.kind == "train":
        settings = settings or ts.TrainSettings()
        step = steps_mod.build_train_step(cfg, rules, settings, batch_shapes)
        opt_shape = jax.eval_shape(lambda p: opt.init(p, settings.adamw), params_shape)
        args = (params_shape, _sds_tree(opt_shape), batch_shapes)
    elif shape.kind == "prefill":
        step = steps_mod.build_prefill(cfg, rules, max_seq=shape.seq_len,
                                       batch=shape.global_batch,
                                       batch_shapes=batch_shapes)
        args = (params_shape, batch_shapes)
    else:  # decode
        cache_shapes = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
        step = steps_mod.build_decode(cfg, rules, max_seq=shape.seq_len,
                                      batch=shape.global_batch,
                                      batch_shapes=batch_shapes,
                                      cache_shapes=_sds_tree(cache_shapes))
        args = (params_shape, batch_shapes, _sds_tree(cache_shapes))

    try:
        t0 = time.perf_counter()
        lowered = step.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
    finally:
        perf.set_flags(None)
    meta = {"lower_s": t1 - t0, "compile_s": t2 - t1, "chips": mesh.size,
            "shard_seq": shard_seq, "variant": variant}
    return compiled, lowered, meta, cfg, shape


def _model_flops(cfg, shape) -> float:
    n_active = M.param_count(cfg, active_only=True)
    if shape.kind == "train":
        return roofline.train_model_flops(n_active,
                                          shape.global_batch * shape.seq_len)
    if shape.kind == "prefill":
        return roofline.prefill_model_flops(n_active,
                                            shape.global_batch * shape.seq_len)
    return roofline.decode_model_flops(n_active, shape.global_batch)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             settings=None, tag: str = "", variant: str = "baseline") -> dict:
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    if variant != "baseline" and not tag:
        tag = f"__{variant}"
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "ok", "variant": variant}
    try:
        compiled, lowered, meta, cfg, shape = lower_cell(
            arch, shape_name, multi_pod=multi_pod, settings=settings,
            variant=variant)
        record.update(meta)
        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes"):
                record[attr] = getattr(mem, attr, None)
        cost = compiled.cost_analysis() or {}
        record["cost_flops"] = float(cost.get("flops", 0.0))
        record["cost_bytes"] = float(cost.get("bytes accessed", 0.0))
        if not multi_pod:
            hlo = compiled.as_text()
            terms = roofline.analyze(
                cost, hlo, chips=record["chips"],
                model_flops=_model_flops(cfg, shape),
                flops_are_global=False,  # CPU backend: per-partition module
            )
            record["roofline"] = terms.to_dict()
    except Exception as e:  # noqa: BLE001 - recorded, not fatal
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    out = ART / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(record, indent=1, default=str))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="perf variant (repro.models.perf.VARIANTS)")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in shapes_for(cfg):
                if not args.multi_pod_only:
                    cells.append((arch, shape.name, False))
                if not args.single_pod_only:
                    cells.append((arch, shape.name, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        t0 = time.perf_counter()
        rec = run_cell(arch, shape, multi_pod=mp, variant=args.variant)
        dt = time.perf_counter() - t0
        mesh_name = "multi_pod" if mp else "single_pod"
        if rec["status"] == "ok":
            r = rec.get("roofline") or {}
            print(f"OK   {arch:24s} {shape:12s} {mesh_name:10s} "
                  f"compile={rec['compile_s']:.1f}s "
                  f"bottleneck={r.get('bottleneck', '-'):10s} "
                  f"frac={r.get('roofline_fraction', 0):.3f} ({dt:.1f}s)")
        else:
            failures += 1
            print(f"FAIL {arch:24s} {shape:12s} {mesh_name:10s} {rec['error']}")
        import sys
        sys.stdout.flush()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
