"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
        --reduced --steps 100 --global-batch 8 --seq-len 64 \
        --checkpoint-dir /tmp/ckpt [--resume]

Wires together: config registry -> model -> AdamW -> synthetic data with
host prefetch -> checkpoint manager (interval + async) -> straggler
watchdog.  With ``--reduced`` the smoke-scale config runs on CPU; full
configs expect a real TPU mesh (the same builder the dry-run exercises).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import model as M
from repro.runtime.fault_tolerance import CheckpointManager
from repro.runtime.straggler import StepTimeWatchdog
from repro.training import optimizer as opt
from repro.training.train_step import TrainSettings, build_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", type=str, default="")
    ap.add_argument("--checkpoint-interval", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    settings = TrainSettings(adamw=opt.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params, settings.adamw)
    step_fn = build_train_step(cfg, settings, None)

    start = 0
    mgr = None
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir,
                                interval=args.checkpoint_interval)
        if args.resume and mgr.latest_step() is not None:
            (params, state), start = mgr.restore_latest((params, state))
            print(f"resumed from step {start}")

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq_len,
                                  args.global_batch))
    pf = Prefetcher(data, start_step=start)
    watchdog = StepTimeWatchdog()
    losses = []
    try:
        for i in range(start, args.steps):
            _, batch = pf.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, state, metrics = step_fn(params, state, batch)
            loss = float(metrics["loss"])
            straggler = watchdog.observe(time.perf_counter() - t0)
            losses.append(loss)
            if mgr is not None:
                mgr.maybe_save(i + 1, (params, state))
            if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
                print(f"step {i+1:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}"
                      + (" [straggler]" if straggler else ""))
    finally:
        pf.close()
        if mgr is not None:
            mgr.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "params": params}


if __name__ == "__main__":
    main()
