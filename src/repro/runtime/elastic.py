"""Elastic rescale: choose a new mesh when devices are lost, and compute the
resharding plan for checkpoint restore.

Policy: the model axis is load-bearing (TP/EP weight shards) and is kept
fixed; failures shrink the DATA axis to the largest size that (a) fits the
surviving device count and (b) divides the global batch.  This matches how
large fleets actually degrade: drop whole DP replicas, keep the model
sharding intact, restore from the latest checkpoint with the new shardings
(training.checkpoint.restore takes the new sharding tree directly).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    devices_used: int

    def build(self, devices=None):
        devices = devices if devices is not None else jax.devices()
        n = int(np.prod(self.shape))
        dev = np.asarray(devices[:n]).reshape(self.shape)
        return jax.sharding.Mesh(dev, self.axes)


def plan_after_failure(total_devices: int, *, model: int, global_batch: int,
                       pod: int = 1) -> MeshPlan:
    """Largest data axis with data*model*pod <= total_devices, data | batch."""
    if total_devices < model:
        raise ValueError(f"cannot keep model axis {model} on {total_devices} devices")
    max_data = total_devices // (model * pod)
    data = max_data
    while data > 1 and (global_batch % data):
        data -= 1
    data = max(data, 1)
    if pod > 1:
        return MeshPlan((pod, data, model), ("pod", "data", "model"),
                        pod * data * model)
    return MeshPlan((data, model), ("data", "model"), data * model)


def degraded_throughput_fraction(old: MeshPlan, new: MeshPlan) -> float:
    return new.devices_used / old.devices_used
