"""Elastic rescale: choose a new mesh when devices are lost, and compute the
resharding plan for checkpoint restore.

Policy: the model axis is load-bearing (TP/EP weight shards) and is kept
fixed; failures shrink the DATA axis to the largest size that (a) fits the
surviving device count and (b) divides the global batch.  This matches how
large fleets actually degrade: drop whole DP replicas, keep the model
sharding intact, restore from the latest checkpoint with the new shardings
(training.checkpoint.restore takes the new sharding tree directly).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    devices_used: int

    def build(self, devices=None):
        devices = devices if devices is not None else jax.devices()
        n = int(np.prod(self.shape))
        dev = np.asarray(devices[:n]).reshape(self.shape)
        return jax.sharding.Mesh(dev, self.axes)


def plan_after_failure(total_devices: int, *, model: int, global_batch: int,
                       pod: int = 1) -> MeshPlan:
    """Largest data axis with data*model*pod <= total_devices, data | batch."""
    if total_devices < model:
        raise ValueError(f"cannot keep model axis {model} on {total_devices} devices")
    max_data = total_devices // (model * pod)
    data = max_data
    while data > 1 and (global_batch % data):
        data -= 1
    data = max(data, 1)
    if pod > 1:
        return MeshPlan((pod, data, model), ("pod", "data", "model"),
                        pod * data * model)
    return MeshPlan((data, model), ("data", "model"), data * model)


def degraded_throughput_fraction(old: MeshPlan, new: MeshPlan) -> float:
    return new.devices_used / old.devices_used


# -- serving-pool elasticity (live KV migration; see serving.engine) --------
@dataclass(frozen=True)
class LoadTrajectory:
    """Piecewise-constant pool-size plan: ``points`` are (at_s, target)
    pairs, sorted by time; ``target_at(t)`` is the last target at or before
    ``t`` (the first target before the first point).  Drives
    ``ElasticPoolController`` through a scripted ramp in benchmarks and
    tests — the serving analogue of a traffic forecast."""

    points: tuple[tuple[float, int], ...]

    def __post_init__(self):
        pts = tuple(sorted((float(a), int(n)) for a, n in self.points))
        if not pts:
            raise ValueError("LoadTrajectory needs at least one point")
        object.__setattr__(self, "points", pts)

    def target_at(self, t_s: float) -> int:
        tgt = self.points[0][1]
        for at, n in self.points:
            if at <= t_s:
                tgt = n
            else:
                break
        return tgt


class ElasticPoolController:
    """Scale a ServeEngine's server pool toward a target size mid-traffic.

    Scale-up adds servers (``engine.add_server``: pool + admission grow in
    lockstep, pools warmed off the hot path).  Scale-down retires the
    LEAST-utilized live servers (by admission GPU utilization, ties to the
    highest index so elastically-added servers leave first) via
    ``engine.remove_server`` — live-KV migration for in-flight streams,
    degraded-mode admission proving the shrunk placement.  A server whose
    drain times out is left alone (scale-down is best-effort; the next
    ``scale_to`` retries)."""

    def __init__(self, engine, *, min_servers: int = 1,
                 max_servers: int = 8):
        if min_servers < 1 or max_servers < min_servers:
            raise ValueError(f"bad bounds [{min_servers}, {max_servers}]")
        self.engine = engine
        self.min_servers = min_servers
        self.max_servers = max_servers
        self.events: list[tuple[str, int]] = []  # ("add"|"remove", si)

    def live(self) -> list[int]:
        drain = self.engine.pool.draining()
        return [i for i in self.engine.pool.alive_servers()
                if i not in drain]

    def scale_to(self, n: int, *, timeout_s: float = 10.0) -> list[int]:
        """Add/remove servers until the live count hits ``n`` (clamped to
        the controller's bounds); returns the live server list after."""
        n = max(self.min_servers, min(self.max_servers, int(n)))
        while len(self.live()) < n:
            si = self.engine.add_server()
            self.events.append(("add", si))
        while len(self.live()) > n:
            victim = min(self.live(),
                         key=lambda i: (self.engine.admission
                                        .gpu_utilization(i), -i))
            try:
                self.engine.remove_server(victim, timeout_s=timeout_s)
            except TimeoutError:
                break  # busy server: leave it; a later scale_to retries
            self.events.append(("remove", victim))
        return self.live()
