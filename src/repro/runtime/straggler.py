"""Straggler mitigation.

Two mechanisms, matching the two workload kinds:

  * Serving: the GPU server's request queue is the single control point
    (the paper's central-knowledge observation, §7).  ``DeadlineAwarePolicy``
    watches per-request handling times; when a stream's p95 handling time
    approaches its deadline it promotes the stream (or flips the server to
    EDF ordering), which is exactly the paper's priority-queue mechanism
    applied online.

  * Training: ``StepTimeWatchdog`` tracks per-step wall times; a step
    exceeding ``factor`` x the running p50 flags a straggler.  The standard
    mitigations at fleet scale are (a) within-pod: rely on XLA's collective
    timeouts, (b) cross-pod: drop the slow DP replica at the next
    checkpoint boundary (runtime.elastic plans the shrink).  The watchdog
    emits the signal; the supervisor applies (b).
"""

from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field


class StepTimeWatchdog:
    def __init__(self, *, window: int = 50, factor: float = 3.0,
                 min_samples: int = 5, escalate_after: int = 3):
        self.times: deque[float] = deque(maxlen=window)
        self.factor = factor
        self.min_samples = min_samples
        self.escalate_after = escalate_after
        self.flagged: list[tuple[int, float]] = []
        self.consecutive = 0  # straggler steps in a row (degraded health)
        self._step = 0

    def observe(self, duration_s: float) -> bool:
        """Record a step duration; returns True if it is a straggler step."""
        self._step += 1
        is_straggler = False
        if len(self.times) >= self.min_samples:
            p50 = statistics.median(self.times)
            if duration_s > self.factor * p50:
                self.flagged.append((self._step, duration_s))
                is_straggler = True
        self.consecutive = self.consecutive + 1 if is_straggler else 0
        self.times.append(duration_s)
        return is_straggler

    @property
    def degraded(self) -> bool:
        """True once ``escalate_after`` consecutive steps ran slow — the
        owner should treat the device as unhealthy (serving wires this next
        to the heartbeat stall path as a softer escalation signal)."""
        return self.consecutive >= self.escalate_after


@dataclass
class StreamStats:
    deadline_ms: float
    handling_ms: deque = field(default_factory=lambda: deque(maxlen=100))


class DeadlineAwarePolicy:
    """Serving-side mitigation on top of core.server_runtime.

    ``observe(stream, handling_ms)`` feeds completions;
    ``at_risk()`` lists streams whose p95 handling time is within
    ``margin`` of their deadline;  ``boost(stream)`` returns the suggested
    priority bump (applied by the engine when submitting that stream's next
    requests)."""

    def __init__(self, *, margin: float = 0.8):
        self.margin = margin
        self.streams: dict[str, StreamStats] = {}

    def register(self, name: str, deadline_ms: float) -> None:
        self.streams[name] = StreamStats(deadline_ms)

    def observe(self, name: str, handling_ms: float) -> None:
        self.streams[name].handling_ms.append(handling_ms)

    def p95(self, name: str) -> float:
        h = sorted(self.streams[name].handling_ms)
        if not h:
            return 0.0
        return h[min(int(0.95 * len(h)), len(h) - 1)]

    def at_risk(self) -> list[str]:
        return [n for n, s in self.streams.items()
                if s.handling_ms and self.p95(n) > self.margin * s.deadline_ms]

    def boost(self, name: str, current_priority: int) -> int:
        return current_priority + (100 if name in self.at_risk() else 0)
