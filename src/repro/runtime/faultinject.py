"""Deterministic fault injection for the serving pool.

This is the runtime half of the fault story (``core.faults`` holds the
shared vocabulary; ``core.simulator`` replays ``DeviceFault`` schedules
against the discrete-event model).  Here, faults are injected into LIVE
``AcceleratorServer``/``BatchingServer`` threads: every server runs its
device calls through ``_attempt``, which first invokes an installed
``fault_hook`` — the injector's per-server closure — so a schedule can
make a real device call die, stall, run slow, or fail transiently at an
exact call index, deterministically and repeatably.

Failure model
=============

Four fault kinds, matching how real accelerators misbehave:

``die``
    The device is gone: the hook raises ``DeviceLostError``.  The server
    declares itself failed — every queued and in-flight request completes
    with ``ServerFailedError``, waking suspended clients into the stream-
    recovery path (``ServeEngine`` re-prefills each stream's retained
    prefix on a survivor; ``ServerPool.evict_server`` re-routes).

``stall``
    The call hangs for ``delay_s`` and THEN raises ``DeviceLostError`` —
    modeling a wedged device whose call never returns usefully.  Because
    servers heartbeat between device calls, a stall longer than the
    monitor timeout is detected from OUTSIDE by the ``HeartbeatMonitor``
    (``ServerPool.enable_failure_detection``): the monitor thread evicts
    the server while the call is still stuck, which is what makes the
    stall path a *per-device-call timeout* rather than a hang.

``slow``
    The call sleeps ``delay_s`` and then proceeds normally — a straggler
    step, visible to the server's ``StepTimeWatchdog`` (consecutive slow
    steps mark the server ``degraded``).

``transient``
    The hook raises ``TransientDeviceError`` for ``count`` consecutive
    attempts, then lets the call through.  The server retries with
    bounded exponential backoff (``max_retries``); a storm longer than
    the retry budget escalates to ``DeviceLostError`` — i.e. ``die``.

Recovery-delay analysis term
============================

The analysis side prices a death as a ``core.faults.DeviceFault``: the
failed device's streams migrate to a single survivor and each gains one
extra GPU request — the *recovery segment*, the re-prefill of the
stream's retained prefix (prompt + tokens generated so far), priced by
the calibrated ``StepCostModel`` at admission time
(``PoolAdmissionController.evict_device(recovery_cost_ms=...)``).  The
per-task bound becomes a sum of per-phase Eqs (1)-(6) bounds plus the
detection gap (``server_analysis.analyze_pool_under_faults``), and the
property suite pins it above simulated WCRT under the same schedule.

Writing a fault schedule
========================

A schedule is a list of :class:`ServerFault` events, each pinned to a
server index and a 0-based device-call ordinal on that server::

    from repro.runtime.faultinject import FaultInjector, ServerFault

    inj = FaultInjector([
        ServerFault(server=1, at_call=5, kind="die"),
        ServerFault(server=0, at_call=3, kind="transient", count=2),
        ServerFault(server=2, at_call=0, kind="stall", delay_s=1.0),
    ])
    inj.attach(pool)          # or pool.attach_fault_injector(inj)

Call indices count the calls the schedule's hook sees on that server
(prefill and decode alike), so a fixed workload + fixed schedule is
bit-reproducible.  ``FaultInjector.seeded(...)`` derives a schedule from
a seed for chaos matrices; ``injector.events`` logs every fired fault
with a timestamp, which the recovery benchmark uses to measure
detection -> resume latency.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

# Re-exported so schedule authors import one module.
from repro.core.faults import (DeviceFault, DeviceLostError,  # noqa: F401
                               ServerFailedError, StreamShedError,
                               TransientDeviceError, seeded_device_faults)

__all__ = [
    "FaultInjector",
    "ServerFault",
    "DeviceFault",
    "DeviceLostError",
    "ServerFailedError",
    "StreamShedError",
    "TransientDeviceError",
    "seeded_device_faults",
]

_KINDS = ("die", "stall", "slow", "transient")


@dataclass(frozen=True)
class ServerFault:
    """One scheduled fault against a live server.

    Fires when server ``server`` makes its ``at_call``-th device call
    (0-based, counted per server).  ``count`` extends ``transient`` faults
    over that many consecutive attempts; ``delay_s`` is the hang length
    for ``stall`` / ``slow``."""

    server: int
    at_call: int
    kind: str
    count: int = 1
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if self.at_call < 0 or self.count < 1 or self.delay_s < 0:
            raise ValueError(f"invalid fault: {self}")


@dataclass
class FaultEvent:
    """One fired fault, logged for the recovery benchmark."""

    server: int
    call: int
    kind: str
    at_monotonic: float


class FaultInjector:
    """Installs per-server fault hooks realizing a :class:`ServerFault`
    schedule.  Deterministic: hooks key off each server's device-call
    ordinal, not wall time.  One injector serves one pool run."""

    def __init__(self, schedule: list[ServerFault]):
        self.schedule = list(schedule)
        self.events: list[FaultEvent] = []
        self._calls: dict[int, int] = {}
        self._lock = threading.Lock()

    @classmethod
    def seeded(cls, seed: int, *, num_servers: int, num_faults: int = 1,
               max_call: int = 20, kinds: tuple = ("die",),
               delay_s: float = 0.0, transient_count: int = 2,
               ) -> "FaultInjector":
        """Derive a deterministic schedule from ``seed``: ``num_faults``
        distinct victim servers, each faulted at a random call ordinal in
        [1, max_call] with a random kind from ``kinds``."""
        if num_faults >= num_servers:
            raise ValueError(
                f"cannot fault {num_faults} of {num_servers} servers")
        rng = random.Random(seed)
        victims = rng.sample(range(num_servers), num_faults)
        schedule = [
            ServerFault(server=v, at_call=rng.randint(1, max_call),
                        kind=rng.choice(list(kinds)),
                        count=transient_count, delay_s=delay_s)
            for v in victims
        ]
        return cls(schedule)

    def hook_for(self, si: int):
        """The ``fault_hook`` closure for server ``si`` (runs on that
        server's thread at the top of every device-call attempt)."""
        faults = sorted((f for f in self.schedule if f.server == si),
                        key=lambda f: f.at_call)
        if not faults:
            return None

        def hook() -> None:
            with self._lock:
                call = self._calls.get(si, 0)
                self._calls[si] = call + 1
                live = [f for f in faults
                        if f.at_call <= call < f.at_call + f.count]
                for f in live:
                    self.events.append(FaultEvent(
                        si, call, f.kind, time.monotonic()))
            for f in live:
                if f.kind == "die":
                    raise DeviceLostError(
                        f"injected death on server {si} at call {call}")
                if f.kind == "stall":
                    time.sleep(f.delay_s)
                    raise DeviceLostError(
                        f"injected stall on server {si} at call {call}")
                if f.kind == "slow":
                    time.sleep(f.delay_s)
                elif f.kind == "transient":
                    raise TransientDeviceError(
                        f"injected transient error on server {si} "
                        f"at call {call}")

        return hook

    def attach(self, pool) -> None:
        """Install hooks into every scheduled server of ``pool``."""
        for si in range(len(pool.servers)):
            hook = self.hook_for(si)
            if hook is not None:
                pool.servers[si].fault_hook = hook
