"""Fault-tolerance runtime: checkpoint manager, failure detection, and the
restart/elastic policy glue.

At thousand-node scale the failure model is: a worker (host) stops
heartbeating -> the job controller declares it dead -> surviving workers
restart from the latest complete checkpoint, possibly on a SMALLER mesh
(elastic shrink of the data axis) until the replacement arrives.  The
pieces here implement that loop in-process (threads stand in for hosts);
the same interfaces drive the real multi-host deployment where heartbeats
arrive over RPC.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.training import checkpoint as ckpt


class CheckpointManager:
    """Wraps training.checkpoint with step-interval policy and async save.

    Async mode snapshots leaves to host (device_get) synchronously — the
    cheap part — and does file IO on a background thread so the train loop
    only stalls for the transfer, not the disk.
    """

    def __init__(self, root: str, *, interval: int = 100, keep_last: int = 3,
                 async_save: bool = True):
        self.root = root
        self.interval = interval
        self.keep_last = keep_last
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.interval:
            return False
        self.save(step, tree)
        return True

    def save(self, step: int, tree) -> None:
        self.wait()  # one in-flight save at a time
        if self.async_save:
            import jax
            import numpy as np
            host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            self._pending = threading.Thread(
                target=ckpt.save, args=(self.root, step, host_tree),
                kwargs=dict(keep_last=self.keep_last), daemon=True)
            self._pending.start()
        else:
            ckpt.save(self.root, step, tree, keep_last=self.keep_last)
        self.saved_steps.append(step)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, tree_like, *, shardings=None):
        self.wait()
        return ckpt.restore(self.root, tree_like, shardings=shardings)

    def latest_step(self):
        self.wait()
        return ckpt.latest_step(self.root)


@dataclass
class WorkerState:
    name: str
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    """Failure detector: workers call ``beat(name)``; a monitor thread marks
    a worker dead after ``timeout`` seconds of silence and fires
    ``on_failure(name)`` exactly once per transition.

    Usable as a context manager; after ``close()`` returns, ``on_failure``
    is guaranteed not to fire again — callbacks run under a dedicated lock
    that ``close()`` takes before setting the stop flag, so a close racing
    the monitor thread either waits out the in-flight callback or suppresses
    the pending one (the old code could fire into torn-down owners)."""

    def __init__(self, *, timeout: float = 1.0, poll: float = 0.1,
                 on_failure: Callable[[str], None] | None = None,
                 on_tick: Callable[[], None] | None = None):
        self.timeout = timeout
        self.poll = poll
        self.on_failure = on_failure
        # periodic hook, fired once per poll under the callback lock — the
        # serving engine piggybacks its work-stealing rebalance pass here
        # (same cadence and teardown guarantees as failure callbacks)
        self.on_tick = on_tick
        self.workers: dict[str, WorkerState] = {}
        self._lock = threading.Lock()
        self._cb_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def register(self, name: str) -> None:
        with self._lock:
            self.workers[name] = WorkerState(name, time.monotonic())

    def unregister(self, name: str) -> None:
        """Stop watching ``name`` (e.g. a server already declared dead by
        another path — no point re-reporting it)."""
        with self._lock:
            self.workers.pop(name, None)

    def beat(self, name: str) -> None:
        with self._lock:
            w = self.workers.get(name)
            if w is not None:
                w.last_beat = time.monotonic()
                w.alive = True

    def alive_workers(self) -> list[str]:
        with self._lock:
            return [w.name for w in self.workers.values() if w.alive]

    def _run(self) -> None:
        while not self._stop.wait(self.poll):
            now = time.monotonic()
            dead = []
            with self._lock:
                for w in self.workers.values():
                    if w.alive and now - w.last_beat > self.timeout:
                        w.alive = False
                        dead.append(w.name)
            for name in dead:
                with self._cb_lock:
                    if self._stop.is_set():
                        return  # closed mid-scan: suppress late callbacks
                    if self.on_failure:
                        self.on_failure(name)
            with self._cb_lock:
                if self._stop.is_set():
                    return
                if self.on_tick:
                    self.on_tick()

    def close(self) -> None:
        """Idempotent; once it returns, no further ``on_failure`` fires."""
        with self._cb_lock:
            self._stop.set()
        self._thread.join(timeout=2)

    def __enter__(self) -> "HeartbeatMonitor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TrainSupervisor:
    """Restart-from-checkpoint policy: wires the monitor to the manager.

    run_step is the application's step callable; on a detected failure the
    supervisor (1) notes the event, (2) calls ``rescale(alive)`` to get a
    new world size (elastic), (3) restores the latest checkpoint, and
    (4) resumes.  Used in-process by tests and examples; on real clusters
    the same object runs inside the controller process.
    """

    def __init__(self, manager: CheckpointManager,
                 rescale: Callable[[list[str]], None] | None = None):
        self.manager = manager
        self.rescale = rescale
        self.failures: list[str] = []
        self._failed = threading.Event()

    def on_failure(self, name: str) -> None:
        self.failures.append(name)
        self._failed.set()

    @property
    def failure_pending(self) -> bool:
        return self._failed.is_set()

    def recover(self, tree_like, alive: list[str], *, shardings=None):
        """Restore latest checkpoint (optionally on a reshaped mesh)."""
        if self.rescale is not None:
            self.rescale(alive)
        tree, step = self.manager.restore_latest(tree_like, shardings=shardings)
        self._failed.clear()
        return tree, step
