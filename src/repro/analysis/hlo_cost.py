"""HLO cost model over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` on the CPU (dry-run) backend
counts each while-loop BODY once, ignoring the trip count — a scanned
126-layer model reports ~1 layer of FLOPs.  The optimized HLO text carries
``backend_config={"known_trip_count":{"n":"126"}}`` on each while op, so we
walk the call graph ourselves and multiply.

What it produces (per-device, since the SPMD-partitioned module is
per-device):
  * flops            — 2*prod(result)*prod(contracted) per dot (+conv est.),
                       the standard MFU convention (elementwise excluded);
  * hbm_bytes        — post-fusion traffic model: every top-level
                       instruction reads its operands and writes its result
                       (fusions count only at their boundary); dynamic-slice
                       / dynamic-update-slice / gather count only the slice
                       actually touched (XLA performs them in place);
  * collective_bytes — per collective kind, result-shape bytes x trip
                       multiplier (async -start counted, -done skipped).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _array_bytes(type_str: str) -> int:
    """Sum bytes over every array in a (possibly tuple) type string."""
    total = 0
    for m in _ARRAY_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _array_elems_first(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


@dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    operands: list[str]
    raw: str
    attrs: str


@dataclass
class Computation:
    name: str
    instructions: list[Instruction] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # name -> type str


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\],{}\/ ]+?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    comment = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment.sub("", line)
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and "(" in stripped:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                continue
        else:
            if stripped == "}" or stripped.startswith("} "):
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                _, name, rtype, op, rest = m.groups()
                rtype = rtype.strip()
                # operands = %refs inside the top-level parens; attrs after
                depth = 1
                args_end = len(rest)
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            args_end = i
                            break
                args = rest[:args_end]
                attrs = rest[args_end + 1:]
                operands = _OPERAND.findall(args)
                cur.instructions.append(
                    Instruction(name, rtype, op, operands, line, attrs))
                cur.symbols[name] = rtype
    return comps, entry


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_counts: dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota", "reshape", "custom-call",
    "rng-bit-generator", "rng-get-and-update-state", "copy-start",
    "copy-done", "opt-barrier",
}


def _dot_flops(instr: Instruction, symbols: dict[str, str]) -> float:
    result = _array_elems_first(instr.result_type)
    if not result:
        return 0.0
    out_elems = 1
    for d in result[0][1]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    if not m or not instr.operands:
        return 2.0 * out_elems  # degenerate
    lhs_type = symbols.get(instr.operands[0], "")
    lhs = _array_elems_first(lhs_type)
    contracted = 1
    if lhs:
        dims = lhs[0][1]
        for ci in m.group(1).split(","):
            if ci and int(ci) < len(dims):
                contracted *= dims[int(ci)]
    return 2.0 * out_elems * contracted


def _conv_flops(instr: Instruction, symbols: dict[str, str]) -> float:
    result = _array_elems_first(instr.result_type)
    if not result or len(instr.operands) < 2:
        return 0.0
    out_elems = 1
    for d in result[0][1]:
        out_elems *= d
    kernel = _array_elems_first(symbols.get(instr.operands[1], ""))
    k_elems = 1
    if kernel:
        for d in kernel[0][1]:
            k_elems *= d
        # per-output flops ~ 2 * kernel_elems / out_features (rough)
        if kernel[0][1]:
            k_elems //= max(kernel[0][1][-1], 1)
    return 2.0 * out_elems * max(k_elems, 1)


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")

# pure dtype/layout plumbing: free inside a fusion on the TPU target (the
# CPU backend materializes f32 legalization copies around bf16 dots; a TPU
# compile fuses the conversion into the consumer)
_PASS_THROUGH = {"convert", "bitcast", "reshape", "copy", "reduce-precision"}


def _fusion_bytes(ins: Instruction, symbols: dict[str, str],
                  inner: "Computation") -> float:
    """HBM traffic of one fusion: each operand read once (sliced operands
    charged at slice size; in-place dynamic-update-slice targets charged
    zero), output written once (root DUS writes only the update).  Operand
    identity is resolved THROUGH convert/bitcast/reshape chains, so the CPU
    backend's bf16<->f32 legalization round-trips are not charged as
    full-buffer traffic (DESIGN.md hardware-adaptation note)."""
    # parameter index -> name inside the fused computation
    idx_to_name: dict[int, str] = {}
    by_name: dict[str, Instruction] = {}
    for fi in inner.instructions:
        by_name[fi.name] = fi
        if fi.op == "parameter":
            m = _PARAM_IDX.search(fi.raw)
            if m:
                idx_to_name[int(m.group(1))] = fi.name

    def resolve(name: str) -> str:
        """Follow pass-through ops up to the producing source."""
        seen = 0
        while name in by_name and by_name[name].op in _PASS_THROUGH \
                and by_name[name].operands and seen < 64:
            name = by_name[name].operands[0]
            seen += 1
        return name

    # usage map: source name -> consuming non-pass-through instructions
    uses: dict[str, list[Instruction]] = {}
    for fi in inner.instructions:
        if fi.op in _PASS_THROUGH or fi.op == "parameter":
            continue
        for o in fi.operands:
            src = resolve(o)
            uses.setdefault(src, []).append(fi)

    charged = 0.0
    for i, operand in enumerate(ins.operands):
        pname = idx_to_name.get(i)
        psize = _array_bytes(symbols.get(operand, ""))
        u = uses.get(pname, []) if pname else []
        if u and all(fi.op in ("dynamic-slice", "gather") for fi in u):
            charged += sum(min(_array_bytes(fi.result_type), psize) for fi in u)
        elif u and all(fi.op == "dynamic-update-slice" and fi.operands
                       and resolve(fi.operands[0]) == pname for fi in u):
            charged += 0.0  # in-place update target: aliased, not read
        else:
            charged += psize

    # output: resolve the ROOT through pass-through wrappers
    root = inner.instructions[-1] if inner.instructions else None
    if root is not None:
        rname = resolve(root.name)
        rins = by_name.get(rname)
        if rins is not None and rins.op == "dynamic-update-slice" \
                and len(rins.operands) > 1:
            charged += _array_bytes(inner.symbols.get(
                resolve(rins.operands[1]), inner.symbols.get(rins.operands[1], "")))
            return charged
    charged += _array_bytes(ins.result_type)
    return charged


def _instr_bytes(instr: Instruction, symbols: dict[str, str]) -> float:
    op = instr.op
    out_b = _array_bytes(instr.result_type)
    if op == "dynamic-slice":
        return 2.0 * out_b
    if op == "dynamic-update-slice":
        upd = _array_bytes(symbols.get(instr.operands[1], "")) if len(
            instr.operands) > 1 else 0
        return 2.0 * upd
    if op == "gather":
        idx = _array_bytes(symbols.get(instr.operands[1], "")) if len(
            instr.operands) > 1 else 0
        return 2.0 * out_b + idx
    if op == "scatter":
        upd = _array_bytes(symbols.get(instr.operands[-1], ""))
        return 3.0 * upd + out_b * 0  # read-modify-write of touched slices
    in_b = sum(_array_bytes(symbols.get(o, "")) for o in instr.operands)
    return in_b + out_b


def _src_itemsize(name: str, by_name: dict[str, Instruction],
                  comps: dict[str, Computation], depth: int = 0) -> int | None:
    """Itemsize of the ultimate data source of ``name``, following top-level
    convert/bitcast/reshape/copy chains and convert-only fusions (the CPU
    backend's f32 legalization of bf16 payloads — a TPU compile ships the
    narrow dtype on the wire)."""
    if depth > 16 or name not in by_name:
        return None
    ins = by_name[name]
    if ins.op in _PASS_THROUGH and ins.operands:
        return _src_itemsize(ins.operands[0], by_name, comps, depth + 1)
    if ins.op == "fusion":
        m = _CALLS.search(ins.raw)
        if m and m.group(1) in comps:
            inner = comps[m.group(1)]
            body_ops = {fi.op for fi in inner.instructions}
            if body_ops <= (_PASS_THROUGH | {"parameter"}):
                if ins.operands:
                    return _src_itemsize(ins.operands[0], by_name, comps,
                                         depth + 1)
    arrays = _array_elems_first(ins.result_type)
    if arrays:
        return _DTYPE_BYTES.get(arrays[0][0])
    return None


def _walk(comp: Computation, comps: dict[str, Computation], mult: float,
          acc: Cost, visited_stack: tuple = ()) -> None:
    if comp.name in visited_stack:  # defensive: no recursion in HLO anyway
        return
    by_name = {i.name: i for i in comp.instructions}
    for ins in comp.instructions:
        base = ins.op.replace("-start", "")
        if ins.op.endswith("-done"):
            continue
        if base in COLLECTIVES:
            b = _array_bytes(ins.result_type)
            # dtype-normalize: charge at the source payload's itemsize when
            # the operand is a legalization upcast of a narrower dtype
            arrays = _array_elems_first(ins.result_type)
            if arrays and ins.operands:
                res_item = _DTYPE_BYTES.get(arrays[0][0])
                src_item = _src_itemsize(ins.operands[0], by_name, comps)
                if res_item and src_item and src_item < res_item:
                    b = b * src_item / res_item
            acc.collective_bytes[base] += b * mult
            acc.collective_counts[base] += mult
            acc.hbm_bytes += 2.0 * b * mult  # payload read + write
            continue
        if ins.op == "while":
            trip = 1.0
            m = _TRIP.search(ins.raw)
            if m:
                trip = float(m.group(1))
            body = _CALLS.search(ins.attrs or ins.raw)
            if body and body.group(1) in comps:
                _walk(comps[body.group(1)], comps, mult * trip, acc,
                      (*visited_stack, comp.name))
            cond = _COND.search(ins.raw)
            if cond and cond.group(1) in comps:
                _walk(comps[cond.group(1)], comps, mult * trip, acc,
                      (*visited_stack, comp.name))
            continue
        if ins.op == "conditional":
            m = _BRANCHES.search(ins.raw)
            if m:
                for b in _OPERAND.findall(m.group(1)):
                    if b in comps:
                        _walk(comps[b], comps, mult, acc,
                              (*visited_stack, comp.name))
            continue
        if ins.op == "call":
            m = _CALLS.search(ins.raw)
            if m and m.group(1) in comps:
                _walk(comps[m.group(1)], comps, mult, acc,
                      (*visited_stack, comp.name))
            continue
        if ins.op == "fusion":
            m = _CALLS.search(ins.raw)
            if m and m.group(1) in comps:
                inner = comps[m.group(1)]
                for fi in inner.instructions:
                    if fi.op == "dot":
                        acc.flops += _dot_flops(fi, inner.symbols) * mult
                    elif fi.op == "convolution":
                        acc.flops += _conv_flops(fi, inner.symbols) * mult
                acc.hbm_bytes += _fusion_bytes(ins, comp.symbols, inner) * mult
            else:
                acc.hbm_bytes += _instr_bytes(ins, comp.symbols) * mult
            continue
        if ins.op == "dot":
            acc.flops += _dot_flops(ins, comp.symbols) * mult
            acc.hbm_bytes += _instr_bytes(ins, comp.symbols) * mult
            continue
        if ins.op == "convolution":
            acc.flops += _conv_flops(ins, comp.symbols) * mult
            acc.hbm_bytes += _instr_bytes(ins, comp.symbols) * mult
            continue
        if ins.op in _SKIP_BYTES:
            continue
        acc.hbm_bytes += _instr_bytes(ins, comp.symbols) * mult
    return


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: newer
    releases return the properties dict directly, 0.4.x wraps it in a
    one-element list (one entry per executable module)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def analyze_text(text: str) -> Cost:
    comps, entry = parse_module(text)
    acc = Cost()
    if entry and entry in comps:
        _walk(comps[entry], comps, 1.0, acc)
    return acc


def top_contributors(text: str, k: int = 12) -> list[tuple[float, float, str, str, str]]:
    """(bytes, mult, op, name, result_type) of the k largest HBM contributors
    — the §Perf diagnosis tool."""
    comps, entry = parse_module(text)
    tops: list[tuple[float, float, str, str, str]] = []

    def walk(comp: Computation, mult: float) -> None:
        for ins in comp.instructions:
            base = ins.op.replace("-start", "")
            if ins.op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                b = 2.0 * _array_bytes(ins.result_type) * mult
                tops.append((b, mult, base, ins.name, ins.result_type[:60]))
                continue
            if ins.op == "while":
                m = _TRIP.search(ins.raw)
                trip = float(m.group(1)) if m else 1.0
                b = _CALLS.search(ins.attrs or ins.raw)
                if b and b.group(1) in comps:
                    walk(comps[b.group(1)], mult * trip)
                continue
            if ins.op in ("conditional", "call"):
                m = _CALLS.search(ins.raw)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult)
                continue
            if ins.op == "fusion":
                m = _CALLS.search(ins.raw)
                if m and m.group(1) in comps:
                    b = _fusion_bytes(ins, comp.symbols, comps[m.group(1)]) * mult
                else:
                    b = _instr_bytes(ins, comp.symbols) * mult
                tops.append((b, mult, ins.op, ins.name, ins.result_type[:60]))
                continue
            if ins.op in _SKIP_BYTES:
                continue
            tops.append((_instr_bytes(ins, comp.symbols) * mult, mult, ins.op,
                         ins.name, ins.result_type[:60]))

    if entry in comps:
        walk(comps[entry], 1.0)
    tops.sort(key=lambda t: -t[0])
    return tops[:k]
