"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Terms (per step, per chip), TPU v5e constants:

    compute_ms    = HLO_FLOPs   / (chips * 197e12 FLOP/s)  * 1e3
    memory_ms     = HLO_bytes   / (chips * 819e9  B/s)     * 1e3
    collective_ms = coll_bytes  / (chips * 50e9   B/s/link)* 1e3

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the optimized HLO text: the summed operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (cost_analysis does not expose it).

roofline_fraction = compute_ms / max(compute_ms, memory_ms, collective_ms):
how close the step is to being compute-bound at peak — the number reported
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12  # bf16 FLOP/s per v5e chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link (~per chip, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,256]' -> bytes; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    HLO line form:  %name = bf16[...]{...} all-gather(...), replica_groups=...
    We count the op's RESULT shape (for all-gather that's the gathered size,
    for reduce-scatter the scattered size; a consistent, conservative proxy
    for wire bytes per participating device).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k + "_count": 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]+?)\s+(\w[\w\-]*)\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        # async collective pairs: count -start only, skip -done
        base = op.replace("-start", "")
        if op.endswith("-done") or base not in _COLLECTIVES:
            continue
        out[base] += _shape_bytes(shape_str)
        counts[base + "_count"] += 1
    out.update(counts)
    return out


@dataclass
class RooflineTerms:
    compute_ms: float
    memory_ms: float
    collective_ms: float
    bottleneck: str
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    model_flops_ratio: float  # MODEL_FLOPS / HLO_FLOPS (useful-compute share)
    roofline_fraction: float
    per_collective: dict

    def to_dict(self):
        return asdict(self)


def analyze(cost: dict, hlo_text: str, *, chips: int, model_flops: float,
            flops_are_global: bool = True) -> RooflineTerms:
    """cost: compiled.cost_analysis() (kept for reference only); hlo_text:
    the compiled (SPMD-partitioned, per-device) module text.

    The terms come from analysis.hlo_cost, which walks the call graph and
    multiplies while-loop bodies by their known_trip_count —
    cost_analysis() counts scanned layer stacks once and is unusable for a
    scanned 126-layer model (verified; see tests/test_roofline.py).
    """
    from repro.analysis import hlo_cost

    walked = hlo_cost.analyze_text(hlo_text)
    flops = walked.flops
    bytes_ = walked.hbm_bytes
    per_coll = {**walked.collective_bytes,
                **{k + "_count": v for k, v in walked.collective_counts.items()}}
    coll = walked.total_collective_bytes

    compute_ms = flops / PEAK_FLOPS * 1e3
    memory_ms = bytes_ / HBM_BW * 1e3
    collective_ms = coll / ICI_BW * 1e3
    terms = {"compute": compute_ms, "memory": memory_ms, "collective": collective_ms}
    bottleneck = max(terms, key=terms.get)
    mf_per_chip = model_flops / chips
    return RooflineTerms(
        compute_ms=compute_ms,
        memory_ms=memory_ms,
        collective_ms=collective_ms,
        bottleneck=bottleneck,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        coll_bytes=coll,
        model_flops=mf_per_chip,
        model_flops_ratio=(mf_per_chip / flops) if flops else 0.0,
        roofline_fraction=(compute_ms / max(max(terms.values()), 1e-12)),
        per_collective=per_coll,
    )


def train_model_flops(n_params: int, tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) — pass active params for MoE."""
    return 6.0 * n_params * tokens


def decode_model_flops(n_params: int, batch: int) -> float:
    """One decode token per sequence: 2*N FLOPs each (fwd only)."""
    return 2.0 * n_params * batch


def prefill_model_flops(n_params: int, tokens: int) -> float:
    return 2.0 * n_params * tokens
