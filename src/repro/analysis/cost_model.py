"""Calibrated step-cost model: measured batch_meta cells -> priced shapes.

The serving stack buckets every device call into a shape CELL —
``("decode", padded_rows, table_width)`` or ``("prefill", padded_rows,
len_bucket)``, the post-bucketing shape that names the jit trace
(``core.server_runtime.cell_key``) — and the dispatcher reports each call's
cell plus its timed duration into running per-cell aggregates
(``ServerStats.cell_stats``, merged pool-wide by ``ServerPool.cell_stats``).

This module closes the measurement -> admission loop on those aggregates,
PPT-style (hybrid analytic/empirical: an analytic surface calibrated
against a few measured points prices unseen shapes):

  * ``StepCostModel.ingest`` loads measured cells; ``fit`` solves a
    per-phase non-negative least-squares surface over roofline-shaped
    features — an intercept (the per-call dispatch overhead, the runtime
    analogue of the paper's eps), a ``rows`` term (per-row compute +
    parameter traffic: the compute_ms axis), and a ``rows*width`` term (KV
    bytes gathered: the memory_ms axis).  The fitted coefficients are the
    ACHIEVED per-unit rates, where ``analysis.roofline`` uses peak-hardware
    constants; ``roofline_features`` swaps in statically priced
    (flops, bytes) per cell — e.g. from ``hlo_cost.analyze_text`` — so the
    coefficients become dimensionless efficiency factors.
  * ``predict`` prices any cell: the measured mean where the cell was
    observed, the fitted surface elsewhere (interpolation via the roofline
    terms).  ``error_report`` tracks surface-vs-measured relative error per
    cell — the BENCH_cost_model.json artifact.
  * Three consumers feed back:
      (a) ``recost`` re-prices a task's GPU segments at
          ``min(declared, predicted)`` for the cell it actually runs in —
          calibrated admission (``core.admission`` with ``cost_model=``)
          admits a superset of the worst-case-declared sets by
          construction, and the per-server bounds stay sound because the
          analysis and the simulated execution use the same calibrated
          costs (Eqs (1)-(6) are monotone in segment costs).
      (b) ``autotune_buckets`` picks the pow2 bucket boundaries minimizing
          predicted padding waste for an observed length distribution
          (``ServeEngine.tune_buckets``).
      (c) ``TrafficModel`` names the cells a workload will actually hit so
          ``ServeEngine.precompile(traffic=...)`` warms only those.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

from repro.core.server_runtime import CellStats, cell_key

__all__ = [
    "CellKey",
    "StepCostModel",
    "TrafficModel",
    "autotune_buckets",
    "bucket_up",
    "hlo_cell_features",
    "roofline_features",
]

CellKey = tuple  # (phase, rows, width_or_bucket), pow2-bucketed values


def bucket_up(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n; the largest bucket when none covers (callers
    guarantee the largest bucket covers every legal n)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def _default_work(cell: CellKey) -> tuple[float, float]:
    """Roofline-shaped features of a cell: (rows, rows*width).

    ``rows`` scales the per-row compute + parameter-read term (every row
    reads the full weight stack once per step on the compute_ms axis);
    ``rows*width`` scales the KV-gather traffic (bytes grow with the block
    table's live width on the memory_ms axis).  The fit's coefficients are
    then the achieved seconds-per-row and seconds-per-block-row."""
    _, rows, width = cell
    return (float(rows), float(rows) * float(width))


def roofline_features(flops_of: Callable[[CellKey], float],
                      bytes_of: Callable[[CellKey], float]):
    """Build a ``work`` callable from static per-cell pricing — e.g.
    ``hlo_cost.analyze_text`` FLOPs/bytes of the cell's trace — normalized
    by the peak-rate constants so the fitted coefficients are dimensionless
    achieved-fraction-of-peak factors (the roofline interpolation input)."""
    from repro.analysis import roofline

    def work(cell: CellKey) -> tuple[float, float]:
        return (flops_of(cell) / roofline.PEAK_FLOPS,
                bytes_of(cell) / roofline.HBM_BW)

    return work


def hlo_cell_features(costs: Mapping[CellKey, tuple[float, float]]):
    """Build a ``work`` callable from static HLO per-cell pricing.

    ``costs`` maps CellKey -> (flops, hbm_bytes) — e.g.
    ``ServeEngine.static_cell_costs``, which compiles each cell's trace and
    walks the optimized HLO with ``analysis.hlo_cost``.  Listed cells get
    their EXACT normalized roofline features; an unlisted cell of a listed
    phase is extrapolated from a per-phase least-squares fit of the listed
    cells' flops/bytes over the default ``[1, rows, rows*width]`` basis —
    so a migration or scatter width never observed at runtime still prices
    off static analysis instead of falling to ``inf``/declared worst case.
    Phases with no static pricing at all keep the default
    ``(rows, rows*width)`` analytic features, making this a strict
    refinement of ``_default_work``."""
    by_phase: dict[str, list[CellKey]] = {}
    for cell in costs:
        by_phase.setdefault(cell[0], []).append(cell)
    fits: dict[str, tuple[np.ndarray, np.ndarray]] = {}
    for phase, keys in by_phase.items():
        X = np.array([[1.0, k[1], float(k[1]) * float(k[2])] for k in keys])
        fl = np.array([costs[k][0] for k in keys])
        by = np.array([costs[k][1] for k in keys])
        tf, *_ = np.linalg.lstsq(X, fl, rcond=None)
        tb, *_ = np.linalg.lstsq(X, by, rcond=None)
        fits[phase] = (tf, tb)

    def _static(cell: CellKey) -> tuple[float, float] | None:
        got = costs.get(cell)
        if got is not None:
            return got
        fit = fits.get(cell[0])
        if fit is None:
            return None
        x = np.array([1.0, cell[1], float(cell[1]) * float(cell[2])])
        return (max(float(fit[0] @ x), 0.0), max(float(fit[1] @ x), 0.0))

    normalized = roofline_features(lambda c: _static(c)[0],
                                   lambda c: _static(c)[1])

    def work(cell: CellKey) -> tuple[float, float]:
        if _static(cell) is None:
            return _default_work(cell)
        return normalized(cell)

    return work


def _nnls(X: np.ndarray, y: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Weighted least squares with non-negative coefficients (single-pass
    active set: solve, clamp negatives to zero, re-solve the rest).  A
    negative cost coefficient is nonphysical — cells cannot get cheaper as
    they grow — and would break the monotonicity calibrated admission
    leans on."""
    sw = np.sqrt(w)[:, None]
    active = list(range(X.shape[1]))
    theta = np.zeros(X.shape[1])
    for _ in range(X.shape[1]):
        sol, *_ = np.linalg.lstsq(X[:, active] * sw, y * sw[:, 0],
                                  rcond=None)
        if (sol >= 0).all() or len(active) == 1:
            sol = np.maximum(sol, 0.0)
            for i, a in enumerate(active):
                theta[a] = sol[i]
            return theta
        active = [a for a, s in zip(active, sol) if s >= 0]
    return theta


@dataclass
class _PhaseFit:
    theta: np.ndarray  # (1 + n_features,): intercept first
    n_cells: int


@dataclass
class StepCostModel:
    """Per-cell step-cost surfaces fit from measured device calls.

    ``work`` maps a cell to its analytic feature vector (default: the
    (rows, rows*width) roofline axes; see ``roofline_features`` for
    statically priced variants).  ``safety`` scales predictions used for
    admission recosting — a calibration margin over the measured mean."""

    work: Callable[[CellKey], Sequence[float]] = _default_work
    safety: float = 1.2
    cells: dict = field(default_factory=dict)  # CellKey -> CellStats
    _fits: dict = field(default_factory=dict)  # phase -> _PhaseFit

    # -- measurement intake ------------------------------------------------
    def observe(self, cell: CellKey, seconds: float, *,
                rows: int | None = None) -> None:
        """Add one timed call of ``cell`` (bench-run intake path)."""
        stats = self.cells.get(cell)
        if stats is None:
            stats = self.cells[cell] = CellStats()
        stats.add({"rows": rows if rows is not None else cell[1],
                   "seconds": seconds})
        self._fits.clear()

    def ingest(self, source) -> int:
        """Load measurements from ``ServerPool.cell_stats()`` /
        ``ServerStats.cell_stats`` (a mapping of CellKey -> CellStats) or
        from an iterable of raw ``batch_meta`` dicts carrying ``seconds``.
        Returns the number of cells updated."""
        n = 0
        if isinstance(source, Mapping):
            for key, stats in source.items():
                if not stats.timed:
                    continue
                mine = self.cells.get(key)
                if mine is None:
                    mine = self.cells[key] = CellStats()
                mine.merge(stats)
                n += 1
        else:
            touched = set()
            for meta in source:
                key = cell_key(meta)
                if key is None or meta.get("seconds") is None:
                    continue
                mine = self.cells.get(key)
                if mine is None:
                    mine = self.cells[key] = CellStats()
                mine.add(meta)
                touched.add(key)
            n = len(touched)
        if n:
            self._fits.clear()
        return n

    # -- fitting -----------------------------------------------------------
    def fit(self) -> dict:
        """Fit one non-negative least-squares surface per phase over the
        measured cell means, weighted by sample count.  Returns
        {phase: intercept-first coefficient list}."""
        by_phase: dict[str, list[CellKey]] = {}
        for key, stats in self.cells.items():
            if stats.timed:
                by_phase.setdefault(key[0], []).append(key)
        self._fits.clear()
        for phase, keys in by_phase.items():
            X = np.array([[1.0, *self.work(k)] for k in keys])
            y = np.array([self.cells[k].mean_s for k in keys])
            w = np.array([float(self.cells[k].timed) for k in keys])
            self._fits[phase] = _PhaseFit(_nnls(X, y, w), len(keys))
        return {p: f.theta.tolist() for p, f in self._fits.items()}

    def _surface(self, cell: CellKey) -> float:
        if not self._fits:
            self.fit()
        f = self._fits.get(cell[0])
        if f is None:
            return math.inf  # unmeasured phase: calibration cannot price it
        return float(f.theta @ np.array([1.0, *self.work(cell)]))

    # -- pricing -----------------------------------------------------------
    def predict(self, phase: str, rows: int, width: int, *,
                surface_only: bool = False) -> float:
        """Predicted step cost of one cell in SECONDS: the measured mean
        where the cell was observed, the fitted surface elsewhere
        (roofline-feature interpolation).  ``inf`` when the model has no
        data for the phase at all — callers degrade to their declared
        worst case, so an empty model is exactly the uncalibrated mode."""
        cell = (phase, rows, width)
        stats = self.cells.get(cell)
        if stats is not None and stats.timed and not surface_only:
            return stats.mean_s
        return self._surface(cell)

    def dispatch_overhead_s(self, phase: str = "decode") -> float:
        """The fitted intercept: per-device-call cost at zero work — the
        measured analogue of the paper's server overhead eps."""
        if not self._fits:
            self.fit()
        f = self._fits.get(phase)
        return float(f.theta[0]) if f is not None else math.inf

    # -- admission feedback ------------------------------------------------
    def recost(self, task, cells) -> "object":
        """Re-price a task's GPU segments at ``min(declared,
        safety * predict(cell))`` — the calibrated-admission input.

        ``cells`` is one CellKey applied to every segment, or a sequence of
        eta_i keys (``None`` entries keep that segment's declared cost).
        The min() keeps each calibrated cost <= the declared worst case, so
        every task set admitted under declared costs is admitted under
        calibrated costs (the analysis is monotone in segment costs), and
        the bound stays sound as long as real calls run within the
        calibrated cost — which the safety margin over the measured mean
        plus the error report are there to police."""
        if not task.segments:
            return task
        if cells is None or isinstance(cells, tuple) and cells and \
                isinstance(cells[0], str):
            cells = [cells] * len(task.segments)
        if len(cells) != len(task.segments):
            raise ValueError(
                f"{task.name}: {len(cells)} cells for {task.eta} segments")
        segs = []
        for seg, cell in zip(task.segments, cells):
            if cell is None:
                segs.append(seg)
                continue
            pred_ms = self.predict(*cell) * self.safety * 1e3
            if not pred_ms < seg.total:  # inf or no improvement: declared
                segs.append(seg)
                continue
            scale = pred_ms / seg.total
            segs.append(replace(seg, e=seg.e * scale, m=seg.m * scale))
        return replace(task, segments=tuple(segs))

    # -- tracking ----------------------------------------------------------
    def error_report(self) -> dict:
        """Surface-vs-measured relative error per measured cell (the
        tracked predicted-vs-measured artifact).  The surface is used even
        for measured cells here — this scores the interpolator that prices
        UNSEEN cells, not the lookup table."""
        rows = []
        errs = []
        for key in sorted(self.cells):
            stats = self.cells[key]
            if not stats.timed:
                continue
            pred = self._surface(key)
            rel = (abs(pred - stats.mean_s) / stats.mean_s
                   if stats.mean_s > 0 else math.inf)
            errs.append(rel)
            rows.append({
                "cell": list(key), "calls": stats.calls,
                "timed": stats.timed, "measured_s": stats.mean_s,
                "std_s": math.sqrt(stats.var_s), "predicted_s": pred,
                "rel_err": rel,
            })
        errs.sort()
        median = errs[len(errs) // 2] if errs else math.inf
        return {"cells": rows, "n_cells": len(rows),
                "median_rel_err": median,
                "coeffs": {p: f.theta.tolist()
                           for p, f in self._fits.items()}}


class TrafficModel:
    """Which cells will traffic actually hit?  Fitted from the observed
    per-cell call counts; ``hot_cells`` names every cell carrying at least
    ``min_share`` of a phase's calls — the precompile planner's input
    (``ServeEngine.precompile(traffic=...)``)."""

    def __init__(self, counts: Mapping[CellKey, int]):
        self.counts = {k: int(v) for k, v in counts.items() if v > 0}

    @classmethod
    def from_stats(cls, cell_stats: Mapping[CellKey, CellStats]
                   ) -> "TrafficModel":
        return cls({k: s.calls for k, s in cell_stats.items()})

    def hot_cells(self, *, min_share: float = 0.0) -> set:
        phase_total: dict[str, int] = {}
        for key, n in self.counts.items():
            phase_total[key[0]] = phase_total.get(key[0], 0) + n
        return {key for key, n in self.counts.items()
                if n >= min_share * phase_total[key[0]]}


def autotune_buckets(values: Iterable[int], candidates: Sequence[int], *,
                     max_buckets: int,
                     cost_of: Callable[[int, int], float] | None = None,
                     ) -> tuple[int, ...]:
    """Pick <= ``max_buckets`` bucket boundaries from ``candidates`` (the
    pow2 ladder — trace shapes must stay pow2-bucketed) minimizing the
    total bucketing cost of the observed ``values`` distribution.

    ``cost_of(bucket, value)`` prices one value landing in ``bucket``
    (default: the padding waste ``bucket - value``; pass a closure over
    ``StepCostModel.predict`` to price in predicted seconds instead).  The
    largest candidate is always kept so every legal value stays covered —
    dropping the cover would re-route work to a trace that cannot hold it.
    Exact DP over (candidate index, buckets used): candidates and
    max_buckets are O(log) sized, so the cubic scan is trivial."""
    vals = sorted(int(v) for v in values)
    cands = sorted(set(int(c) for c in candidates))
    if not cands:
        raise ValueError("no bucket candidates")
    if vals and vals[-1] > cands[-1]:
        raise ValueError(f"value {vals[-1]} exceeds the largest candidate "
                         f"{cands[-1]} (no bucket could cover it)")
    if cost_of is None:
        cost_of = lambda bucket, value: float(bucket - value)  # noqa: E731
    if not vals:
        return (cands[-1],)
    max_buckets = max(1, min(max_buckets, len(cands)))

    # seg_cost[i][j]: cost of values in (cands[i-1], cands[j]] all landing
    # in bucket cands[j]  (i.e. cands[j] is the next boundary above cands[i-1])
    n = len(cands)
    import bisect

    def seg_cost(lo_idx: int, j: int) -> float:
        lo = cands[lo_idx - 1] if lo_idx > 0 else 0
        a = bisect.bisect_right(vals, lo)
        b = bisect.bisect_right(vals, cands[j])
        return sum(cost_of(cands[j], v) for v in vals[a:b])

    INF = math.inf
    # best[j][k]: min cost covering all values <= cands[j] using k buckets,
    # with cands[j] the largest chosen so far
    best = [[INF] * (max_buckets + 1) for _ in range(n)]
    back: dict[tuple[int, int], tuple[int, int] | None] = {}
    for j in range(n):
        best[j][1] = seg_cost(0, j)
        back[(j, 1)] = None
    for k in range(2, max_buckets + 1):
        for j in range(n):
            for i in range(j):
                if best[i][k - 1] is INF:
                    continue
                c = best[i][k - 1] + seg_cost(i + 1, j)
                if c < best[j][k]:
                    best[j][k] = c
                    back[(j, k)] = (i, k - 1)
    # the cover constraint: the last candidate must be chosen
    j = n - 1
    k = min(range(1, max_buckets + 1), key=lambda kk: best[j][kk])
    chosen = []
    cur: tuple[int, int] | None = (j, k)
    while cur is not None:
        chosen.append(cands[cur[0]])
        cur = back[cur]
    return tuple(sorted(chosen))
