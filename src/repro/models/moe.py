"""Mixture-of-Experts layer.

Three execution paths, all numerically equivalent (up to capacity drops):

  * dense      — every expert runs on every token, combined by routing
                 weights.  Exact (dropless); used for CPU smoke tests and as
                 the reference oracle for the distributed paths.
  * ep_a2a     — expert parallelism over the 'model' mesh axis via
                 shard_map: tokens are dispatched into per-expert capacity
                 buffers locally, exchanged with a single all_to_all,
                 computed on the expert-owning shard, and returned with a
                 second all_to_all.  Used for train/prefill (seq divisible
                 by the model axis).
  * ep_replicated — tokens replicated over the model axis; each shard
                 computes only its local experts and partial outputs are
                 psum-combined.  Used for decode (seq length 1).

Routing: top-k over softmax(router logits), renormalized over the selected
experts (DeepSeek/Qwen convention), plus the standard load-balance auxiliary
loss.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.layers import Params, dense_init, mlp


def init_moe(cfg, key, dtype) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "experts": {
            "w_gate": dense_init(ks[1], (e, d, f), dtype, scale=1.0 / math.sqrt(d)),
            "w_up": dense_init(ks[2], (e, d, f), dtype, scale=1.0 / math.sqrt(d)),
            "w_down": dense_init(ks[3], (e, f, d), dtype, scale=1.0 / math.sqrt(f)),
        },
    }
    if cfg.num_shared_experts:
        shared_f = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, shared_f), dtype),
            "w_up": dense_init(jax.random.fold_in(ks[4], 1), (d, shared_f), dtype),
            "w_down": dense_init(jax.random.fold_in(ks[4], 2), (shared_f, d), dtype),
        }
    return p


def router_topk(cfg, p: Params, x):
    """x (T, D) -> (idx (T,K), weights (T,K), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.num_experts_per_tok
    top_p, idx = jax.lax.top_k(probs, k)
    weights = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    e = cfg.num_experts
    occupancy = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f_e = occupancy / idx.size
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return idx, weights.astype(x.dtype), aux


def _expert_ffn(experts: Params, h):
    """h (E, C, D) -> (E, C, D), batched swiglu over experts."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, experts["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", h, experts["w_up"])
    return jnp.einsum("ecf,efd->ecd", g * u, experts["w_down"])


def _dispatch(tokens, idx, weights, e: int, capacity: int):
    """Scatter tokens into per-expert capacity buffers.

    tokens (T, D); idx/weights (T, K).  Returns (buf (E*C, D), slot (T*K,),
    keep (T*K,)).  Slot assignment is in token order (first-come
    first-served within each expert), overflow tokens are dropped.
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)  # (T*K,)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(oh, axis=0) - 1  # running count per expert
    safe_e = jnp.minimum(flat_e, e - 1)
    pos_in_e = jnp.take_along_axis(pos, safe_e[:, None], axis=1)[:, 0]
    # flat_e may carry the sentinel value `e` (non-local expert): always drop
    keep = (pos_in_e < capacity) & (flat_e < e)
    slot = jnp.where(keep, flat_e * capacity + pos_in_e, e * capacity)
    src = jnp.repeat(tokens, k, axis=0)  # (T*K, D)
    buf = jnp.zeros((e * capacity + 1, tokens.shape[-1]), tokens.dtype)
    buf = buf.at[slot].add(src * keep[:, None].astype(tokens.dtype))
    return buf[:-1], slot, keep


def _combine(buf_out, slot, keep, weights, t: int, k: int):
    """Gather expert outputs back to tokens and mix with routing weights."""
    d = buf_out.shape[-1]
    padded = jnp.concatenate([buf_out, jnp.zeros((1, d), buf_out.dtype)], axis=0)
    safe_slot = jnp.where(keep, slot, buf_out.shape[0])
    y = padded[safe_slot]  # (T*K, D)
    y = y.reshape(t, k, d) * weights[..., None]
    return jnp.sum(y, axis=1)


def moe_dense(cfg, p: Params, x):
    """Reference path: all experts on all tokens (exact, dropless)."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    idx, weights, aux = router_topk(cfg, p, tokens)
    # (E, T, D): every expert everywhere
    g = jax.nn.silu(jnp.einsum("td,edf->etf", tokens, p["experts"]["w_gate"]))
    u = jnp.einsum("td,edf->etf", tokens, p["experts"]["w_up"])
    y_all = jnp.einsum("etf,efd->etd", g * u, p["experts"]["w_down"])
    combine = jnp.zeros((tokens.shape[0], cfg.num_experts), x.dtype)
    tk = jnp.arange(tokens.shape[0])[:, None]
    combine = combine.at[tk, idx].add(weights)
    out = jnp.einsum("te,etd->td", combine, y_all)
    return out.reshape(b, s, d), aux


def _moe_local(cfg, router, experts, tokens, *, capacity: int, e_local: int,
               axis: str | None):
    """Per-shard MoE body (runs inside shard_map, or standalone if axis None
    with e_local == num_experts)."""
    t, d = tokens.shape
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    idx, weights, aux = router_topk(cfg, {"router": router}, tokens)
    buf, slot, keep = _dispatch(tokens, idx, weights, e, capacity)

    if axis is not None:
        n = jax.lax.psum(1, axis)
        # (E, C, D) -> exchange so each shard holds its local experts' tokens
        buf = buf.reshape(e, capacity, d)
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1, tiled=True)
        # (E_local, n*C, D)
        y = _expert_ffn(experts, buf)
        y = jax.lax.all_to_all(y, axis, split_axis=1, concat_axis=0, tiled=True)
        y = y.reshape(e * capacity, d)
    else:
        y = _expert_ffn(experts, buf.reshape(e, capacity, d)).reshape(e * capacity, d)

    out = _combine(y, slot, keep, weights, t, k)
    return out, aux


def _moe_replicated_body(cfg, router, experts, tokens, *, capacity: int, axis: str):
    """Decode path: tokens replicated over the model axis; each shard runs
    its local experts only and partial results are psum-combined."""
    t, d = tokens.shape
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    n = jax.lax.psum(1, axis)
    e_local = e // n
    shard = jax.lax.axis_index(axis)
    idx, weights, aux = router_topk(cfg, {"router": router}, tokens)
    # mask to experts owned by this shard, re-indexed locally
    local = (idx // e_local) == shard
    local_idx = jnp.where(local, idx % e_local, e_local)  # e_local = drop
    w_local = jnp.where(local, weights, 0.0)
    buf, slot, keep = _dispatch(tokens, local_idx, w_local, e_local, capacity)
    y = _expert_ffn(experts, buf.reshape(e_local, capacity, d)).reshape(-1, d)
    out = _combine(y, slot, keep, w_local, t, k)
    return jax.lax.psum(out, axis), aux


def _moe_decode_tpdata(cfg, rules, p: Params, x):
    """§Perf decode path: expert FFN width sharded over the DP axes.

    Instead of FSDP-gathering ~GBs of expert weights per layer to process a
    few hundred tokens, gather the TOKENS (all_gather over DP: ~MBs),
    compute each (expert-shard x FFN-slice) locally, and combine with
    psum over the model axis (expert partials) + psum_scatter over the DP
    axes (FFN partials + return each shard its own batch slice)."""
    import math as _math

    mesh, axis = rules.mesh, rules.model_axis
    batch = rules.batch()
    n_model = mesh.shape[axis]
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    e_local = e // n_model
    b, s, d = x.shape
    t_all = b * s
    capacity = max(int(_math.ceil(t_all * k / e * cfg.capacity_factor)), 4)

    def body(router, wg, wu, wd, xx):
        xl = xx.reshape(-1, d)
        if rules.shard_batch:
            # tokens sharded over DP: gather them (MBs, vs GBs of weights)
            xa = jax.lax.all_gather(xl, rules.batch_axes, axis=0, tiled=True)
        else:
            xa = xl  # serve_2d: tokens already replicated over DP
        idx, weights, aux = router_topk(cfg, {"router": router}, xa)
        shard = jax.lax.axis_index(axis)
        local = (idx // e_local) == shard
        local_idx = jnp.where(local, idx % e_local, e_local)
        w_local = jnp.where(local, weights, 0.0)
        buf, slot, keep = _dispatch(xa, local_idx, w_local, e_local, capacity)
        hbuf = buf.reshape(e_local, capacity, d)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hbuf, wg))
        u = jnp.einsum("ecd,edf->ecf", hbuf, wu)
        y = jnp.einsum("ecf,efd->ecd", g * u, wd).reshape(-1, d)
        out = _combine(y, slot, keep, w_local, t_all, k)  # (T, D)
        out = jax.lax.psum(out, axis)  # sum expert partials over TP
        if rules.shard_batch:
            # sum FFN-width partials over DP + return each shard its tokens
            out = jax.lax.psum_scatter(out, rules.batch_axes,
                                       scatter_dimension=0, tiled=True)
            # aux is identical on every DP shard post-gather, but the VMA
            # system can't infer that through all_gather: pmean to prove it
            aux = jax.lax.pmean(aux, rules.batch_axes)
        else:
            out = jax.lax.psum(out, rules.batch_axes)  # FFN partials only
        return out.reshape(xx.shape), aux

    dp = (tuple(rules.batch_axes) if len(rules.batch_axes) > 1
          else rules.batch_axes[0])
    x_spec = P(batch, None, None)
    return shd.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None, dp), P(axis, None, dp), P(axis, dp, None),
                  x_spec),
        out_specs=(x_spec, P()),
    )(p["router"], p["experts"]["w_gate"], p["experts"]["w_up"],
      p["experts"]["w_down"], x)


def moe_layer(cfg, p: Params, x):
    """Dispatching MoE layer: picks the execution path from the active
    sharding rules.  Returns (out (B,S,D), aux_loss)."""
    b, s, d = x.shape
    rules = shd.current_rules()
    k = cfg.num_experts_per_tok
    e = cfg.num_experts

    if rules is None or rules.mesh is None or rules.mesh.shape[rules.model_axis] == 1:
        out, aux = moe_dense(cfg, p, x)
    else:
        mesh = rules.mesh
        axis = rules.model_axis
        n = mesh.shape[axis]
        batch = rules.batch()
        if e % n == 0 and s % n == 0 and s > 1:
            # EP with all_to_all: tokens seq-sharded over the model axis
            t_loc = (b * s) // (n * math.prod(mesh.shape[a] for a in rules.batch_axes))
            capacity = max(_ceil_mult(t_loc * k / e * cfg.capacity_factor, 1), 4)

            all_axes = (*rules.batch_axes, axis)

            def body(router, experts, xx):
                bb, ss, dd = xx.shape
                out, aux = _moe_local(cfg, router, experts, xx.reshape(-1, dd),
                                      capacity=capacity, e_local=e // n, axis=axis)
                return out.reshape(bb, ss, dd), jax.lax.pmean(aux, all_axes)

            out, aux = shd.shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(axis, None, None), P(batch, axis, None)),
                out_specs=(P(batch, axis, None), P()),
            )(p["router"], p["experts"], x)
        elif e % n == 0 and rules.expert_ff_fsdp:
            from repro.models import perf

            assert perf.current().moe_decode == "tp_data"
            out, aux = _moe_decode_tpdata(cfg, rules, p, x)
        elif e % n == 0:
            # decode: tokens replicated over model, partial psum combine
            t_loc = (b * s) // math.prod(mesh.shape[a] for a in rules.batch_axes)
            capacity = max(_ceil_mult(t_loc * k / e * cfg.capacity_factor, 1), 4)

            def body(router, experts, xx):
                bb, ss, dd = xx.shape
                out, aux = _moe_replicated_body(
                    cfg, router, experts, xx.reshape(-1, dd),
                    capacity=capacity, axis=axis)
                # aux is computed on model-replicated tokens: it only varies
                # over the DP axes, so average over those alone
                return out.reshape(bb, ss, dd), jax.lax.pmean(aux, rules.batch_axes)

            out, aux = shd.shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(axis, None, None), P(batch, None, None)),
                out_specs=(P(batch, None, None), P()),
            )(p["router"], p["experts"], x)
        else:
            out, aux = moe_dense(cfg, p, x)

    if "shared" in p:
        out = out + mlp(cfg, p["shared"], x)
    return shd.shard_hidden(out), aux


def _ceil_mult(x: float, m: int) -> int:
    return int(math.ceil(x / m) * m)
