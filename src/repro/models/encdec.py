"""Whisper-style encoder-decoder backbone.

The audio frontend (log-mel + conv1d subsampling) is a STUB: the encoder
consumes precomputed frame embeddings (B, encoder_seq, d_model) supplied by
``input_specs()``.  Positions are sinusoidal (parameter-free) — an
adaptation of Whisper's learned 448-position table, which cannot cover the
assigned 4k/32k decoder shapes (DESIGN.md §8).

Decode cache: per decoder layer, self-attention K/V (growing) plus
cross-attention K/V (computed once from the encoder output at prefill).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import layers as L


def init_cross_attention(cfg, key, dtype):
    d, n, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], (d, n, hd), dtype),
        "wk": L.dense_init(ks[1], (d, nkv, hd), dtype),
        "wv": L.dense_init(ks[2], (d, nkv, hd), dtype),
        "wo": L.dense_init(ks[3], (n, hd, d), dtype, scale=1.0 / math.sqrt(n * hd)),
    }


def init_encoder_layer(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "attn": L.init_attention(cfg, ks[0], dtype),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(cfg, ks[1], dtype),
    }


def init_decoder_layer(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "attn": L.init_attention(cfg, ks[0], dtype),
        "ln_x": L.init_rms_norm(cfg.d_model, dtype),
        "cross": init_cross_attention(cfg, ks[1], dtype),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(cfg, ks[2], dtype),
    }


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    params = {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "encoder": jax.vmap(
            lambda k: init_encoder_layer(cfg, k, dtype)
        )(jax.random.split(ks[1], cfg.encoder_layers)),
        "enc_norm": L.init_rms_norm(cfg.d_model, dtype),
        "decoder": jax.vmap(
            lambda k: init_decoder_layer(cfg, k, dtype)
        )(jax.random.split(ks[2], cfg.num_layers)),
        "final_norm": L.init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    n, hd = cfg.num_kv_heads, cfg.head_dim

    def kv(seq):
        return (jnp.zeros((batch, seq, n, hd), dtype),
                jnp.zeros((batch, seq, n, hd), dtype))

    return {
        "self": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), kv(max_seq)),
        "cross": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)),
            kv(cfg.encoder_seq)),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def cache_family(cfg) -> str | None:
    """Enc-dec stacks must DECLARE their family (``cache_family='encdec'``)
    — the cross cache is a shared read-only segment, not derivable."""
    return getattr(cfg, "cache_family", "") or None


def supports_paged(cfg) -> bool:
    return cache_family(cfg) == "encdec"


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None, *,
                     num_slabs: int = 0, num_segments: int = 0):
    """``self`` — growing decoder self-KV block pools (L, NB, BS, n, hd);
    ``cross`` — cross-attention KV SEGMENT pools (L, NSeg, enc_seq, n,
    hd), read-only after prefill and refcount-shared across streams that
    decode against the same encoder output (COW-dedup of shared
    prefixes)."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged decode cache unsupported for family={cfg.family!r}")
    dtype = dtype or jnp.dtype(cfg.dtype)
    n, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    kv_shape = (nl, num_blocks, block_size, n, hd)
    seg_shape = (nl, num_segments, cfg.encoder_seq, n, hd)
    # distinct buffers per leaf: the engine donates the pools into its
    # jitted steps, and XLA rejects the same buffer donated twice
    return {"self": (jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype)),
            "cross": (jnp.zeros(seg_shape, dtype),
                      jnp.zeros(seg_shape, dtype))}


def paged_pool_kinds(cfg) -> dict[str, str]:
    return {"self": "block", "cross": "segment"}


def paged_insert_views(cfg, prefill_cache) -> dict:
    return {"self": prefill_cache["self"], "cross": prefill_cache["cross"]}


def encode(cfg, params, frames):
    """frames (B, T_enc, D) — precomputed embeddings (frontend stub)."""
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    x = frames + L.sinusoidal_positions(pos, cfg.d_model).astype(frames.dtype)
    x = shd.shard_hidden(x)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
        # bidirectional: no mask
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dnh->bsnh", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dnh->bsnh", h, lp["attn"]["wv"])
        o = L._sdpa(q, k, v, mask=None, scale=1.0 / math.sqrt(cfg.head_dim))
        x = x + jnp.einsum("bsnh,nhd->bsd", o, lp["attn"]["wo"])
        h = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
        x = x + L.mlp(cfg, lp["mlp"], h)
        return shd.shard_hidden(x), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _cross_attention(cfg, p, x, k, v):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    o = L._sdpa(q, k, v, mask=None, scale=1.0 / math.sqrt(cfg.head_dim))
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


def forward(cfg, params, batch, *, mode: str, cache=None, remat: bool = False,
            remat_policy=None):
    """batch: 'frames' (B,T_enc,D) for train/prefill; 'tokens' (B,S)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shd.shard_hidden(x)

    if mode == "decode":
        positions = cache["pos"][:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    if mode == "decode":
        enc_out = None  # cross K/V come from the cache
    else:
        enc_out = encode(cfg, params, batch["frames"])

    paged = mode == "decode" and cache is not None and "block_tables" in cache
    if paged:
        # self-KV block pools ride as carry (scatter+gather per layer);
        # the cross segment pools are READ-ONLY — they ride as xs, each
        # layer gathering its streams' shared segments at ``segment_ids``
        tables, segs = cache["block_tables"], cache["segment_ids"]
        pos = cache["pos"]

        def paged_body(carry, inp):
            x, ks, vs, lidx = carry
            lp, (ck_pool, cv_pool) = inp
            h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
            out, (ks, vs) = L.attention(cfg, lp["attn"], h,
                                        positions=positions,
                                        layer_cache=(ks, vs, lidx, tables,
                                                     pos))
            x = x + out
            h = L.rms_norm(x, lp["ln_x"]["scale"], cfg.norm_eps)
            x = x + _cross_attention(cfg, lp["cross"], h, ck_pool[segs],
                                     cv_pool[segs])
            h = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
            x = x + L.mlp(cfg, lp["mlp"], h)
            return (x, ks, vs, lidx + 1), None

        ks, vs = cache["self"]
        (x, ks, vs, _), _ = jax.lax.scan(
            paged_body, (x, ks, vs, jnp.int32(0)),
            (params["decoder"], cache["cross"]))
        x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, table,
                            preferred_element_type=jnp.float32)
        logits = shd.shard_logits(logits)
        new_cache = {"self": (ks, vs), "cross": cache["cross"],
                     "pos": cache["pos"] + 1, "block_tables": tables,
                     "segment_ids": segs}
        return logits, new_cache, jnp.zeros((), jnp.float32)

    def body(carry, inp):
        x = carry
        if mode == "decode":
            lp, (sc, cc) = inp
            self_cache = sc + (cache["pos"],)
        else:
            lp, self_cache, cc = inp, None, None
        h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
        out, new_self = L.attention(
            cfg, lp["attn"], h, positions=positions,
            cache="build" if mode == "prefill" else None,
            layer_cache=self_cache)
        x = x + out
        h = L.rms_norm(x, lp["ln_x"]["scale"], cfg.norm_eps)
        if mode == "decode":
            ck, cv = cc
        else:
            ck = jnp.einsum("btd,dnh->btnh", enc_out, lp["cross"]["wk"])
            cv = jnp.einsum("btd,dnh->btnh", enc_out, lp["cross"]["wv"])
        x = x + _cross_attention(cfg, lp["cross"], h, ck, cv)
        h = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
        x = x + L.mlp(cfg, lp["mlp"], h)
        new_cross = (ck, cv) if mode == "prefill" else None
        return x, (new_self, new_cross)

    body_fn = jax.checkpoint(body, policy=remat_policy) if remat else body
    xs = (params["decoder"], (cache["self"], cache["cross"])) \
        if mode == "decode" else params["decoder"]
    x, (self_c, cross_c) = jax.lax.scan(body_fn, x, xs)

    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, table, preferred_element_type=jnp.float32)
    logits = shd.shard_logits(logits)

    if mode == "train":
        return logits, None, jnp.zeros((), jnp.float32)

    if mode == "prefill":
        max_seq = batch.get("max_seq", s)
        self_c = jax.tree.map(lambda a: _pad_seq(a, 2, max_seq), self_c)
        lengths = batch.get("lengths")
        new_cache = {"self": self_c, "cross": cross_c,
                     "pos": (jnp.asarray(lengths, jnp.int32)
                             if lengths is not None
                             else jnp.full((b,), s, jnp.int32))}
    else:
        new_cache = {"self": self_c, "cross": cache["cross"],
                     "pos": cache["pos"] + 1}
    new_cache["self"] = jax.tree.map(
        lambda a: shd.shard_cache_seq(a, batch_axis=1, seq_axis=2), new_cache["self"])
    return logits, new_cache, jnp.zeros((), jnp.float32)


def _pad_seq(x, axis: int, target: int):
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)
