"""Performance flags for the §Perf hillclimb.

Defaults are the NAIVE baselines the roofline table was recorded with;
named variants in launch/dryrun.py flip individual flags so each
hypothesis -> change -> re-lower -> re-analyse iteration is a one-liner.
After the hillclimb, launchers enable the winners explicitly.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, replace

_CTX = threading.local()


@dataclass(frozen=True)
class PerfFlags:
    # decode KV/latent cache update: "where" rewrites the whole cache per
    # step (baseline); "scatter" touches only the new token's row.
    cache_update: str = "where"
    # Mamba2 input projection: fused single matmul whose output width
    # (d_in + conv_dim + heads) rarely divides the TP axis -> falls back to
    # fully replicated compute (baseline).  True splits z/xBC/dt into three
    # cleanly-shardable projections.
    split_ssm_proj: bool = False
    # SSD intra-chunk length Q: the L matrix is O(B*S*Q*H) bytes — linear
    # in Q.
    ssd_chunk: int = 256
    # MoE decode: "replicated" psum-combine with FSDP weight gathers
    # (baseline); "tp_data" shards expert FFN width over the data axis and
    # gathers TOKENS instead of weights (requires rules.expert_ff_fsdp so
    # the storage sharding matches).
    moe_decode: str = "replicated"
    # decode: 2D tensor parallelism — weights stay (data x model)-sharded,
    # activations replicate over the batch axes (psum), the cache sequence
    # shards over both axes.  Kills the per-layer FSDP weight all-gathers.
    serve_2d: bool = False
    # train: sequence parallelism — residual-stream activations sharded over
    # the model axis on the sequence dim (Megatron-SP), so norms/residual
    # ops touch S/TP tokens and the TP all-reduces become RS+AG pairs.
    shard_seq: bool = False


def current() -> PerfFlags:
    return getattr(_CTX, "flags", None) or PerfFlags()


def set_flags(flags: PerfFlags | None) -> None:
    _CTX.flags = flags


class use_flags:
    def __init__(self, flags: PerfFlags | None):
        self.flags = flags

    def __enter__(self):
        self.prev = getattr(_CTX, "flags", None)
        set_flags(self.flags)
        return self.flags

    def __exit__(self, *exc):
        set_flags(self.prev)


VARIANTS: dict[str, PerfFlags] = {
    "baseline": PerfFlags(),
    "opt_cache": PerfFlags(cache_update="scatter"),
    "opt_moe": PerfFlags(moe_decode="tp_data"),
    "opt_ssm": PerfFlags(split_ssm_proj=True),
    "opt_ssm_q128": PerfFlags(split_ssm_proj=True, ssd_chunk=128),
    "opt_ssm_q64": PerfFlags(split_ssm_proj=True, ssd_chunk=64),
    "opt_serve2d": PerfFlags(serve_2d=True),
    "opt_serve2d_moe": PerfFlags(serve_2d=True, moe_decode="tp_data"),
    "opt_sp": PerfFlags(shard_seq=True),
    "opt_ssm_sp": PerfFlags(split_ssm_proj=True, ssd_chunk=128, shard_seq=True),
    "opt_all": PerfFlags(split_ssm_proj=True, ssd_chunk=128,
                         moe_decode="tp_data", serve_2d=True, shard_seq=True),
}
