"""Unified model API over all families.

    init_params(cfg, key)                      -> params pytree
    init_cache(cfg, batch, max_seq)            -> decode cache pytree
    init_paged_cache(cfg, blocks, block_size)  -> block-pool decode cache
    supports_paged(cfg)                        -> paged decode available?
    apply(cfg, params, batch, mode=...)        -> (logits, cache, aux)
    loss_fn(cfg, params, batch, ...)           -> (loss, metrics)
    param_count(cfg)                           -> analytical N (for rooflines)
    input_specs(cfg, shape)                    -> ShapeDtypeStruct batch stand-ins
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer

Params = Any


def _family_mod(cfg):
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "encdec":
        return encdec
    return transformer  # dense | moe | ssm | vlm


def init_params(cfg, key) -> Params:
    return _family_mod(cfg).init_params(cfg, key)


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    return _family_mod(cfg).init_cache(cfg, batch, max_seq, dtype)


def cache_family(cfg) -> str | None:
    """Resolve the cache family (a ``serving.kvcache.FAMILIES`` key) this
    config pages under, or None when nothing resolves.  A declared
    ``cfg.cache_family`` always wins; only plain GQA-shaped stacks derive
    one implicitly — there is NO silent dense fallback for the rest."""
    mod = _family_mod(cfg)
    return getattr(mod, "cache_family", lambda _cfg: None)(cfg)


def supports_paged(cfg) -> bool:
    """True when the family can run its decode cache in pooled form
    (``init_paged_cache`` + a block-table / slab-id decode cache)."""
    mod = _family_mod(cfg)
    return getattr(mod, "supports_paged", lambda _cfg: False)(cfg)


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None, *,
                     num_slabs: int = 0, num_segments: int = 0):
    """Pooled decode cache for the resolved cache family: block pools
    (num_blocks, block_size, ...) for attention KV, state-slab pools
    (num_slabs, ...) for SSM layers, and shared read-only segment pools
    (num_segments, ...) for enc-dec cross KV; the caller owns block
    tables, slab/segment ids, and lengths (see serving/kvcache.py)."""
    return _family_mod(cfg).init_paged_cache(
        cfg, num_blocks, block_size, dtype, num_slabs=num_slabs,
        num_segments=num_segments)


def paged_pool_kinds(cfg) -> dict[str, str]:
    """Pools-dict key -> "block" | "slab" | "segment" — the engine's map
    for generic staging, export/import, and the per-kind leak probe."""
    return _family_mod(cfg).paged_pool_kinds(cfg)


def paged_insert_views(cfg, prefill_cache) -> dict:
    """Prefill-cache leaves rearranged to match the ``init_paged_cache``
    pools structure ((Laxis, B, ...) per leaf) for the engine's generic
    insert scatter."""
    mod = _family_mod(cfg)
    if hasattr(mod, "paged_insert_views"):
        return mod.paged_insert_views(cfg, prefill_cache)
    views = {"layers": prefill_cache["layers"]}
    if "first_layers" in prefill_cache:
        views["first_layers"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *prefill_cache["first_layers"])
    return views


def apply(cfg, params, batch, *, mode: str, cache=None, remat: bool = False,
          remat_policy=None):
    return _family_mod(cfg).forward(cfg, params, batch, mode=mode, cache=cache,
                                    remat=remat, remat_policy=remat_policy)


def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Stable CE in fp32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg, params, batch, *, remat: bool = True, remat_policy=None,
            aux_weight: float = 0.01):
    logits, _, aux = apply(cfg, params, batch, mode="train", remat=remat,
                           remat_policy=remat_policy)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# analytical parameter counts (roofline MODEL_FLOPS = 6*N*D or 6*N_active*D)
# --------------------------------------------------------------------------


def _attn_params(cfg) -> int:
    d = cfg.d_model
    if cfg.attn_type == "mla":
        r, pr, pn, hv, n = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                            cfg.qk_nope_head_dim, cfg.v_head_dim, cfg.num_heads)
        return (d * n * (pn + pr) + d * (r + pr) + r * n * pn + r * n * hv
                + n * hv * d)
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * hd * (nq + 2 * nkv) + nq * hd * d


def _mlp_params(d: int, f: int, mlp_type: str) -> int:
    return (3 if mlp_type == "swiglu" else 2) * d * f


def _mamba_params(cfg) -> int:
    d, din, h = cfg.d_model, cfg.d_inner, cfg.ssm_nheads
    cdim = din + 2 * cfg.ssm_ngroups * cfg.ssm_state_dim
    return (d * (din + cdim + h) + cfg.conv_width * cdim + cdim
            + 3 * h + din + din * d)


def param_count(cfg, *, active_only: bool = False) -> int:
    d, v = cfg.d_model, cfg.vocab_size
    total = v * d + d  # embedding + final norm
    if not cfg.tie_embeddings:
        total += d * v  # lm_head

    if cfg.family == "hybrid":
        g = cfg.num_layers // cfg.attn_every
        n_mamba = cfg.num_layers - g
        total += n_mamba * (_mamba_params(cfg) + d)
        total += _attn_params(cfg) + _mlp_params(d, cfg.d_ff, cfg.mlp_type) + 2 * d
        return total

    if cfg.family == "encdec":
        enc = cfg.encoder_layers * (_attn_params(cfg)
                                    + _mlp_params(d, cfg.d_ff, cfg.mlp_type) + 2 * d)
        dec = cfg.num_layers * (2 * _attn_params(cfg)
                                + _mlp_params(d, cfg.d_ff, cfg.mlp_type) + 3 * d)
        return total + enc + dec + d  # + enc_norm

    if cfg.family == "ssm":
        return total + cfg.num_layers * (_mamba_params(cfg) + d)

    # dense / moe / vlm decoder
    for i in range(cfg.num_layers):
        total += _attn_params(cfg) + 2 * d
        if cfg.is_moe and i >= cfg.first_dense_layers:
            routed = cfg.num_experts_per_tok if active_only else cfg.num_experts
            total += routed * _mlp_params(d, cfg.moe_d_ff, "swiglu")
            total += cfg.num_shared_experts * _mlp_params(d, cfg.moe_d_ff, "swiglu")
            total += d * cfg.num_experts  # router
        else:
            total += _mlp_params(d, cfg.d_ff, cfg.mlp_type)
    return total


# --------------------------------------------------------------------------
# input stand-ins for the dry-run (ShapeDtypeStruct: no allocation)
# --------------------------------------------------------------------------


def input_specs(cfg, shape, *, for_train: bool | None = None) -> dict:
    """Batch stand-ins for one step of the given ShapeSpec.

    train:   tokens+labels (B,S)   [+frames/embeds/mrope per family]
    prefill: tokens (B,S)          [+...]
    decode:  tokens (B,1), cache supplied separately by the launcher
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    kind = shape.kind if for_train is None else ("train" if for_train else shape.kind)

    if kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        seq = (b, s)
    elif kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
        seq = (b, s)
    else:  # decode: one new token against a cache of length s
        batch = {"tokens": sds((b, 1), i32)}
        seq = (b, 1)

    if cfg.family == "vlm":
        # frontend stub: merged text+vision embeddings and M-RoPE positions
        # replace raw tokens entirely
        batch.pop("tokens", None)
        batch["embeds"] = sds((*seq, cfg.d_model), dt)
        batch["mrope_positions"] = sds((3, *seq), i32)
    if cfg.family == "encdec" and kind != "decode":
        # frontend stub: precomputed encoder frame embeddings
        batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), dt)
    return batch
