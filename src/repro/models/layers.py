"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), attention
(GQA/MQA and MLA with absorbed decode), and MLPs.

All functions are pure; parameters are plain dicts of jnp arrays.  Matmul
accumulations that feed softmax/normalization run in fp32
(``preferred_element_type``); activations stay in the config dtype.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd

Params = dict[str, Any]

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, *, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim/2) in fp32."""
    freqs = _rope_freqs(head_dim, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions, head_dim: int, theta: float, sections):
    """Qwen2-VL multimodal rotary: ``positions`` (3, B, S) carries the
    temporal/height/width streams; rotary pairs are split into ``sections``
    (summing to head_dim/2), each driven by its own stream."""
    assert positions.shape[0] == 3, "M-RoPE needs (3, B, S) positions"
    cos, sin = rope_cos_sin(positions, head_dim, theta)  # (3, B, S, hd/2)
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=head_dim // 2
    )  # static
    take = jax.nn.one_hot(sec_ids, 3, dtype=cos.dtype)  # (hd/2, 3)
    cos = jnp.einsum("tbsd,dt->bsd", cos, take)
    sin = jnp.einsum("tbsd,dt->bsd", sin, take)
    return cos, sin


def apply_rope(x, cos, sin):
    """x (B, S, N, H); cos/sin (B, S, H/2).  Llama-style rotate-half."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(positions, d_model: int):
    """Whisper-style absolute sinusoidal embeddings, (..., S) -> (..., S, D)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# attention (GQA / MQA)
# --------------------------------------------------------------------------


def init_attention(cfg, key, dtype) -> Params:
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, nq, hd), dtype),
        "wk": dense_init(ks[1], (d, nkv, hd), dtype),
        "wv": dense_init(ks[2], (d, nkv, hd), dtype),
        "wo": dense_init(ks[3], (nq, hd, d), dtype, scale=1.0 / math.sqrt(nq * hd)),
    }


def _sdpa(q, k, v, *, mask, scale: float):
    """q (B,Sq,Nq,H); k/v (B,Sk,Nkv,H); grouped heads; fp32 softmax.

    This is also the pure-jnp oracle the Pallas flash kernel is verified
    against (kernels/ref.py re-exports it)."""
    b, sq, nq, h = q.shape
    nkv = k.shape[2]
    hv = v.shape[-1]  # may differ from h (MLA: qk dim != v dim)
    g = nq // nkv
    q = q.reshape(b, sq, nkv, g, h)
    logits = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, nq, hv).astype(v.dtype)


def attention(cfg, p: Params, x, *, positions, cache=None, layer_cache=None,
              mrope_positions=None):
    """GQA attention.

    Training/prefill: ``layer_cache is None`` -> causal self-attention; if
    ``cache == 'build'`` also returns the (k, v) for cache construction.
    Decode: ``layer_cache = (k_cache, v_cache, pos)`` with x of seq-len 1;
    returns (out, (k_cache', v_cache')).
    Paged decode: ``layer_cache = (k_stack, v_stack, lidx, block_tables,
    pos)`` with the full layer-stacked pools (L, num_blocks, block_size,
    Nkv, H) shared across rows, ``lidx`` this layer's index into the stack,
    and ``block_tables`` (B, W) int32 the per-row indirection; returns
    (out, (k_stack', v_stack')).
    """
    b, s, d = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    q = shd.shard_heads(q)

    if cfg.rope_theta:
        if cfg.mrope and mrope_positions is not None:
            cos, sin = mrope_cos_sin(mrope_positions, hd, cfg.rope_theta,
                                     cfg.mrope_sections)
        else:
            cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        # absolute positions are added at the embedding layer (whisper)
        pass

    scale = 1.0 / math.sqrt(hd)
    if layer_cache is None:
        # causal self-attention over the full sequence
        idx = jnp.arange(s)
        mask = (idx[None, :] <= idx[:, None])[None, None, None, :, :]
        out = _sdpa(q, k, v, mask=mask, scale=scale)
        new_cache = (k, v) if cache == "build" else None
    elif len(layer_cache) == 5:
        # paged decode: the cache is a block POOL shared by all rows, each
        # row addressing its own blocks through ``tables``.  The pool rides
        # the layer scan as CARRY — the full (L, NB, BS, Nkv, H) stacks,
        # indexed by ``lidx`` — so the only per-step data movement is the
        # one-row scatter of the new token and the gather of the W live
        # blocks: cost tracks actual work, never pool capacity (the dense
        # path copies its whole (max_batch, max_seq) cache every step).
        # Rows never share a tail block (the paged KV manager copy-on-
        # write-forks shared tails), so scatters are row-disjoint and no
        # masked merge is needed.
        k_stack, v_stack, lidx, tables, pos = layer_cache
        bs_blk = k_stack.shape[2]  # (L, NB, BS, Nkv, H), (B, W), (B,)
        bidx = jnp.arange(b)
        blk = tables[bidx, pos // bs_blk]
        off = pos % bs_blk
        k_stack = k_stack.at[lidx, blk, off].set(k[:, 0].astype(k_stack.dtype))
        v_stack = v_stack.at[lidx, blk, off].set(v[:, 0].astype(v_stack.dtype))
        w = tables.shape[1]
        k_seq = k_stack[lidx, tables].reshape(b, w * bs_blk, *k_stack.shape[3:])
        v_seq = v_stack[lidx, tables].reshape(b, w * bs_blk, *v_stack.shape[3:])
        valid = jnp.arange(w * bs_blk)[None, :] <= pos[:, None]
        mask = valid[:, None, None, None, :]
        out = _sdpa(q, k_seq, v_seq, mask=mask, scale=scale)
        new_cache = (k_stack, v_stack)
    else:
        k_cache, v_cache, pos = layer_cache  # (B, Smax, Nkv, H), pos (B,)
        # write the new token at its position per batch element
        from repro.models import perf

        if perf.current().cache_update == "scatter":
            bidx = jnp.arange(b)
            k_cache = k_cache.at[bidx, pos].set(k[:, 0].astype(k_cache.dtype))
            v_cache = v_cache.at[bidx, pos].set(v[:, 0].astype(v_cache.dtype))
        else:  # naive baseline: full-cache select
            upd = jnp.arange(k_cache.shape[1])[None, :] == pos[:, None]
            k_cache = jnp.where(upd[..., None, None], k.astype(k_cache.dtype),
                                k_cache)
            v_cache = jnp.where(upd[..., None, None], v.astype(v_cache.dtype),
                                v_cache)
        valid = (jnp.arange(k_cache.shape[1])[None, :] <= pos[:, None])
        mask = valid[:, None, None, None, :]
        out = _sdpa(q, k_cache, v_cache, mask=mask, scale=scale)
        new_cache = (k_cache, v_cache)

    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return shd.shard_hidden(out), new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# --------------------------------------------------------------------------


def init_mla(cfg, key, dtype) -> Params:
    d, n = cfg.d_model, cfg.num_heads
    r, pr, pn, hv = cfg.kv_lora_rank, cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, n, pn + pr), dtype),
        "w_dkv": dense_init(ks[1], (d, r + pr), dtype),  # latent + shared rope key
        "w_uk": dense_init(ks[2], (r, n, pn), dtype),
        "w_uv": dense_init(ks[3], (r, n, hv), dtype),
        "wo": dense_init(ks[4], (n, hv, d), dtype, scale=1.0 / math.sqrt(n * hv)),
    }


def mla_attention(cfg, p: Params, x, *, positions, cache=None, layer_cache=None):
    """MLA: KV compressed to a ``kv_lora_rank`` latent + one shared rotary
    key.  The cache stores only (c_kv, k_rope) — the paper-accurate memory
    win.  Decode uses the absorbed formulation (queries projected into the
    latent space; no per-step K/V decompression).

    Paged decode: ``layer_cache = (ckv_stack, krope_stack, lidx, tables,
    pos)`` — latent block pools (L, NB, BS, r) / (L, NB, BS, pr) shared
    across rows, the same block-table indirection as GQA but with much
    smaller rows (r + pr vs 2 * n_kv * head_dim per token), which is why
    MLA paging has its own block-size sensitivity."""
    b, s, d = x.shape
    n = cfg.num_heads
    r, pr, pn, hv = cfg.kv_lora_rank, cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(pn + pr)

    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])  # (B,S,N,pn+pr)
    q_nope, q_rope = q[..., :pn], q[..., pn:]
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # (B,S,r+pr)
    c_kv, k_rope = ckv_full[..., :r], ckv_full[..., r:]

    cos, sin = rope_cos_sin(positions, pr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]  # shared head

    if layer_cache is None:
        k_nope = jnp.einsum("bsr,rnh->bsnh", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rnh->bsnh", c_kv, p["w_uv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope[:, :, None, :], (b, s, n, pr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        idx = jnp.arange(s)
        mask = (idx[None, :] <= idx[:, None])[None, None, None, :, :]
        out = _sdpa(qq, k, v, mask=mask, scale=scale)
        new_cache = (c_kv, k_rope) if cache == "build" else None
    elif len(layer_cache) == 5:
        # paged decode over latent block pools: scatter the new (c_kv,
        # k_rope) row into this stream's tail block, gather the W live
        # blocks through the table, then the same absorbed math as the
        # dense branch.  Rows never share a tail block (COW fork), so the
        # scatters are row-disjoint exactly as in GQA paged decode.
        ckv_stack, krope_stack, lidx, tables, pos = layer_cache
        bs_blk = ckv_stack.shape[2]  # (L, NB, BS, r), (B, W), (B,)
        bidx = jnp.arange(b)
        blk = tables[bidx, pos // bs_blk]
        off = pos % bs_blk
        ckv_stack = ckv_stack.at[lidx, blk, off].set(
            c_kv[:, 0].astype(ckv_stack.dtype))
        krope_stack = krope_stack.at[lidx, blk, off].set(
            k_rope[:, 0].astype(krope_stack.dtype))
        w = tables.shape[1]
        ckv_seq = ckv_stack[lidx, tables].reshape(b, w * bs_blk, r)
        krope_seq = krope_stack[lidx, tables].reshape(b, w * bs_blk, pr)
        q_lat = jnp.einsum("bsnh,rnh->bsnr", q_nope, p["w_uk"])
        logits = (
            jnp.einsum("bsnr,btr->bnst", q_lat, ckv_seq,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bsnh,bth->bnst", q_rope, krope_seq,
                         preferred_element_type=jnp.float32)
        ) * scale
        valid = (jnp.arange(w * bs_blk)[None, :] <= pos[:, None])
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bnst,btr->bsnr", probs,
                           ckv_seq.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bsnr,rnh->bsnh", o_lat, p["w_uv"])
        new_cache = (ckv_stack, krope_stack)
    else:
        ckv_cache, krope_cache, pos = layer_cache  # (B,Smax,r), (B,Smax,pr)
        t = ckv_cache.shape[1]
        from repro.models import perf

        if perf.current().cache_update == "scatter":
            bidx = jnp.arange(b)
            ckv_cache = ckv_cache.at[bidx, pos].set(
                c_kv[:, 0].astype(ckv_cache.dtype))
            krope_cache = krope_cache.at[bidx, pos].set(
                k_rope[:, 0].astype(krope_cache.dtype))
        else:  # naive baseline: full-cache select
            upd = jnp.arange(t)[None, :] == pos[:, None]
            ckv_cache = jnp.where(upd[..., None], c_kv.astype(ckv_cache.dtype),
                                  ckv_cache)
            krope_cache = jnp.where(upd[..., None],
                                    k_rope.astype(krope_cache.dtype), krope_cache)
        # absorbed decode: q_nope' = q_nope @ w_uk  -> latent space
        q_lat = jnp.einsum("bsnh,rnh->bsnr", q_nope, p["w_uk"])
        logits = (
            jnp.einsum("bsnr,btr->bnst", q_lat, ckv_cache,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bsnh,bth->bnst", q_rope, krope_cache,
                         preferred_element_type=jnp.float32)
        ) * scale
        valid = (jnp.arange(t)[None, :] <= pos[:, None])[:, None, None, :]
        logits = jnp.where(valid, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bnst,btr->bsnr", probs,
                           ckv_cache.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bsnr,rnh->bsnh", o_lat, p["w_uv"])
        new_cache = (ckv_cache, krope_cache)

    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return shd.shard_hidden(out), new_cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_mlp(cfg, key, dtype, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f), dtype),
            "w_up": dense_init(ks[1], (d, f), dtype),
            "w_down": dense_init(ks[2], (f, d), dtype),
        }
    return {  # gelu
        "w_up": dense_init(ks[1], (d, f), dtype),
        "w_down": dense_init(ks[2], (f, d), dtype),
    }


def mlp(cfg, p: Params, x):
    if "w_gate" in p:
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    h = shd.shard_ffn(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
