"""Decoder-only transformer stack (dense / MoE / VLM / pure-SSM families).

Layers are stacked along a leading axis and executed with ``jax.lax.scan``
so the HLO stays O(1) in depth (mandatory for compiling 94/126-layer models
in the 512-device dry-run, and the production-correct choice anyway).
Non-uniform prefixes (e.g. DeepSeek's first dense layer) run as plain Python
loops before the scan.

Modes:
  train   — causal forward, logits for all positions, no cache
  prefill — causal forward + returns the decode cache
  decode  — single-token step against the cache
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

# --------------------------------------------------------------------------
# per-layer init
# --------------------------------------------------------------------------


def _mixer_kind(cfg) -> str:
    if cfg.family == "ssm":
        return "mamba"
    return cfg.attn_type  # gqa | mla


def init_layer(cfg, key, dtype, *, use_moe: bool, d_ff: int | None = None):
    ks = jax.random.split(key, 3)
    kind = _mixer_kind(cfg)
    p: dict = {"ln1": L.init_rms_norm(cfg.d_model, dtype)}
    if kind == "mamba":
        p["mamba"] = S.init_mamba2(cfg, ks[0], dtype)
        return p  # SSM blocks: mixer only, no separate MLP
    if kind == "mla":
        p["attn"] = L.init_mla(cfg, ks[0], dtype)
    else:
        p["attn"] = L.init_attention(cfg, ks[0], dtype)
    p["ln2"] = L.init_rms_norm(cfg.d_model, dtype)
    if use_moe:
        p["moe"] = M.init_moe(cfg, ks[1], dtype)
    else:
        p["mlp"] = L.init_mlp(cfg, ks[1], dtype, d_ff=d_ff)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    n_first = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.num_layers - n_first

    params: dict = {}
    params["embed"] = L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype)

    if n_first:
        params["first_layers"] = [
            init_layer(cfg, jax.random.fold_in(ks[1], i), dtype,
                       use_moe=False, d_ff=cfg.d_ff)
            for i in range(n_first)
        ]

    layer_keys = jax.random.split(ks[2], n_scan)
    params["layers"] = jax.vmap(
        lambda k: init_layer(cfg, k, dtype, use_moe=cfg.is_moe,
                             d_ff=cfg.moe_d_ff if cfg.is_moe else cfg.d_ff)
    )(layer_keys)

    params["final_norm"] = L.init_rms_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype)
    return params


# --------------------------------------------------------------------------
# block
# --------------------------------------------------------------------------


def block(cfg, p, x, *, positions, mrope_positions=None, mode: str,
          layer_cache=None, use_moe: bool, lengths=None):
    """One transformer block.  Returns (x, new_layer_cache, aux_loss).
    ``lengths`` (B,) are the true per-row prompt lengths of a padded
    (bucketed) prefill — only the SSM prefill needs them (its recurrent
    state is polluted by pad positions unless dt is masked)."""
    kind = _mixer_kind(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if kind == "mamba":
        if mode == "prefill":
            out, new_cache = S.prefill_mamba_cache(cfg, p["mamba"], h,
                                                   lengths=lengths)
        else:
            out, new_cache = S.mamba2_block(cfg, p["mamba"], h,
                                            layer_cache=layer_cache)
        return x + out, new_cache, aux

    cache_flag = "build" if mode == "prefill" else None
    if kind == "mla":
        out, new_cache = L.mla_attention(cfg, p["attn"], h, positions=positions,
                                         cache=cache_flag, layer_cache=layer_cache)
    else:
        out, new_cache = L.attention(cfg, p["attn"], h, positions=positions,
                                     cache=cache_flag, layer_cache=layer_cache,
                                     mrope_positions=mrope_positions)
    x = x + out
    h = L.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    if use_moe:
        out, aux = M.moe_layer(cfg, p["moe"], h)
    else:
        out = L.mlp(cfg, p["mlp"], h)
    return x + out, new_cache, aux


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    """Zero decode cache for the scanned stack (leading L axis) plus any
    prefix layers and the position counter."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_first = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.num_layers - n_first
    kind = _mixer_kind(cfg)

    def one_layer():
        if kind == "mamba":
            return (
                jnp.zeros((batch, cfg.conv_width - 1, S.conv_dim(cfg)), dtype),
                jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                           cfg.ssm_state_dim), jnp.float32),
            )
        if kind == "mla":
            return (
                jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
            )
        return (
            jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        )

    stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_scan, *a.shape)), one_layer())
    cache = {"layers": stack, "pos": jnp.zeros((batch,), jnp.int32)}
    if n_first:
        cache["first_layers"] = [one_layer() for _ in range(n_first)]
    return cache


def cache_family(cfg) -> str | None:
    """Resolve the cache family this stack pages under (a key into
    ``serving.kvcache.FAMILIES``).  A declared ``cfg.cache_family`` wins;
    otherwise only plain GQA-shaped stacks (gqa/vlm attention) derive a
    family — everything else must declare or gets None (NO silent dense
    fallback: the engine refuses paged mode rather than guessing)."""
    if getattr(cfg, "cache_family", ""):
        return cfg.cache_family
    if cfg.family in ("encdec", "hybrid"):
        return None
    return "gqa" if _mixer_kind(cfg) == "gqa" else None


def supports_paged(cfg) -> bool:
    """Stacks whose decode cache can run in pooled form: GQA k/v block
    pools, MLA latent block pools (smaller rows, same tables), and SSM
    state-slab pools.  Non-uniform MoE prefix layers ride along as an
    extra pool with their own leading axis."""
    return cache_family(cfg) in ("gqa", "mla", "ssm")


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None, *,
                     num_slabs: int = 0, num_segments: int = 0):
    """Zero pooled decode cache, keyed to match :func:`paged_pool_kinds`:

      gqa  — ``layers``: (k, v) pools (L, NB, BS, n_kv, head_dim)
      mla  — ``layers``: (c_kv, k_rope) pools (L, NB, BS, r) / (L, NB, BS,
             rope_dim); MoE prefix layers add ``first_layers`` with their
             own leading axis
      ssm  — ``layers``: (conv, state) SLAB pools (L, NS, W-1, C) /
             (L, NS, H, P, N) fp32 — constant-size, one slab per stream

    Block tables / slab ids and per-row lengths are NOT part of this
    pytree — the serving engine passes them per decode call (they change
    every step; the pool doesn't)."""
    fam = cache_family(cfg)
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged decode cache unsupported for family={cfg.family!r} "
            f"attn_type={cfg.attn_type!r}")
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_first = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.num_layers - n_first

    def one_layer():
        if fam == "ssm":
            return (
                jnp.zeros((num_slabs, cfg.conv_width - 1, S.conv_dim(cfg)),
                          dtype),
                jnp.zeros((num_slabs, cfg.ssm_nheads, cfg.ssm_head_dim,
                           cfg.ssm_state_dim), jnp.float32),
            )
        if fam == "mla":
            return (
                jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank), dtype),
                jnp.zeros((num_blocks, block_size, cfg.qk_rope_head_dim),
                          dtype),
            )
        shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    def stack(n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)),
                            one_layer())

    pools = {"layers": stack(n_scan)}
    if n_first:
        pools["first_layers"] = stack(n_first)
    return pools


def paged_pool_kinds(cfg) -> dict[str, str]:
    """Pool-kind map for the engine's generic staging/migration: pools-dict
    key -> "block" | "slab" | "segment"."""
    kind = "slab" if cache_family(cfg) == "ssm" else "block"
    kinds = {"layers": kind}
    if cfg.is_moe and cfg.first_dense_layers:
        kinds["first_layers"] = kind
    return kinds


def _shard_cache(cfg, cache):
    kind = _mixer_kind(cfg)
    if kind == "mamba":
        return cache  # state caches: small, head-sharded via params

    def f(x):
        # stacked leaves are (L, B, S, ...): shard batch + sequence
        if x.ndim >= 3:
            return shd.shard_cache_seq(x, batch_axis=1, seq_axis=2)
        return x

    cache = dict(cache)
    cache["layers"] = jax.tree.map(f, cache["layers"])
    return cache


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x * 1.0  # keep dtype


def unembed(cfg, params, x):
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, table,
                        preferred_element_type=jnp.float32)
    return shd.shard_logits(logits)


def forward(cfg, params, batch, *, mode: str, cache=None, remat: bool = False,
            remat_policy=None):
    """batch: dict with 'tokens' (B,S) or 'embeds' (B,S,D); optional
    'positions' ((B,S) or (3,B,S) for M-RoPE).  Returns (logits, new_cache,
    aux_loss)."""
    if cfg.embed_inputs and "embeds" in batch:
        x = batch["embeds"]
    else:
        x = embed_tokens(cfg, params, batch["tokens"])
    x = shd.shard_hidden(x)
    b, s, _ = x.shape

    if mode == "decode":
        pos = cache["pos"]  # (B,)
        positions = pos[:, None]
        mrope_positions = batch.get("mrope_positions")  # (3,B,1) or None
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        mrope_positions = batch.get("mrope_positions")
    if cfg.rope_theta == 0.0:  # absolute sinusoidal (whisper-style)
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    n_first = cfg.first_dense_layers if cfg.is_moe else 0
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"pos": None} if mode != "train" else None

    paged = mode == "decode" and cache is not None and (
        "block_tables" in cache or "slab_ids" in cache)
    prefill_lengths = None
    if mode == "prefill" and batch.get("lengths") is not None:
        prefill_lengths = jnp.asarray(batch["lengths"], jnp.int32)

    # -- prefix (non-scanned) layers ------------------------------------
    first_caches = []
    first_pools = cache.get("first_layers") if paged else None
    for i in range(n_first):
        if paged:
            # prefix pools carry their own leading axis; lidx selects it
            lc = first_pools + (jnp.int32(i), cache["block_tables"],
                                cache["pos"])
        elif mode == "decode":
            lc = cache["first_layers"][i] + (cache["pos"],)
        else:
            lc = None
        x, c, aux = block(cfg, params["first_layers"][i], x,
                          positions=positions, mrope_positions=mrope_positions,
                          mode=mode, layer_cache=lc, use_moe=False,
                          lengths=prefill_lengths)
        aux_total += aux
        if paged:
            first_pools = c
        else:
            first_caches.append(c)

    # -- scanned stack ---------------------------------------------------
    if paged:
        # the pool stacks ride the scan as CARRY (not xs/ys): each layer
        # scatters one row (or slab) and gathers its live window in place,
        # so the scan never materializes a copy of the whole pool —
        # per-step cost tracks the live rows' work, not pool capacity
        kind = _mixer_kind(cfg)

        def paged_body(carry, lp):
            x, aux_acc, p0, p1, lidx = carry
            if kind == "mamba":
                lc = (p0, p1, lidx, cache["slab_ids"])
            else:  # gqa / mla block pools share the table indirection
                lc = (p0, p1, lidx, cache["block_tables"], cache["pos"])
            x, (p0, p1), aux = block(
                cfg, lp, x, positions=positions,
                mrope_positions=mrope_positions, mode=mode, layer_cache=lc,
                use_moe=cfg.is_moe)
            return (x, aux_acc + aux, p0, p1, lidx + 1), None

        p0, p1 = cache["layers"]
        carry = (x, aux_total, p0, p1, jnp.int32(0))
        (x, aux_total, p0, p1, _), _ = jax.lax.scan(
            paged_body, carry, params["layers"])
        layer_caches = (p0, p1)
    else:
        def body(carry, inp):
            x, aux_acc = carry
            if mode == "decode":
                lp, lc = inp
                lc = lc + (cache["pos"],)
            else:
                lp, lc = inp, None
            x, c, aux = block(cfg, lp, x, positions=positions,
                              mrope_positions=mrope_positions, mode=mode,
                              layer_cache=lc, use_moe=cfg.is_moe,
                              lengths=prefill_lengths)
            return (x, aux_acc + aux), c

        body_fn = body
        if remat:
            body_fn = jax.checkpoint(body, policy=remat_policy)

        xs = (params["layers"], cache["layers"]) if mode == "decode" \
            else params["layers"]
        (x, aux_total), layer_caches = jax.lax.scan(body_fn, (x, aux_total),
                                                    xs)

    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(cfg, params, x)

    if mode == "train":
        return logits, None, aux_total
    out_cache = {"layers": layer_caches, "pos": None}
    if n_first:
        out_cache["first_layers"] = first_pools if paged else first_caches
    if mode == "prefill":
        # per-row true lengths: bucketed prefill batching pads same-bucket
        # prompts to a common length; rows past ``lengths[b]`` hold padding
        # KV that decode masks (and progressively overwrites)
        out_cache["pos"] = (prefill_lengths if prefill_lengths is not None
                            else jnp.full((b,), s, jnp.int32))
        kind = _mixer_kind(cfg)
        if kind in ("gqa", "mla"):
            out_cache = _pad_prefill_cache(cfg, out_cache, batch.get("max_seq", s))
    else:
        out_cache["pos"] = cache["pos"] + 1
        if paged:
            # pools are not (L,B,S,...)-shaped; sharding rules don't apply
            for k in ("block_tables", "slab_ids"):
                if k in cache:
                    out_cache[k] = cache[k]
            return logits, out_cache, aux_total
    return logits, _shard_cache(cfg, out_cache), aux_total


def _pad_prefill_cache(cfg, cache, max_seq: int):
    """Grow prefill caches to max_seq along the sequence axis: axis 2 for
    the scanned stack (L,B,S,...), axis 1 for unstacked prefix layers
    (B,S,...)."""

    def pad_axis(axis):
        def pad(x):
            if x.ndim > axis and x.shape[axis] < max_seq:
                pads = [(0, 0)] * x.ndim
                pads[axis] = (0, max_seq - x.shape[axis])
                return jnp.pad(x, pads)
            return x

        return pad

    cache = dict(cache)
    cache["layers"] = jax.tree.map(pad_axis(2), cache["layers"])
    if "first_layers" in cache:
        cache["first_layers"] = jax.tree.map(pad_axis(1), cache["first_layers"])
    return cache
