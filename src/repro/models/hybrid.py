"""Zamba2-style hybrid stack: a Mamba-2 backbone with a single SHARED
attention+MLP block applied every ``attn_every`` layers (weights shared
across all applications; real Zamba2 adds per-use LoRA deltas, omitted —
DESIGN.md §5).

Layout for scan-friendliness: the depth is decomposed into
  G groups x [ (attn_every - 1) mamba layers + 1 shared-attn application ]
+ R tail mamba layers,
with G = num_layers // attn_every and R = num_layers - G * attn_every.
The group scan carries stacked mamba weights (G, attn_every-1, ...) and the
shared block enters as a closed-over constant, so the HLO is O(1) in depth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import block as tf_block


def _split(cfg) -> tuple[int, int, int]:
    g = cfg.num_layers // cfg.attn_every
    per_group = cfg.attn_every - 1  # mamba layers per group
    tail = cfg.num_layers - g * cfg.attn_every
    return g, per_group, tail


def init_mamba_layer(cfg, key, dtype):
    return {"ln1": L.init_rms_norm(cfg.d_model, dtype),
            "mamba": S.init_mamba2(cfg, key, dtype)}


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    g, per_group, tail = _split(cfg)
    ks = jax.random.split(key, 6)
    params: dict = {"embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype)}

    def init_group(k):
        kk = jax.random.split(k, per_group)
        return jax.vmap(lambda q: init_mamba_layer(cfg, q, dtype))(kk)

    params["groups"] = jax.vmap(init_group)(jax.random.split(ks[1], g))
    params["shared_attn"] = {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "attn": L.init_attention(cfg, ks[2], dtype),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(cfg, ks[3], dtype),
    }
    if tail:
        params["tail"] = jax.vmap(
            lambda q: init_mamba_layer(cfg, q, dtype)
        )(jax.random.split(ks[4], tail))
    params["final_norm"] = L.init_rms_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[5], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    g, per_group, tail = _split(cfg)

    def mamba_cache():
        return (
            jnp.zeros((batch, cfg.conv_width - 1, S.conv_dim(cfg)), dtype),
            jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                       cfg.ssm_state_dim), jnp.float32),
        )

    def attn_cache():
        return (
            jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        )

    grp_mamba = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (g, per_group, *a.shape)), mamba_cache())
    grp_attn = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (g, *a.shape)), attn_cache())
    cache = {"groups_mamba": grp_mamba, "groups_attn": grp_attn,
             "pos": jnp.zeros((batch,), jnp.int32)}
    if tail:
        cache["tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail, *a.shape)), mamba_cache())
    return cache


def cache_family(cfg) -> str | None:
    """Hybrid stacks must DECLARE their family (``cache_family='hybrid'``)
    — two pool kinds ride one scan, nothing derivable to fall back on."""
    return getattr(cfg, "cache_family", "") or None


def supports_paged(cfg) -> bool:
    return cache_family(cfg) == "hybrid"


def init_paged_cache(cfg, num_blocks: int, block_size: int, dtype=None, *,
                     num_slabs: int = 0, num_segments: int = 0):
    """Both pool kinds for one stack: ``attn`` — shared-attention KV block
    pools with a leading G (group) axis, every application addressing the
    SAME per-stream block table into its own plane; ``mamba`` — state slab
    pools with the G*per_group + tail mamba layers flattened onto one
    leading axis (a running layer index walks it during the scan)."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged decode cache unsupported for family={cfg.family!r}")
    dtype = dtype or jnp.dtype(cfg.dtype)
    g, per_group, tail = _split(cfg)
    n_mamba = g * per_group + tail
    kv_shape = (g, num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        # two DISTINCT buffers: the engine donates the pools into its jitted
        # steps, and XLA rejects the same buffer donated twice
        "attn": (jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype)),
        "mamba": (
            jnp.zeros((n_mamba, num_slabs, cfg.conv_width - 1,
                       S.conv_dim(cfg)), dtype),
            jnp.zeros((n_mamba, num_slabs, cfg.ssm_nheads, cfg.ssm_head_dim,
                       cfg.ssm_state_dim), jnp.float32),
        ),
    }


def paged_pool_kinds(cfg) -> dict[str, str]:
    return {"attn": "block", "mamba": "slab"}


def paged_insert_views(cfg, prefill_cache) -> dict:
    """Reshape a prefill cache into leaves matching the pools dict of
    :func:`init_paged_cache` — (Laxis, B, ...) per leaf — so the engine's
    generic scatter can stage any family without knowing its layout."""
    g, per_group, tail = _split(cfg)

    def flat(leaf_idx):
        grp = prefill_cache["groups_mamba"][leaf_idx]  # (G, PG, B, ...)
        out = grp.reshape(g * per_group, *grp.shape[2:])
        if tail:
            out = jnp.concatenate([out, prefill_cache["tail"][leaf_idx]], 0)
        return out

    return {"attn": prefill_cache["groups_attn"],
            "mamba": (flat(0), flat(1))}


def _mamba_sub(cfg, p, x, *, mode, layer_cache, lengths=None):
    h = L.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if mode == "prefill":
        out, c = S.prefill_mamba_cache(cfg, p["mamba"], h, lengths=lengths)
    else:
        out, c = S.mamba2_block(cfg, p["mamba"], h, layer_cache=layer_cache)
    return x + out, c


def forward(cfg, params, batch, *, mode: str, cache=None, remat: bool = False,
            remat_policy=None):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = shd.shard_hidden(x)
    b, s, _ = x.shape
    g, per_group, tail = _split(cfg)

    if mode == "decode":
        positions = cache["pos"][:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    shared = params["shared_attn"]
    prefill_lengths = None
    if mode == "prefill" and batch.get("lengths") is not None:
        prefill_lengths = jnp.asarray(batch["lengths"], jnp.int32)

    paged = mode == "decode" and cache is not None and "block_tables" in cache
    if paged:
        # both pool kinds ride the group scan as carry: the mamba slabs
        # walk a running flat layer index, the shared-attn block pools walk
        # the group index — one per-stream block table serves every group
        # plane (storage is disjoint per plane, the table is not)
        kp, vp = cache["attn"]
        cs, ss = cache["mamba"]
        tables, slabs, pos = (cache["block_tables"], cache["slab_ids"],
                              cache["pos"])

        def mamba_step(c2, lp):
            xx, cs, ss, li = c2
            xx, (cs, ss) = _mamba_sub(cfg, lp, xx, mode=mode,
                                      layer_cache=(cs, ss, li, slabs))
            return (xx, cs, ss, li + 1), None

        def group_body(carry, gp):
            x, kp, vp, cs, ss, gidx = carry
            (x, cs, ss, _), _ = jax.lax.scan(
                mamba_step, (x, cs, ss, gidx * per_group), gp)
            h = L.rms_norm(x, shared["ln1"]["scale"], cfg.norm_eps)
            lc = (kp, vp, gidx, tables, pos)
            out, (kp, vp) = L.attention(cfg, shared["attn"], h,
                                        positions=positions, layer_cache=lc)
            x = x + out
            h = L.rms_norm(x, shared["ln2"]["scale"], cfg.norm_eps)
            x = x + L.mlp(cfg, shared["mlp"], h)
            return (x, kp, vp, cs, ss, gidx + 1), None

        carry = (x, kp, vp, cs, ss, jnp.int32(0))
        (x, kp, vp, cs, ss, _), _ = jax.lax.scan(group_body, carry,
                                                 params["groups"])
        if tail:
            (x, cs, ss, _), _ = jax.lax.scan(
                mamba_step, (x, cs, ss, jnp.int32(g * per_group)),
                params["tail"])

        x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, table,
                            preferred_element_type=jnp.float32)
        logits = shd.shard_logits(logits)
        new_cache = {"attn": (kp, vp), "mamba": (cs, ss),
                     "pos": cache["pos"] + 1, "block_tables": tables,
                     "slab_ids": slabs}
        return logits, new_cache, jnp.zeros((), jnp.float32)

    def group_body(carry, inp):
        x = carry
        if mode == "decode":
            gp, (mc, ac) = inp
        else:
            gp, mc, ac = inp, None, None

        def inner(carry2, inp2):
            xx = carry2
            if mode == "decode":
                lp, lc = inp2
                lc = lc + (cache["pos"],)
            else:
                lp, lc = inp2, None
            xx, c = _mamba_sub(cfg, lp, xx, mode=mode, layer_cache=lc,
                               lengths=prefill_lengths)
            return xx, c

        inner_xs = (gp, mc) if mode == "decode" else gp
        x, mamba_caches = jax.lax.scan(inner, x, inner_xs)

        # shared attention block
        h = L.rms_norm(x, shared["ln1"]["scale"], cfg.norm_eps)
        lc = ac + (cache["pos"],) if mode == "decode" else None
        out, attn_c = L.attention(cfg, shared["attn"], h, positions=positions,
                                  cache="build" if mode == "prefill" else None,
                                  layer_cache=lc)
        x = x + out
        h = L.rms_norm(x, shared["ln2"]["scale"], cfg.norm_eps)
        x = x + L.mlp(cfg, shared["mlp"], h)
        return x, (mamba_caches, attn_c)

    body = jax.checkpoint(group_body, policy=remat_policy) if remat else group_body
    xs = (params["groups"], (cache["groups_mamba"], cache["groups_attn"])) \
        if mode == "decode" else params["groups"]
    x, (grp_mamba_c, grp_attn_c) = jax.lax.scan(body, x, xs)

    tail_c = None
    if tail:
        def tail_body(carry, inp):
            xx = carry
            if mode == "decode":
                lp, lc = inp
                lc = lc + (cache["pos"],)
            else:
                lp, lc = inp, None
            xx, c = _mamba_sub(cfg, lp, xx, mode=mode, layer_cache=lc,
                               lengths=prefill_lengths)
            return xx, c

        tail_xs = (params["tail"], cache["tail"]) if mode == "decode" else params["tail"]
        x, tail_c = jax.lax.scan(tail_body, x, tail_xs)

    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, table, preferred_element_type=jnp.float32)
    logits = shd.shard_logits(logits)

    if mode == "train":
        return logits, None, jnp.zeros((), jnp.float32)

    new_cache = {"groups_mamba": grp_mamba_c, "groups_attn": grp_attn_c}
    if tail:
        new_cache["tail"] = tail_c
    if mode == "prefill":
        new_cache["pos"] = (prefill_lengths if prefill_lengths is not None
                            else jnp.full((b,), s, jnp.int32))
        max_seq = batch.get("max_seq", s)
        new_cache["groups_attn"] = jax.tree.map(
            lambda a: _pad_seq(a, 2, max_seq), new_cache["groups_attn"])
    else:
        new_cache["pos"] = cache["pos"] + 1
    new_cache["groups_attn"] = jax.tree.map(
        lambda a: shd.shard_cache_seq(a, batch_axis=1, seq_axis=2),
        new_cache["groups_attn"])
    return logits, new_cache, jnp.zeros((), jnp.float32)


def _pad_seq(x, axis: int, target: int):
    if x.shape[axis] == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pads)
