"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation within chunks + a sequential state pass between chunks (O(S)
overall).  Decode is a single recurrent state update.

The intra-chunk computation is the compute hot-spot; kernels/ssd_scan.py
provides the Pallas TPU kernel, with ``ssd_chunked`` here as the pure-jnp
oracle (re-exported by kernels/ref.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.layers import Params, dense_init, rms_norm

DEFAULT_CHUNK = 256


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state_dim


def init_mamba2(cfg, key, dtype) -> Params:
    from repro.models import perf

    d = cfg.d_model
    d_in = cfg.d_inner
    h = cfg.ssm_nheads
    cdim = conv_dim(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {
        "conv_w": dense_init(ks[1], (cfg.conv_width, cdim), dtype,
                             scale=1.0 / math.sqrt(cfg.conv_width)),
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "ssm_D": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }
    if perf.current().split_ssm_proj:
        # §Perf: three cleanly-TP-shardable projections instead of one fused
        # matmul whose output width (d_in + cdim + h) rarely divides the
        # model axis (which forces fully replicated compute)
        p["z_proj"] = dense_init(ks[0], (d, d_in), dtype)
        p["xbc_proj"] = dense_init(jax.random.fold_in(ks[0], 1), (d, cdim), dtype)
        p["dt_proj"] = dense_init(jax.random.fold_in(ks[0], 2), (d, h), dtype)
    else:
        p["in_proj"] = dense_init(ks[0], (d, d_in + cdim + h), dtype)
    return p


def _in_projections(cfg, p: Params, x):
    """-> (z (B,S,d_in), xBC (B,S,cdim), dt_raw (B,S,H))."""
    d_in = cfg.d_inner
    cdim = conv_dim(cfg)
    if "in_proj" in p:
        zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
        return jnp.split(zxbcdt, [d_in, d_in + cdim], axis=-1)
    z = jnp.einsum("bsd,de->bse", x, p["z_proj"])
    xBC = jnp.einsum("bsd,de->bse", x, p["xbc_proj"])
    dt = jnp.einsum("bsd,de->bse", x, p["dt_proj"])
    return z, xBC, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv1d.  x (B,S,C), w (W,C) -> (B,S,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    # sum of shifted slices: cheap and fusion-friendly for small W
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(width):
        out = out + pad[:, i:i + s, :] * w[i]
    return out + b


def ssd_chunked(x, dt, A, B, C, *, chunk: int):
    """Chunked SSD scan (pure jnp oracle).

    x  (B,S,H,P)   per-head inputs
    dt (B,S,H)     positive step sizes (softplus applied by caller)
    A  (H,)        negative per-head decay rates
    B  (B,S,G,N)   input projections  (G groups broadcast over H)
    C  (B,S,G,N)   output projections
    returns y (B,S,H,P), final_state (B,H,P,N)
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g  # heads per group

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    dA = dtc * A  # (B,NC,Q,H), negative
    seg = jnp.cumsum(dA, axis=2)  # within-chunk cumulative decay

    # intra-chunk (attention-like): L[i,j] = exp(seg_i - seg_j) for i >= j.
    # Mask INSIDE the exp: masked entries have seg_i - seg_j > 0 and exp
    # overflows to inf, which would turn the where-gradient into inf*0=NaN.
    li = seg[:, :, :, None, :]  # (B,NC,Q,1,H)
    lj = seg[:, :, None, :, :]  # (B,NC,1,Q,H)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], li - lj, -1e30))

    # scores: C_i . B_j per group
    cb = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)  # (B,NC,Q,Q,G)
    cb = jnp.repeat(cb, hg, axis=-1)  # broadcast groups -> heads
    w = cb * L  # (B,NC,Q,Q,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # dt-weighted inputs
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xdt)

    # chunk summaries: S_c = sum_j exp(seg_last - seg_j) * dt_j B_j x_j^T
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)  # (B,NC,Q,H)
    Bh = jnp.repeat(Bc, hg, axis=3)  # (B,NC,Q,H,N)
    s_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", decay_to_end * dtc, Bh, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(seg[:, :, -1, :])  # (B,NC,H)

    # inter-chunk: h_c = chunk_decay_c * h_{c-1} + S_c (sequential over NC)
    def step(hprev, inp):
        dec, sc = inp
        hnew = dec[:, :, None, None] * hprev + sc
        return hnew, hprev  # emit the state *entering* the chunk

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, h_in = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_c, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,NC,H,P,N)

    # inter-chunk contribution: y_i += exp(seg_i) * C_i . h_in
    Ch = jnp.repeat(Cc, hg, axis=3)  # (B,NC,Q,H,N)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Ch * jnp.exp(seg)[..., None], h_in)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, B, C):
    """One recurrent step.  state (B,H,P,N); x (B,H,P); dt (B,H);
    B,C (B,G,N).  Returns (y (B,H,P), state')."""
    bsz, h, p, n = state.shape
    g = B.shape[1]
    hg = h // g
    dt = dt.astype(jnp.float32)
    dec = jnp.exp(dt * A)  # (B,H)
    Bh = jnp.repeat(B, hg, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(C, hg, axis=1).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32), Bh)
    state = dec[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x.dtype), state


def mamba2_block(cfg, p: Params, x, *, layer_cache=None, chunk: int | None = None):
    """Full Mamba-2 mixer.

    Training/prefill: layer_cache None (or 'build' via cache arg semantics of
    callers — here we always return (out, cache_tuple or None)).
    Decode: layer_cache = (conv_cache (B,W-1,C), state (B,H,P,N), pos).
    Slab-paged decode: layer_cache = (conv_stack (Lm,NS,W-1,C), state_stack
    (Lm,NS,H,P,N) fp32, lidx, slabs (B,) int32) — the constant-size per-
    stream state lives in a SLAB pool shared by all rows; each row gathers
    its slab, steps the recurrence, and scatters the slab back (state never
    grows, so "paging" is pure slot indirection, no block tables).
    """
    b, s, d = x.shape
    d_in = cfg.d_inner
    h = cfg.ssm_nheads
    n = cfg.ssm_state_dim
    g = cfg.ssm_ngroups
    cdim = conv_dim(cfg)

    z, xBC, dt = _in_projections(cfg, p, x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (H,)

    if layer_cache is None:
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        xs, B, C = jnp.split(xBC, [d_in, d_in + g * n], axis=-1)
        xs = xs.reshape(b, s, h, cfg.ssm_head_dim)
        B = B.reshape(b, s, g, n)
        C = C.reshape(b, s, g, n)
        from repro.models import perf

        cq = min(chunk or perf.current().ssd_chunk, s)
        while s % cq:
            cq //= 2
        y, final = ssd_chunked(xs, dt, A, B, C, chunk=max(cq, 1))
        y = y + xs * p["ssm_D"].astype(xs.dtype)[None, None, :, None]
        new_cache = None
        conv_tail = None
        if s >= cfg.conv_width - 1:
            conv_tail = xBC  # caller may slice the tail for cache build
        y = y.reshape(b, s, d_in)
    else:
        paged = len(layer_cache) == 4
        if paged:
            conv_stack, state_stack, lidx, slabs = layer_cache
            conv_cache = conv_stack[lidx, slabs]  # (B,W-1,C)
            state = state_stack[lidx, slabs]  # (B,H,P,N) fp32
        else:
            conv_cache, state, pos = layer_cache  # (B,W-1,C), (B,H,P,N)
        win = jnp.concatenate([conv_cache.astype(xBC.dtype), xBC], axis=1)
        conv_out = jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"]
        xBC_t = jax.nn.silu(conv_out)[:, None, :]  # (B,1,C)
        xs, B, C = jnp.split(xBC_t[:, 0], [d_in, d_in + g * n], axis=-1)
        xs = xs.reshape(b, h, cfg.ssm_head_dim)
        B = B.reshape(b, g, n)
        C = C.reshape(b, g, n)
        y, state = ssd_decode_step(state, xs, dt[:, 0], A, B, C)
        y = y + xs * p["ssm_D"].astype(xs.dtype)[None, :, None]
        y = y.reshape(b, 1, d_in)
        if paged:
            conv_stack = conv_stack.at[lidx, slabs].set(
                win[:, 1:, :].astype(conv_stack.dtype))
            state_stack = state_stack.at[lidx, slabs].set(state)
            new_cache = (conv_stack, state_stack)
        else:
            new_cache = (win[:, 1:, :], state)

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return shd.shard_hidden(out), new_cache


def prefill_mamba_cache(cfg, p: Params, x, dt_unused=None, *, lengths=None):
    """Run the block in training mode AND build the decode cache: returns
    (out, (conv_cache, state)).

    ``lengths`` (B,) int32 makes a PADDED (length-bucketed) prefill exact:
    dt is forced to 0 past each row's true length, so padded positions
    contribute identity decay (exp(0) = 1) and a zero input term — the
    final state equals the state at the true length — and the conv tail is
    gathered per row ending at its true length instead of at the padded
    end.  ``lengths=None`` keeps the exact-length single-sequence path
    bit-identical to before."""
    b, s, d = x.shape
    d_in = cfg.d_inner
    h = cfg.ssm_nheads
    n = cfg.ssm_state_dim
    g = cfg.ssm_ngroups
    cdim = conv_dim(cfg)

    z, xBC_raw, dt = _in_projections(cfg, p, x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if lengths is not None:
        valid = jnp.arange(s)[None, :] < lengths[:, None]  # (B,S)
        dt = jnp.where(valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"])
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xs, B, C = jnp.split(xBC, [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(b, s, h, cfg.ssm_head_dim)
    B = B.reshape(b, s, g, n)
    C = C.reshape(b, s, g, n)
    from repro.models import perf

    cq = min(perf.current().ssd_chunk, s)
    while s % cq:
        cq //= 2
    y, final = ssd_chunked(xs, dt, A, B, C, chunk=max(cq, 1))
    y = y + xs * p["ssm_D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    w = cfg.conv_width
    if lengths is None:
        conv_cache = xBC_raw[:, -(w - 1):, :] if s >= w - 1 else jnp.pad(
            xBC_raw, ((0, 0), (w - 1 - s, 0), (0, 0)))
    else:
        # per-row tail: the W-1 raw conv inputs ENDING at each true length
        # (front-pad with W-1 zeros so short rows read zeros, exactly what
        # the causal conv saw)
        padded = jnp.pad(xBC_raw, ((0, 0), (w - 1, 0), (0, 0)))
        conv_cache = jax.vmap(
            lambda row, ln: jax.lax.dynamic_slice_in_dim(row, ln, w - 1,
                                                         axis=0)
        )(padded, lengths)
    return shd.shard_hidden(out), (conv_cache, final)
