"""Paged KV-cache management for the serving engine.

vLLM-style block tables adapted to TPU constraints: the cache pool is a
dense (num_blocks, block_size, n_kv, head_dim) tensor per layer (TPU wants
dense gathers, not pointer chasing); each stream owns a list of block ids;
the block table (max_blocks_per_seq int32 per slot) is the indirection the
decode gather uses.

This module is the HOST-side allocator + table builder:
  * allocate/extend/free with O(1) free-list ops;
  * copy-on-write sharing for common prefixes (prefix caching), with
    reference counts — the paper's server has central knowledge of all
    requests (§7), which is what makes cross-stream prefix sharing safe to
    coordinate;
  * fragmentation-free by construction (fixed-size blocks).

The device-side gather (cache[block_table] -> contiguous view) is exercised
in tests with the pure-jnp reference; the Pallas decode kernel consumes the
same layout one block column at a time.
"""

from __future__ import annotations

from dataclasses import dataclass, field


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class SeqAlloc:
    blocks: list[int] = field(default_factory=list)
    length: int = 0  # tokens written


class PagedKVCacheManager:
    def __init__(self, *, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: list[int] = list(range(num_blocks - 1, -1, -1))
        self.refcount = [0] * num_blocks
        self.seqs: dict[str, SeqAlloc] = {}

    # -- allocation ---------------------------------------------------------
    def _take_block(self) -> int:
        if not self.free:
            raise OutOfBlocksError("KV cache pool exhausted")
        b = self.free.pop()
        self.refcount[b] = 1
        return b

    def allocate(self, seq_id: str, num_tokens: int) -> list[int]:
        """Allocate blocks for a fresh sequence of ``num_tokens``."""
        if seq_id in self.seqs:
            raise ValueError(f"{seq_id!r} already allocated")
        n = self._blocks_for(num_tokens)
        if len(self.free) < n:
            raise OutOfBlocksError(
                f"need {n} blocks, {len(self.free)} free")
        alloc = SeqAlloc([self._take_block() for _ in range(n)], num_tokens)
        self.seqs[seq_id] = alloc
        return list(alloc.blocks)

    def extend(self, seq_id: str, new_tokens: int = 1) -> list[int]:
        """Grow a sequence; returns newly allocated block ids (often [])."""
        a = self.seqs[seq_id]
        target = self._blocks_for(a.length + new_tokens)
        fresh = []
        while len(a.blocks) < target:
            # copy-on-write: a shared tail block must be forked before write
            fresh.append(self._take_block())
            a.blocks.append(fresh[-1])
        # forking a shared final block on write
        last = a.blocks[-1]
        if self.refcount[last] > 1 and (a.length % self.block_size or new_tokens):
            fork = self._take_block()
            self.refcount[last] -= 1
            a.blocks[-1] = fork
            fresh.append(fork)
        a.length += new_tokens
        return fresh

    def fork(self, src_id: str, dst_id: str) -> None:
        """Share ``src``'s blocks with a new sequence (prefix caching)."""
        if dst_id in self.seqs:
            raise ValueError(f"{dst_id!r} already allocated")
        src = self.seqs[src_id]
        for b in src.blocks:
            self.refcount[b] += 1
        self.seqs[dst_id] = SeqAlloc(list(src.blocks), src.length)

    def free_seq(self, seq_id: str) -> None:
        a = self.seqs.pop(seq_id)
        for b in a.blocks:
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                self.free.append(b)

    # -- tables -------------------------------------------------------------
    def block_table(self, seq_id: str, *, max_blocks: int) -> list[int]:
        """Padded block table row for the device-side gather (pad = 0 with
        the length masking the tail, matching decode_attention's lengths)."""
        a = self.seqs[seq_id]
        if len(a.blocks) > max_blocks:
            raise ValueError("sequence exceeds max_blocks")
        return a.blocks + [0] * (max_blocks - len(a.blocks))

    def length(self, seq_id: str) -> int:
        return self.seqs[seq_id].length

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free)

    def utilization(self) -> float:
        return self.blocks_in_use / self.num_blocks

    def _blocks_for(self, tokens: int) -> int:
        return max(1, -(-tokens // self.block_size))
