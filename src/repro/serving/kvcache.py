"""Paged cache management for the serving engine — all cache families.

vLLM-style block tables adapted to TPU constraints: the cache pool is a
dense (num_blocks, block_size, n_kv, head_dim) tensor per layer (TPU wants
dense gathers, not pointer chasing); each stream owns a list of block ids;
the block table (max_blocks_per_seq int32 per slot) is the indirection the
decode gather uses.

This module is the HOST-side allocator + table builder:
  * allocate/extend/free with O(1) free-list ops;
  * copy-on-write sharing for common prefixes (prefix caching), with
    reference counts — the paper's server has central knowledge of all
    requests (§7), which is what makes cross-stream prefix sharing safe to
    coordinate;
  * fragmentation-free by construction (fixed-size blocks).

Cache families
--------------
Not every architecture caches GQA-shaped KV, so the allocator manages
three POOL KINDS and a :class:`CacheFamily` spec says which ones a model
needs:

  * BLOCK pools — growable per-token attention KV (GQA k/v stacks, MLA
    latent c_kv/k_rope).  Fixed-size blocks, COW refcounts, the classic
    layout above.
  * SLAB pools — constant-size per-stream state (SSM conv tail +
    recurrent state).  One slab id per sequence, never grows, never
    shared (a fork gets a FRESH slab; the engine copies the contents).
  * SEGMENT pools — read-only-after-prefill shareable caches (enc-dec
    cross-attention KV).  Acquired by CONTENT KEY with refcounts: two
    streams decoding against the same encoder output share one segment
    (COW-dedup of shared prefixes / system prompts); the last release
    frees it.

  family   blocks  slab  segment   models
  ------   ------  ----  -------   -------------------------------------
  gqa        x                     llama/qwen/internlm/granite/vlm
  mla        x                     deepseek (latent cache, smaller rows)
  ssm                x             mamba2
  hybrid     x       x             zamba2 (shared-attn + mamba groups)
  encdec     x            x        whisper (self-KV blocks + cross seg)

To ADD a family: register a :class:`CacheFamily` in :data:`FAMILIES`,
declare ``cache_family`` on the config (or teach
``models.model.cache_family`` to derive it), return matching device pools
from the model's ``init_paged_cache`` (``pools`` dict + ``pool_kinds``
kind map), and give the model a paged decode branch that consumes the
per-kind index arrays the engine stages (block table row / slab id /
segment id).  The allocator here is family-agnostic beyond the three
kinds.

Device-side data path (the paged batched decode hot loop):

  block POOL (device)       one zero pool per server & layer,
    (num_blocks, block_size, n_kv, head_dim)    built by
    ``models.model.init_paged_cache``;          prefill KV is scattered
    into a stream's reserved blocks once (ServeEngine._insert_paged_impl)
        │
  block TABLE (host->device)   this manager's per-sequence block list,
    (rows, W) int32            padded row built by :meth:`block_table`;
        │                      W covers only the LIVE rows' lengths
        ▼                      (power-of-two bucketed per step)
  paged gather-attend       pool[tables] -> (rows, W*block_size, ...) view,
                            masked past ``lengths``; kernels/
                            paged_decode_attention.py does the same via
                            scalar-prefetch indirection, one block per
                            grid step, early-exiting past each length

Slab pools skip the table: the staged row carries the slab id and the
model gathers/scatters ``state_pool[slab]`` directly.  Segment pools are
gather-only (read-only after prefill): the staged row carries the segment
id and the decode scan reads ``seg_pool[seg]`` without ever writing it.

When does which knob kick in (ServeEngine, paged=True):
  * slot COMPACTION — every step: only live rows enter the device call,
    padded to the next power of two; the call narrows whenever fewer than
    half the slots are decoding (pow2(n) < max_batch <=> n <= max_batch/2).
  * length BUCKETING — every step for the gather width W (pow2 of the
    longest live row's block count); at prefill, same-bucket prompts
    coalesce under batch_key ("prefill", server, bucket).  Slab-only
    families have no gather width — their single decode cell is width 0.

Exact per-stream lengths stay HERE, host-side: the device never sees a
length it doesn't need, and the analysis side keeps its per-request bounds
(declared WCET = full-width call; compaction/bucketing only shrink).

Migration protocol (live cross-server stream moves)
---------------------------------------------------
A stream's live cache can move from server A's pool to server B's pool
without recomputation.  The host-side half lives here; the device-side
half (one gather, one host copy, one scatter) is
``ServeEngine._execute_migration``:

  1. ``export_seq(seq_id)`` on the SOURCE manager snapshots the sequence
     into a frozen :class:`SeqExport` — the exact block-id order, token
     length, whether a slab rides along, and the segment content key.
     The source allocation stays live (blocks still owned) so the
     stream can keep decoding or abort cleanly until commit.
  2. ``import_seq(export)`` on the DESTINATION manager allocates the same
     number of FRESH private blocks (refcount 1 each) under the same
     seq_id, a fresh slab if the export carries one, and acquires the
     segment by key (joining an existing shared segment on B if one
     stream already holds that key).  COW block sharing is intentionally
     not preserved across pools: the destination copy is private, so a
     forked sibling left behind on the source keeps its shared blocks
     untouched.  Raises :class:`OutOfBlocksError` with the destination
     unchanged (all-or-nothing across every pool kind).
  3. The engine gathers ``pool[:, export.blocks]`` (and the slab /
     segment rows) on A (pow2-padded table so a precompiled "migrate"
     cell is reused — no mid-traffic trace), copies once through the
     host, scatters into the fresh ids on B, then COMMITS: ``free_seq``
     on the source, decode resumes on B.  Greedy tokens are bit-identical
     because pool contents and the (blocks, length) mapping are copied
     exactly.

Atomicity w.r.t. ``ServeEngine.remove``: the engine holds both sides in
its ``_held`` ledger for the whole window and serializes commit/abort
against ``remove`` under one lock, so a concurrent remove frees each
side exactly once (``free_seq(..., missing_ok=True)`` makes the race
idempotent, never a double-free).
"""

from __future__ import annotations

from dataclasses import dataclass, field


class OutOfBlocksError(RuntimeError):
    """Any pool kind (blocks, slabs, segments) is exhausted.  One type on
    purpose: the engine's backpressure path treats every kind the same."""


@dataclass(frozen=True)
class CacheFamily:
    """Which pool kinds a model family's cache needs (see module doc)."""

    name: str
    uses_blocks: bool = True
    uses_slab: bool = False
    uses_segment: bool = False

    @property
    def kinds(self) -> tuple[str, ...]:
        out = []
        if self.uses_blocks:
            out.append("block")
        if self.uses_slab:
            out.append("slab")
        if self.uses_segment:
            out.append("segment")
        return tuple(out)


FAMILIES: dict[str, CacheFamily] = {
    "gqa": CacheFamily("gqa"),
    "mla": CacheFamily("mla"),
    "ssm": CacheFamily("ssm", uses_blocks=False, uses_slab=True),
    "hybrid": CacheFamily("hybrid", uses_slab=True),
    "encdec": CacheFamily("encdec", uses_segment=True),
}


@dataclass
class SeqAlloc:
    blocks: list[int] = field(default_factory=list)
    length: int = 0  # tokens written
    slab: int | None = None
    segment: int | None = None
    segment_key: str | None = None


@dataclass(frozen=True)
class SeqExport:
    """Host-side snapshot of one sequence for cross-pool migration: the
    source pool's block ids in table order, the token length, whether a
    state slab rides along, and the shared-segment content key.  Pool
    *contents* travel separately (the engine's gather/scatter pair); this
    carries exactly what :meth:`PagedKVCacheManager.import_seq` needs to
    rebuild the allocation on another pool."""

    seq_id: str
    blocks: tuple[int, ...]
    length: int
    has_slab: bool = False
    segment_key: str | None = None


class PagedKVCacheManager:
    def __init__(self, *, num_blocks: int, block_size: int,
                 num_slabs: int = 0, num_segments: int = 0,
                 family: str | CacheFamily | None = None):
        if family is None:
            family = FAMILIES["gqa"]
        elif isinstance(family, str):
            family = FAMILIES[family]
        self.family = family
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: list[int] = list(range(num_blocks - 1, -1, -1))
        self.refcount = [0] * num_blocks
        self.seqs: dict[str, SeqAlloc] = {}
        # -- slab pool (constant-size per-stream state, unshared) --
        self.num_slabs = num_slabs
        self.free_slabs: list[int] = list(range(num_slabs - 1, -1, -1))
        # -- segment pool (read-only shared caches, keyed + refcounted) --
        self.num_segments = num_segments
        self.free_segments: list[int] = list(range(num_segments - 1, -1, -1))
        self.segment_refcount = [0] * num_segments
        self.segments: dict[str, int] = {}  # content key -> segment id

    # -- allocation ---------------------------------------------------------
    def _take_block(self) -> int:
        if not self.free:
            raise OutOfBlocksError("KV cache block pool exhausted")
        b = self.free.pop()
        self.refcount[b] = 1
        return b

    def _take_slab(self) -> int:
        if not self.free_slabs:
            raise OutOfBlocksError("state slab pool exhausted")
        return self.free_slabs.pop()

    def acquire_segment(self, key: str) -> tuple[int, bool]:
        """Refcounted acquire of the shared read-only segment for ``key``.
        Returns ``(segment_id, fresh)`` — ``fresh`` is True when this call
        allocated the segment (the caller must write its contents; joining
        callers must NOT, the contents are already live and shared)."""
        if key in self.segments:
            seg = self.segments[key]
            self.segment_refcount[seg] += 1
            return seg, False
        if not self.free_segments:
            raise OutOfBlocksError("shared segment pool exhausted")
        seg = self.free_segments.pop()
        self.segment_refcount[seg] = 1
        self.segments[key] = seg
        return seg, True

    def release_segment(self, seg: int) -> None:
        """Drop one reference; the last release returns the segment to the
        free list and retires its content key."""
        self.segment_refcount[seg] -= 1
        if self.segment_refcount[seg] == 0:
            self.free_segments.append(seg)
            for k, v in list(self.segments.items()):
                if v == seg:
                    del self.segments[k]

    def allocate(self, seq_id: str, num_tokens: int, *,
                 segment_key: str | None = None) -> list[int]:
        """Allocate every pool kind the family needs for a fresh sequence
        of ``num_tokens``; returns the block ids (empty for slab-only
        families).  All-or-nothing across kinds: exhaustion of any pool
        leaves the manager unchanged."""
        if seq_id in self.seqs:
            raise ValueError(f"{seq_id!r} already allocated")
        fam = self.family
        n = self._blocks_for(num_tokens) if fam.uses_blocks else 0
        if len(self.free) < n:
            raise OutOfBlocksError(f"need {n} blocks, {len(self.free)} free")
        if fam.uses_slab and not self.free_slabs:
            raise OutOfBlocksError("state slab pool exhausted")
        if (fam.uses_segment and segment_key not in self.segments
                and not self.free_segments):
            raise OutOfBlocksError("shared segment pool exhausted")
        alloc = SeqAlloc([self._take_block() for _ in range(n)], num_tokens)
        if fam.uses_slab:
            alloc.slab = self._take_slab()
        if fam.uses_segment:
            key = segment_key if segment_key is not None else seq_id
            alloc.segment, _ = self.acquire_segment(key)
            alloc.segment_key = key
        self.seqs[seq_id] = alloc
        return list(alloc.blocks)

    def extend(self, seq_id: str, new_tokens: int = 1) -> list[int]:
        """Grow a sequence; returns newly allocated block ids (often []).

        Copy-on-write: the fork decision is made BEFORE any blocks are
        appended — if the first new token lands in a shared, partially-
        filled tail block (``length % block_size != 0`` and refcount > 1),
        that tail is forked; a full shared tail needs no fork because new
        tokens only ever touch freshly appended blocks.  Slabs and
        segments are constant-size — only the length advances."""
        a = self.seqs[seq_id]
        if not self.family.uses_blocks:
            a.length += new_tokens
            return []
        fresh = []
        if new_tokens and a.length % self.block_size:
            last = a.blocks[-1]
            if self.refcount[last] > 1:
                fork = self._take_block()
                self.refcount[last] -= 1
                a.blocks[-1] = fork
                fresh.append(fork)
        target = self._blocks_for(a.length + new_tokens)
        while len(a.blocks) < target:
            fresh.append(self._take_block())
            a.blocks.append(fresh[-1])
        a.length += new_tokens
        return fresh

    def fork(self, src_id: str, dst_id: str) -> None:
        """Share ``src``'s blocks with a new sequence (prefix caching).
        Blocks share via COW refcounts; a shared segment gains a reference
        (read-only, so true sharing); a slab is NEVER shared — the fork
        gets a fresh one (the engine copies its contents)."""
        if dst_id in self.seqs:
            raise ValueError(f"{dst_id!r} already allocated")
        src = self.seqs[src_id]
        if src.slab is not None and not self.free_slabs:
            raise OutOfBlocksError("state slab pool exhausted")
        for b in src.blocks:
            self.refcount[b] += 1
        dst = SeqAlloc(list(src.blocks), src.length)
        if src.slab is not None:
            dst.slab = self._take_slab()
        if src.segment is not None:
            self.segment_refcount[src.segment] += 1
            dst.segment, dst.segment_key = src.segment, src.segment_key
        self.seqs[dst_id] = dst

    def free_seq(self, seq_id: str, *, missing_ok: bool = False) -> None:
        """Release every pool kind a sequence holds.  ``missing_ok`` makes
        the free idempotent — the fault-recovery paths (stream eviction,
        engine ``remove``) may race the generating thread's own cleanup,
        and whichever frees second must be a no-op, not a KeyError."""
        a = self.seqs.pop(seq_id, None)
        if a is None:
            if missing_ok:
                return
            raise KeyError(seq_id)
        for b in a.blocks:
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                self.free.append(b)
        if a.slab is not None:
            self.free_slabs.append(a.slab)
        if a.segment is not None:
            self.release_segment(a.segment)

    # -- migration ----------------------------------------------------------
    def export_seq(self, seq_id: str) -> SeqExport:
        """Snapshot ``seq_id`` for migration (step 1 of the protocol in the
        module docstring).  Pure read: the source allocation stays live and
        owned until the engine commits with :meth:`free_seq`."""
        a = self.seqs[seq_id]
        return SeqExport(seq_id=seq_id, blocks=tuple(a.blocks),
                         length=a.length, has_slab=a.slab is not None,
                         segment_key=a.segment_key)

    def import_seq(self, export: SeqExport) -> list[int]:
        """Rebuild an exported sequence on THIS pool with fresh private
        blocks (step 2 of the protocol); returns the new block ids in the
        same table order as ``export.blocks``.  The block count is
        preserved exactly — including any reservation padding beyond
        ``_blocks_for(length)`` — so a mid-generation move keeps the
        blocks the source had already set aside for upcoming tokens.  A
        slab import gets a fresh slab; a segment import acquires by key
        (joining a same-key segment already live here).  All-or-nothing:
        on exhaustion of ANY kind the pool is left unchanged."""
        if export.seq_id in self.seqs:
            raise ValueError(f"{export.seq_id!r} already allocated")
        n = len(export.blocks)
        if len(self.free) < n:
            raise OutOfBlocksError(
                f"migration needs {n} blocks, {len(self.free)} free")
        if export.has_slab and not self.free_slabs:
            raise OutOfBlocksError("state slab pool exhausted")
        if (export.segment_key is not None
                and export.segment_key not in self.segments
                and not self.free_segments):
            raise OutOfBlocksError("shared segment pool exhausted")
        alloc = SeqAlloc([self._take_block() for _ in range(n)],
                         export.length)
        if export.has_slab:
            alloc.slab = self._take_slab()
        if export.segment_key is not None:
            alloc.segment, _ = self.acquire_segment(export.segment_key)
            alloc.segment_key = export.segment_key
        self.seqs[export.seq_id] = alloc
        return list(alloc.blocks)

    def seq_ids(self, prefix: str = "") -> list[str]:
        """Live sequence ids, optionally filtered by stream-name prefix
        (engine sequence ids are ``f"{stream}#{counter}"``)."""
        return [s for s in self.seqs if s.startswith(prefix)]

    # -- tables -------------------------------------------------------------
    def block_table(self, seq_id: str, *, max_blocks: int) -> list[int]:
        """Padded block table row for the device-side gather (pad = 0 with
        the length masking the tail, matching decode_attention's lengths)."""
        a = self.seqs[seq_id]
        if len(a.blocks) > max_blocks:
            raise ValueError("sequence exceeds max_blocks")
        return a.blocks + [0] * (max_blocks - len(a.blocks))

    def slab(self, seq_id: str) -> int | None:
        return self.seqs[seq_id].slab

    def segment(self, seq_id: str) -> int | None:
        return self.seqs[seq_id].segment

    def length(self, seq_id: str) -> int:
        return self.seqs[seq_id].length

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free)

    @property
    def slabs_in_use(self) -> int:
        return self.num_slabs - len(self.free_slabs)

    @property
    def segments_in_use(self) -> int:
        return self.num_segments - len(self.free_segments)

    def usage(self) -> dict[str, int]:
        """Per-kind live counts — the leak probe's unit of account."""
        return {"blocks": self.blocks_in_use, "slabs": self.slabs_in_use,
                "segments": self.segments_in_use}

    def utilization(self) -> float:
        return self.blocks_in_use / self.num_blocks if self.num_blocks else 0.0

    def _blocks_for(self, tokens: int) -> int:
        return max(1, -(-tokens // self.block_size))
