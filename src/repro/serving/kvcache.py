"""Paged KV-cache management for the serving engine.

vLLM-style block tables adapted to TPU constraints: the cache pool is a
dense (num_blocks, block_size, n_kv, head_dim) tensor per layer (TPU wants
dense gathers, not pointer chasing); each stream owns a list of block ids;
the block table (max_blocks_per_seq int32 per slot) is the indirection the
decode gather uses.

This module is the HOST-side allocator + table builder:
  * allocate/extend/free with O(1) free-list ops;
  * copy-on-write sharing for common prefixes (prefix caching), with
    reference counts — the paper's server has central knowledge of all
    requests (§7), which is what makes cross-stream prefix sharing safe to
    coordinate;
  * fragmentation-free by construction (fixed-size blocks).

Device-side data path (the paged batched decode hot loop):

  block POOL (device)       one zero pool per server & layer,
    (num_blocks, block_size, n_kv, head_dim)    built by
    ``models.model.init_paged_cache``;          prefill KV is scattered
    into a stream's reserved blocks once (ServeEngine._insert_paged_impl)
        │
  block TABLE (host->device)   this manager's per-sequence block list,
    (rows, W) int32            padded row built by :meth:`block_table`;
        │                      W covers only the LIVE rows' lengths
        ▼                      (power-of-two bucketed per step)
  paged gather-attend       pool[tables] -> (rows, W*block_size, ...) view,
                            masked past ``lengths``; kernels/
                            paged_decode_attention.py does the same via
                            scalar-prefetch indirection, one block per
                            grid step, early-exiting past each length

When does which knob kick in (ServeEngine, paged=True):
  * slot COMPACTION — every step: only live rows enter the device call,
    padded to the next power of two; the call narrows whenever fewer than
    half the slots are decoding (pow2(n) < max_batch <=> n <= max_batch/2).
  * length BUCKETING — every step for the gather width W (pow2 of the
    longest live row's block count); at prefill, same-bucket prompts
    coalesce under batch_key ("prefill", server, bucket).

Exact per-stream lengths stay HERE, host-side: the device never sees a
length it doesn't need, and the analysis side keeps its per-request bounds
(declared WCET = full-width call; compaction/bucketing only shrink).

Migration protocol (live cross-server stream moves)
---------------------------------------------------
A stream's live blocks can move from server A's pool to server B's pool
without recomputation.  The host-side half lives here; the device-side
half (one gather, one host copy, one scatter) is
``ServeEngine._execute_migration``:

  1. ``export_seq(seq_id)`` on the SOURCE manager snapshots the sequence
     into a frozen :class:`SeqExport` — the exact block-id order and token
     length.  The source allocation stays live (blocks still owned) so the
     stream can keep decoding or abort cleanly until commit.
  2. ``import_seq(export)`` on the DESTINATION manager allocates the same
     number of FRESH private blocks (refcount 1 each) under the same
     seq_id and returns their ids.  COW sharing is intentionally not
     preserved across pools: the destination copy is private, so a forked
     sibling left behind on the source keeps its shared blocks untouched.
     Raises :class:`OutOfBlocksError` with the destination unchanged.
  3. The engine gathers ``pool[:, export.blocks]`` on A (pow2-padded table
     so a precompiled "migrate" cell is reused — no mid-traffic trace),
     copies once through the host, scatters into the fresh ids on B, then
     COMMITS: ``free_seq`` on the source, decode resumes on B.  Greedy
     tokens are bit-identical because block contents and the (blocks,
     length) mapping are copied exactly.

Atomicity w.r.t. ``ServeEngine.remove``: the engine holds both sides in
its ``_held`` ledger for the whole window and serializes commit/abort
against ``remove`` under one lock, so a concurrent remove frees each
side exactly once (``free_seq(..., missing_ok=True)`` makes the race
idempotent, never a double-free).
"""

from __future__ import annotations

from dataclasses import dataclass, field


class OutOfBlocksError(RuntimeError):
    pass


@dataclass
class SeqAlloc:
    blocks: list[int] = field(default_factory=list)
    length: int = 0  # tokens written


@dataclass(frozen=True)
class SeqExport:
    """Host-side snapshot of one sequence for cross-pool migration: the
    source pool's block ids in table order plus the token length.  Block
    *contents* travel separately (the engine's gather/scatter pair); this
    carries exactly what :meth:`PagedKVCacheManager.import_seq` needs to
    rebuild the allocation on another pool."""

    seq_id: str
    blocks: tuple[int, ...]
    length: int


class PagedKVCacheManager:
    def __init__(self, *, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: list[int] = list(range(num_blocks - 1, -1, -1))
        self.refcount = [0] * num_blocks
        self.seqs: dict[str, SeqAlloc] = {}

    # -- allocation ---------------------------------------------------------
    def _take_block(self) -> int:
        if not self.free:
            raise OutOfBlocksError("KV cache pool exhausted")
        b = self.free.pop()
        self.refcount[b] = 1
        return b

    def allocate(self, seq_id: str, num_tokens: int) -> list[int]:
        """Allocate blocks for a fresh sequence of ``num_tokens``."""
        if seq_id in self.seqs:
            raise ValueError(f"{seq_id!r} already allocated")
        n = self._blocks_for(num_tokens)
        if len(self.free) < n:
            raise OutOfBlocksError(
                f"need {n} blocks, {len(self.free)} free")
        alloc = SeqAlloc([self._take_block() for _ in range(n)], num_tokens)
        self.seqs[seq_id] = alloc
        return list(alloc.blocks)

    def extend(self, seq_id: str, new_tokens: int = 1) -> list[int]:
        """Grow a sequence; returns newly allocated block ids (often []).

        Copy-on-write: the fork decision is made BEFORE any blocks are
        appended — if the first new token lands in a shared, partially-
        filled tail block (``length % block_size != 0`` and refcount > 1),
        that tail is forked; a full shared tail needs no fork because new
        tokens only ever touch freshly appended blocks."""
        a = self.seqs[seq_id]
        fresh = []
        if new_tokens and a.length % self.block_size:
            last = a.blocks[-1]
            if self.refcount[last] > 1:
                fork = self._take_block()
                self.refcount[last] -= 1
                a.blocks[-1] = fork
                fresh.append(fork)
        target = self._blocks_for(a.length + new_tokens)
        while len(a.blocks) < target:
            fresh.append(self._take_block())
            a.blocks.append(fresh[-1])
        a.length += new_tokens
        return fresh

    def fork(self, src_id: str, dst_id: str) -> None:
        """Share ``src``'s blocks with a new sequence (prefix caching)."""
        if dst_id in self.seqs:
            raise ValueError(f"{dst_id!r} already allocated")
        src = self.seqs[src_id]
        for b in src.blocks:
            self.refcount[b] += 1
        self.seqs[dst_id] = SeqAlloc(list(src.blocks), src.length)

    def free_seq(self, seq_id: str, *, missing_ok: bool = False) -> None:
        """Release a sequence's blocks.  ``missing_ok`` makes the free
        idempotent — the fault-recovery paths (stream eviction, engine
        ``remove``) may race the generating thread's own cleanup, and
        whichever frees second must be a no-op, not a KeyError."""
        a = self.seqs.pop(seq_id, None)
        if a is None:
            if missing_ok:
                return
            raise KeyError(seq_id)
        for b in a.blocks:
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                self.free.append(b)

    # -- migration ----------------------------------------------------------
    def export_seq(self, seq_id: str) -> SeqExport:
        """Snapshot ``seq_id`` for migration (step 1 of the protocol in the
        module docstring).  Pure read: the source allocation stays live and
        owned until the engine commits with :meth:`free_seq`."""
        a = self.seqs[seq_id]
        return SeqExport(seq_id=seq_id, blocks=tuple(a.blocks),
                         length=a.length)

    def import_seq(self, export: SeqExport) -> list[int]:
        """Rebuild an exported sequence on THIS pool with fresh private
        blocks (step 2 of the protocol); returns the new block ids in the
        same table order as ``export.blocks``.  The block count is
        preserved exactly — including any reservation padding beyond
        ``_blocks_for(length)`` — so a mid-generation move keeps the
        blocks the source had already set aside for upcoming tokens.
        All-or-nothing: on exhaustion the pool is left unchanged."""
        if export.seq_id in self.seqs:
            raise ValueError(f"{export.seq_id!r} already allocated")
        n = len(export.blocks)
        if len(self.free) < n:
            raise OutOfBlocksError(
                f"migration needs {n} blocks, {len(self.free)} free")
        alloc = SeqAlloc([self._take_block() for _ in range(n)],
                         export.length)
        self.seqs[export.seq_id] = alloc
        return list(alloc.blocks)

    def seq_ids(self, prefix: str = "") -> list[str]:
        """Live sequence ids, optionally filtered by stream-name prefix
        (engine sequence ids are ``f"{stream}#{counter}"``)."""
        return [s for s in self.seqs if s.startswith(prefix)]

    # -- tables -------------------------------------------------------------
    def block_table(self, seq_id: str, *, max_blocks: int) -> list[int]:
        """Padded block table row for the device-side gather (pad = 0 with
        the length masking the tail, matching decode_attention's lengths)."""
        a = self.seqs[seq_id]
        if len(a.blocks) > max_blocks:
            raise ValueError("sequence exceeds max_blocks")
        return a.blocks + [0] * (max_blocks - len(a.blocks))

    def length(self, seq_id: str) -> int:
        return self.seqs[seq_id].length

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free)

    def utilization(self) -> float:
        return self.blocks_in_use / self.num_blocks

    def _blocks_for(self, tokens: int) -> int:
        return max(1, -(-tokens // self.block_size))
