"""Serving engine: the paper's GPU server as the dispatch layer of a JAX
inference runtime — now a multi-server pool with continuous decode batching.

Architecture (one engine per host; one server per device / mesh slice):

  client streams ──admit──▶ PoolAdmissionController (Eqs (1)-(6) per
        │                   device partition; device-assignment = WFD on
        │                   declared accelerator utilization)
        └──submit──▶ ServerPool ──▶ AcceleratorServer / BatchingServer
                         │            (priority queue, §5.1; one request —
                         │             or one BATCH — at a time: XLA is
                         ▼             non-preemptive, like the paper's GPU)
              jitted prefill / masked batched decode steps
                         │
         completion ─────┘ clients suspended on Request.wait()

  * Each stream declares (period, deadline, segment WCETs); admission pins
    it to one server (partitioned, like the paper's per-core partitioning)
    and the pool router follows that assignment for the stream's lifetime.
  * Continuous decode batching (``batching=True``): decode steps from all
    streams assigned to a server share one slot cache of ``max_batch``
    rows.  Each stream owns a slot; its prefill cache is inserted into the
    slot once, and every decode step is a batchable request — the
    BatchingServer coalesces whatever same-server decode steps are queued
    into ONE masked device call (amortizing Lemma 1's 2*eps per request to
    2*eps per batch).  Rows not in the batch are carried through untouched
    (the masked merge), so partial batches are always safe.
  * Per-stream sequence state (generated tokens, the last token, latencies)
    lives in the calling thread, never in the batch: the batch carries only
    (slot, token) pairs.
  * Straggler mitigation: DeadlineAwarePolicy can bump a stream's priority
    or the engine can run the servers in EDF mode.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admission import PoolAdmissionController
from repro.core.dispatch.pool import ServerPool
from repro.core.task_model import GpuSegment, Task
from repro.models import model as M
from repro.runtime.straggler import DeadlineAwarePolicy
from repro.serving.kvcache import PagedKVCacheManager


@dataclass
class StreamSpec:
    name: str
    priority: int
    period_ms: float
    deadline_ms: float
    # declared worst-case segment costs for admission (measured or profiled)
    prefill_ms: float
    decode_ms: float
    decode_steps: int  # decode segments per job (period)
    cpu_ms: float = 0.1


@dataclass
class GenerationResult:
    tokens: list[int] = field(default_factory=list)
    prefill_latency_s: float = 0.0
    decode_latencies_s: list[float] = field(default_factory=list)


class _SlotState:
    """Per-server decode-slot state (touched only on that server's thread,
    except the free-list, which the engine guards with its condition)."""

    def __init__(self, max_batch: int):
        self.free = list(range(max_batch))
        self.cache = None  # lazily built (max_batch rows)
        self.cond = threading.Condition()


def _cache_batch_axes(cfg, max_seq: int):
    """Per-leaf batch axis of the decode cache, discovered by diffing the
    shapes of a 1-row and a 2-row cache (family-agnostic: stacked layer
    leaves are (L,B,...), unstacked ones (B,...))."""
    c1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, max_seq))
    c2 = jax.eval_shape(lambda: M.init_cache(cfg, 2, max_seq))

    def axis(a, b):
        for i, (da, db) in enumerate(zip(a.shape, b.shape)):
            if da != db:
                return i
        raise ValueError(f"no batch axis found in cache leaf {a.shape}")

    return jax.tree.map(axis, c1, c2)


class ServeEngine:
    def __init__(self, cfg, params, *, max_seq: int = 128, batch_size: int = 1,
                 ordering: str = "priority", admission_cores: int = 2,
                 epsilon_ms: float = 0.05, kv_blocks: int = 0,
                 kv_block_size: int = 16, num_servers: int = 1,
                 batching: bool = False, max_batch: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.batching = batching
        self.max_batch = max_batch
        self.pool = ServerPool(num_servers, ordering=ordering,
                               batching=batching, max_batch=max_batch,
                               name="serve-engine")
        self.admission = PoolAdmissionController(
            num_servers, cores_per_device=admission_cores,
            epsilon_ms=epsilon_ms)
        self.straggler = DeadlineAwarePolicy()
        # optional paged-KV accounting: generate() holds block allocations
        # for its sequence's lifetime; exhaustion rejects the request before
        # any device work is dispatched (backpressure at the cache, not OOM)
        self.kv = (PagedKVCacheManager(num_blocks=kv_blocks,
                                       block_size=kv_block_size)
                   if kv_blocks else None)
        self._kv_lock = threading.Lock()
        self._seq_counter = 0
        # max_seq must be static inside the trace (it sizes the cache pad)
        self._prefill = jax.jit(
            lambda p, b: M.apply(cfg, p, {**b, "max_seq": max_seq},
                                 mode="prefill"))
        self._decode = jax.jit(
            lambda p, b, c: M.apply(cfg, p, b, mode="decode", cache=c))
        self._streams: dict[str, StreamSpec] = {}
        if batching:
            self._slots = [_SlotState(max_batch) for _ in range(num_servers)]
            self._batch_axes = _cache_batch_axes(cfg, max_seq)
            self._insert_jit = jax.jit(self._insert_impl)
            self._decode_masked = jax.jit(self._decode_masked_impl)

    @property
    def server(self):
        """The first pool server (single-server back-compat alias)."""
        return self.pool.servers[0]

    # -- stream admission (analysis-driven, Eqs (1)-(6) per partition) -----
    def admit(self, spec: StreamSpec):
        segs = (GpuSegment(e=spec.prefill_ms * 0.9, m=spec.prefill_ms * 0.1),
                *(GpuSegment(e=spec.decode_ms * 0.9, m=spec.decode_ms * 0.1),)
                * spec.decode_steps)
        task = Task(name=spec.name, C=spec.cpu_ms, T=spec.period_ms,
                    D=spec.deadline_ms, segments=segs, priority=spec.priority)
        decision, device = self.admission.try_admit(task)
        if decision.admitted:
            self._streams[spec.name] = spec
            self.straggler.register(spec.name, spec.deadline_ms)
            # the router follows the admission's device-assignment step
            self.pool.assign(spec.name, utilization=task.G / task.T,
                             priority=spec.priority, server=device)
        return decision

    def remove(self, name: str) -> None:
        self.admission.remove(name)
        self.pool.remove(name)
        self._streams.pop(name, None)

    # -- batched decode internals ------------------------------------------
    def _insert_impl(self, full, one, slot):
        """Write a 1-row prefill cache into row ``slot`` of the slot cache."""
        return jax.tree.map(
            lambda f, o, ax: jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), slot, axis=ax),
            full, one, self._batch_axes)

    def _decode_masked_impl(self, params, tokens, cache, active):
        """One batched decode step over the slot cache; rows where ``active``
        is False keep their previous cache (and their logits are garbage,
        discarded by the caller)."""
        logits, new_cache, _ = M.apply(self.cfg, params, {"tokens": tokens},
                                       mode="decode", cache=cache)

        def merge(o, n, ax):
            shape = [1] * n.ndim
            shape[ax] = n.shape[ax]
            return jnp.where(active.reshape(shape), n, o)

        return logits, jax.tree.map(merge, cache, new_cache, self._batch_axes)

    def _acquire_slot(self, si: int) -> int:
        state = self._slots[si]
        with state.cond:
            while not state.free:
                state.cond.wait()
            return state.free.pop()

    def _release_slot(self, si: int, slot: int) -> None:
        state = self._slots[si]
        with state.cond:
            state.free.append(slot)
            state.cond.notify()

    def _insert_slot(self, si: int, slot: int, cache) -> None:
        """Runs on server ``si``'s thread (serialized with its batches)."""
        state = self._slots[si]
        if state.cache is None:
            state.cache = M.init_cache(self.cfg, self.max_batch, self.max_seq)
        state.cache = jax.block_until_ready(
            self._insert_jit(state.cache, cache, jnp.int32(slot)))

    def _run_decode_batch(self, si: int):
        """run_batch callable for server ``si``: payloads are (slot, token)
        pairs; ONE masked device call serves them all."""

        def run(payloads):
            state = self._slots[si]
            slots = np.array([p[0] for p in payloads], np.int32)
            toks = np.zeros((self.max_batch, 1), np.int32)
            toks[slots, 0] = [p[1] for p in payloads]
            active = np.zeros((self.max_batch,), bool)
            active[slots] = True
            logits, state.cache = jax.block_until_ready(
                self._decode_masked(self.params, jnp.asarray(toks),
                                    state.cache, jnp.asarray(active)))
            rows = np.asarray(logits[:, -1], np.float32)
            return [rows[s] for s in slots]

        return run

    # -- generation ---------------------------------------------------------
    def generate(self, name: str, prompt: np.ndarray, *, steps: int,
                 greedy: bool = True) -> GenerationResult:
        """Run one job of stream ``name``: prefill + ``steps`` decode
        segments, each arbitrated by the stream's server.  The calling
        thread suspends between segments (never busy-waits)."""
        if self.batching:
            return self._generate_batched(name, prompt, steps=steps)
        spec = self._streams[name]
        prio = self.straggler.boost(name, spec.priority)
        res = GenerationResult()
        batch = self._prefill_batch(prompt)

        seq_id = self._kv_reserve(name, prompt, steps)
        try:
            t0 = time.monotonic()
            req = self.pool.submit(
                name,
                lambda: jax.block_until_ready(self._prefill(self.params, batch)),
                priority=prio, name=f"{name}/prefill")
            logits, cache, _ = req.wait()
            res.prefill_latency_s = time.monotonic() - t0
            self.straggler.observe(name, res.prefill_latency_s * 1e3)

            last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            for i in range(steps):
                step_batch = {"tokens": last[:, None]}
                t1 = time.monotonic()
                req = self.pool.submit(
                    name,
                    lambda sb=step_batch, c=cache: jax.block_until_ready(
                        self._decode(self.params, sb, c)),
                    priority=prio, name=f"{name}/decode{i}")
                logits, cache, _ = req.wait()
                dt = time.monotonic() - t1
                res.decode_latencies_s.append(dt)
                self.straggler.observe(name, dt * 1e3)
                last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                res.tokens.append(int(last[0]))
        finally:
            self._kv_release(seq_id)
        return res

    def _generate_batched(self, name: str, prompt: np.ndarray, *,
                          steps: int) -> GenerationResult:
        """Continuous-batching path: prefill through the pool, insert into a
        slot, then submit each decode step as a batchable request that the
        server coalesces with other streams' steps."""
        if prompt.shape[0] != 1:
            raise ValueError("batched decode serves one sequence per stream "
                             f"job; got prompt batch {prompt.shape[0]}")
        spec = self._streams[name]
        prio = self.straggler.boost(name, spec.priority)
        si = self.pool.server_of(name)
        res = GenerationResult()
        batch = self._prefill_batch(prompt)

        seq_id = self._kv_reserve(name, prompt, steps)
        try:
            slot = self._acquire_slot(si)
            try:
                t0 = time.monotonic()
                req = self.pool.submit(
                    name,
                    lambda: jax.block_until_ready(
                        self._prefill(self.params, batch)),
                    priority=prio, name=f"{name}/prefill")
                logits, cache, _ = req.wait()
                self.pool.submit(
                    name, lambda: self._insert_slot(si, slot, cache),
                    priority=prio, name=f"{name}/insert").wait()
                res.prefill_latency_s = time.monotonic() - t0
                self.straggler.observe(name, res.prefill_latency_s * 1e3)

                token = int(np.argmax(np.asarray(logits[0, -1], np.float32)))
                run_batch = self._run_decode_batch(si)
                for i in range(steps):
                    t1 = time.monotonic()
                    req = self.pool.submit_batch(
                        name, (slot, token), run_batch=run_batch,
                        batch_key=("decode", si), priority=prio,
                        name=f"{name}/decode{i}")
                    row = req.wait()  # this slot's logits row, np.float32 (V,)
                    dt = time.monotonic() - t1
                    res.decode_latencies_s.append(dt)
                    self.straggler.observe(name, dt * 1e3)
                    token = int(np.argmax(row))
                    res.tokens.append(token)
            finally:
                self._release_slot(si, slot)
        finally:
            self._kv_release(seq_id)
        return res

    # -- shared helpers -----------------------------------------------------
    def _prefill_batch(self, prompt: np.ndarray) -> dict:
        b = prompt.shape[0]
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.encoder_seq, self.cfg.d_model), self.cfg.dtype)
        return batch

    def _kv_reserve(self, name: str, prompt: np.ndarray, steps: int):
        if self.kv is None:
            return None
        with self._kv_lock:
            self._seq_counter += 1
            seq_id = f"{name}#{self._seq_counter}"
            # reserve prompt + all decode tokens up front (reject early
            # rather than stall mid-generation)
            self.kv.allocate(seq_id, prompt.shape[1])
            try:
                self.kv.extend(seq_id, steps)
            except Exception:
                self.kv.free_seq(seq_id)
                raise
            return seq_id

    def _kv_release(self, seq_id) -> None:
        if seq_id is not None:
            with self._kv_lock:
                self.kv.free_seq(seq_id)

    def close(self) -> None:
        self.pool.shutdown()
