"""Serving engine: the paper's GPU server as the dispatch layer of a JAX
inference runtime.

Architecture (one engine per accelerator / mesh slice):

  client streams ──submit──▶ AcceleratorServer (priority queue, §5.1)
                                  │ one request at a time (XLA is
                                  ▼  non-preemptive, like the paper's GPU)
                          jitted prefill / decode steps
                                  │
                  completion ─────┘ clients suspended on Request.wait()

  * Each stream declares (period, deadline, segment WCETs) — an
    AdmissionController (Eqs (1)-(6)) decides whether the stream fits
    before it may submit (beyond-paper: the paper's offline test, online).
  * Straggler mitigation: DeadlineAwarePolicy can bump a stream's priority
    or the engine can run the server in EDF mode (the paper's future-work
    FIFO/alternative-ordering discussion).
  * "GPU segments": a prefill call and each decode call are segments; the
    CPU-side dispatch cost is the paper's G^m, device time is G^e.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admission import AdmissionController
from repro.core.server_runtime import AcceleratorServer
from repro.core.task_model import GpuSegment, Task
from repro.models import model as M
from repro.runtime.straggler import DeadlineAwarePolicy
from repro.serving.kvcache import PagedKVCacheManager


@dataclass
class StreamSpec:
    name: str
    priority: int
    period_ms: float
    deadline_ms: float
    # declared worst-case segment costs for admission (measured or profiled)
    prefill_ms: float
    decode_ms: float
    decode_steps: int  # decode segments per job (period)
    cpu_ms: float = 0.1


@dataclass
class GenerationResult:
    tokens: list[int] = field(default_factory=list)
    prefill_latency_s: float = 0.0
    decode_latencies_s: list[float] = field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg, params, *, max_seq: int = 128, batch_size: int = 1,
                 ordering: str = "priority", admission_cores: int = 2,
                 epsilon_ms: float = 0.05, kv_blocks: int = 0,
                 kv_block_size: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.server = AcceleratorServer(ordering=ordering, name="serve-engine")
        self.admission = AdmissionController(admission_cores, epsilon_ms=epsilon_ms)
        self.straggler = DeadlineAwarePolicy()
        # optional paged-KV accounting: generate() holds block allocations
        # for its sequence's lifetime; exhaustion rejects the request before
        # any device work is dispatched (backpressure at the cache, not OOM)
        self.kv = (PagedKVCacheManager(num_blocks=kv_blocks,
                                       block_size=kv_block_size)
                   if kv_blocks else None)
        self._kv_lock = threading.Lock()
        self._seq_counter = 0
        # max_seq must be static inside the trace (it sizes the cache pad)
        self._prefill = jax.jit(
            lambda p, b: M.apply(cfg, p, {**b, "max_seq": max_seq},
                                 mode="prefill"))
        self._decode = jax.jit(
            lambda p, b, c: M.apply(cfg, p, b, mode="decode", cache=c))
        self._streams: dict[str, StreamSpec] = {}

    # -- stream admission (analysis-driven, Eqs (1)-(6)) -------------------
    def admit(self, spec: StreamSpec):
        segs = (GpuSegment(e=spec.prefill_ms * 0.9, m=spec.prefill_ms * 0.1),
                *(GpuSegment(e=spec.decode_ms * 0.9, m=spec.decode_ms * 0.1),)
                * spec.decode_steps)
        task = Task(name=spec.name, C=spec.cpu_ms, T=spec.period_ms,
                    D=spec.deadline_ms, segments=segs, priority=spec.priority)
        decision = self.admission.try_admit(task)
        if decision.admitted:
            self._streams[spec.name] = spec
            self.straggler.register(spec.name, spec.deadline_ms)
        return decision

    def remove(self, name: str) -> None:
        self.admission.remove(name)
        self._streams.pop(name, None)

    # -- generation ---------------------------------------------------------
    def generate(self, name: str, prompt: np.ndarray, *, steps: int,
                 greedy: bool = True) -> GenerationResult:
        """Run one job of stream ``name``: prefill + ``steps`` decode
        segments, each arbitrated by the server.  The calling thread
        suspends between segments (never busy-waits)."""
        spec = self._streams[name]
        prio = self.straggler.boost(name, spec.priority)
        res = GenerationResult()
        b = prompt.shape[0]
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((b, self.cfg.encoder_seq, self.cfg.d_model),
                                        self.cfg.dtype)

        seq_id = None
        if self.kv is not None:
            with self._kv_lock:
                self._seq_counter += 1
                seq_id = f"{name}#{self._seq_counter}"
                # reserve prompt + all decode tokens up front (reject early
                # rather than stall mid-generation)
                self.kv.allocate(seq_id, prompt.shape[1])
                try:
                    self.kv.extend(seq_id, steps)
                except Exception:
                    self.kv.free_seq(seq_id)
                    raise

        t0 = time.monotonic()
        req = self.server.submit(
            lambda: jax.block_until_ready(self._prefill(self.params, batch)),
            priority=prio, name=f"{name}/prefill")
        logits, cache, _ = req.wait()
        res.prefill_latency_s = time.monotonic() - t0
        self.straggler.observe(name, res.prefill_latency_s * 1e3)

        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for i in range(steps):
            step_batch = {"tokens": last[:, None]}
            t1 = time.monotonic()
            req = self.server.submit(
                lambda sb=step_batch, c=cache: jax.block_until_ready(
                    self._decode(self.params, sb, c)),
                priority=prio, name=f"{name}/decode{i}")
            logits, cache, _ = req.wait()
            dt = time.monotonic() - t1
            res.decode_latencies_s.append(dt)
            self.straggler.observe(name, dt * 1e3)
            last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            res.tokens.append(int(last[0]))
        if seq_id is not None:
            with self._kv_lock:
                self.kv.free_seq(seq_id)
        return res

    def close(self) -> None:
        self.server.shutdown()
