"""Serving engine: the paper's GPU server as the dispatch layer of a JAX
inference runtime — a multi-server pool with continuous, PAGED, length-aware
decode batching.

Architecture (one engine per host; one server per device / mesh slice):

  client streams ──admit──▶ PoolAdmissionController (Eqs (1)-(6) per
        │                   device partition; device-assignment = WFD on
        │                   declared accelerator utilization)
        └──submit──▶ ServerPool ──▶ AcceleratorServer / BatchingServer
                         │            (priority queue, §5.1; one request —
                         │             or one BATCH — at a time: XLA is
                         ▼             non-preemptive, like the paper's GPU)
              jitted prefill / batched decode steps
                         │
         completion ─────┘ clients suspended on Request.wait()

  * Each stream declares (period, deadline, segment WCETs); admission pins
    it to one server (partitioned, like the paper's per-core partitioning)
    and the pool router follows that assignment for the stream's lifetime.
  * Continuous decode batching (``batching=True``): decode steps from all
    streams assigned to a server coalesce into ONE device call (amortizing
    Lemma 1's 2*eps per request to 2*eps per batch).  Two cache layouts:

    masked-dense (default): one slot cache of ``max_batch`` dense rows;
      every step runs over the full (max_batch, max_seq) buffer with
      inactive rows masked and carried through untouched.

    paged (``paged=True``): per-server KV block POOLS (num_blocks,
      block_size, n_kv, head_dim) per layer, with ``PagedKVCacheManager``
      owning the host-side block accounting.  Each step the engine builds a
      COMPACT batch of only the live rows (slot compaction — padded to the
      next power of two, never to max_batch) and a block-table gather whose
      width covers only the live rows' true lengths (bucketed to a power of
      two).  Device cost scales with actual outstanding work — the paper's
      central-knowledge argument (§7) pushed into the device hot path.
      Greedy tokens stay bit-identical to the unbatched dense path: masked
      tail columns contribute exactly zero to the softmax, and pool rows are
      scattered disjointly (no masked merge at all).

  * Batched prefill: prefills are length-bucketed — ``batch_key =
    ("prefill", si, bucket)`` with ``bucket`` the power-of-two pad length —
    so same-bucket prompts from concurrent streams coalesce into one device
    call through the same BatchingServer discipline.  Per-row true lengths
    ride in the batch and become the cache's per-row ``pos``.
  * Per-stream sequence state (generated tokens, the last token, lengths,
    block tables, latencies) lives in the calling thread, never in the
    batch: payloads carry only (token, table, length).
  * Straggler mitigation: DeadlineAwarePolicy can bump a stream's priority
    or the engine can run the servers in EDF mode.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.cost_model import autotune_buckets, bucket_up
from repro.core.admission import PoolAdmissionController
from repro.core.dispatch.pool import ServerPool
from repro.core.faults import ServerFailedError, StreamShedError
from repro.core.task_model import GpuSegment, Task
from repro.models import model as M
from repro.runtime.straggler import DeadlineAwarePolicy, StepTimeWatchdog
from repro.serving.kvcache import (FAMILIES, OutOfBlocksError,
                                   PagedKVCacheManager)


def _pow2ceil(n: int) -> int:
    """Smallest power of two >= n (>= 1): the shape-bucketing rule for
    compacted batch rows, prefill pad lengths, and block-table widths —
    bounds the number of distinct jit traces to O(log) per dimension."""
    return 1 << max(n - 1, 0).bit_length()


def _pow2_ladder(cap: int) -> tuple[int, ...]:
    """Every bucket the pow2-with-clamp rule can produce up to ``cap``:
    1, 2, 4, ... plus ``cap`` itself when cap is not a power of two (the
    runtime clamps ``_pow2ceil`` to the cap, so e.g. max_batch=6 makes the
    live-row counts 5..6 land in a SIX-row cell, not an eight-row one)."""
    out = []
    v = 1
    while v < cap:
        out.append(v)
        v *= 2
    out.append(cap)
    return tuple(out)


@dataclass
class PrecompileReport:
    """What one ``precompile()`` call did: ``compiled`` distinct traces
    warmed now, ``skipped`` reachable/requested cells NOT traced (already
    warm from an earlier call, or filtered out by the traffic model)."""

    compiled: int = 0
    skipped: int = 0
    decode_cells: tuple = ()
    prefill_cells: tuple = ()
    migrate_cells: tuple = ()


@dataclass
class StreamSpec:
    name: str
    priority: int
    period_ms: float
    deadline_ms: float
    # declared worst-case segment costs for admission (measured or profiled)
    prefill_ms: float
    decode_ms: float
    decode_steps: int  # decode segments per job (period)
    cpu_ms: float = 0.1


@dataclass
class GenerationResult:
    tokens: list[int] = field(default_factory=list)
    prefill_latency_s: float = 0.0
    decode_latencies_s: list[float] = field(default_factory=list)
    recoveries: int = 0  # server deaths this job survived
    # monotonic timestamp per recovery at which the retained prefix was
    # re-established on a survivor (resume point, for latency measurement)
    resumed_at_monotonic: list[float] = field(default_factory=list)


@dataclass
class _RecoveryLog:
    """Per-stream-job recovery state: the RETAINED TOKEN PREFIX.

    The first attempt's prefill argmax (``first_token``) is fed to decode
    step 0 but never appended to the result; every decode argmax is
    appended to both the result and ``generated``.  The retained prefix —
    prompt ++ [first_token] ++ generated — is therefore exactly the token
    sequence whose KV the dead server held, so re-prefilling it on a
    survivor puts the cache in the same state the failed decode step saw,
    and its LAST-position argmax equals the token that step would have
    produced: greedy recovered output is bit-identical by construction."""

    prompt: np.ndarray
    first_token: int | None = None
    generated: list[int] = field(default_factory=list)

    def retained_prefix(self) -> np.ndarray:
        if self.first_token is None:
            return self.prompt
        return np.concatenate([
            self.prompt,
            np.asarray([self.first_token], np.int32),
            np.asarray(self.generated, np.int32),
        ])


class _SlotState:
    """Per-server decode-slot state for the masked-dense layout (touched
    only on that server's thread, except the free-list, which the engine
    guards with its condition).  The host-side token/mask staging arrays are
    preallocated once — the decode hot loop must not allocate."""

    def __init__(self, max_batch: int):
        self.free = list(range(max_batch))
        self.cache = None  # lazily built (max_batch rows)
        self.cond = threading.Condition()
        self.tok_scratch = np.zeros((max_batch, 1), np.int32)
        self.active_scratch = np.zeros((max_batch,), bool)


class _PagedState:
    """Per-server paged-cache state: the host-side allocator (blocks, state
    slabs, shared segments — whichever kinds the cache family uses) plus the
    device pools.  ``mgr``/``lock`` are touched from client threads at job
    start/end; ``pools`` and the staging buffers only ever from the server's
    own thread (serialized with its batches)."""

    def __init__(self, cfg, num_blocks: int, block_size: int, max_batch: int,
                 max_seq: int, *, family: str = "gqa", num_slabs: int = 0,
                 num_segments: int = 0):
        self.family = FAMILIES[family]
        self.mgr = PagedKVCacheManager(num_blocks=num_blocks,
                                       block_size=block_size,
                                       num_slabs=num_slabs,
                                       num_segments=num_segments,
                                       family=family)
        self.lock = threading.Lock()
        # table width covering max_seq (0 for slab-only families)
        self.nb_max = max_seq // block_size if self.family.uses_blocks else 0
        # one resource of EACH kind the family uses is held back as the
        # scratch target for padded scatter lanes / unused packed columns;
        # nothing ever reads scratch content
        self.mgr.allocate("__scratch__", 1)
        scratch = self.mgr.seqs["__scratch__"]
        self.scratch_block = scratch.blocks[0] if scratch.blocks else 0
        self.scratch_slab = scratch.slab if scratch.slab is not None else 0
        self.scratch_seg = (scratch.segment if scratch.segment is not None
                            else 0)
        self.pools = None  # lazily built pools dict (family layout)
        # preallocated staging for the compacted decode batch, packed into
        # ONE int32 array so each step pays a single host->device transfer:
        # row = [token, length, slab, segment, block_table...] — a uniform
        # header across families; unused columns carry scratch ids
        self.pack_scratch = np.zeros((max_batch, 4 + self.nb_max), np.int32)


class ServeEngine:
    def __init__(self, cfg, params, *, max_seq: int = 128, batch_size: int = 1,
                 ordering: str = "priority", admission_cores: int = 2,
                 epsilon_ms: float = 0.05, kv_blocks: int = 0,
                 kv_block_size: int = 16, num_servers: int = 1,
                 batching: bool = False, max_batch: int = 8,
                 paged: bool = False, cost_model=None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.batching = batching
        self.max_batch = max_batch
        self.cost_model = cost_model
        if paged and not batching:
            raise ValueError("paged=True requires batching=True (the block "
                             "pools are the batched decode cache layout)")
        if paged and not M.supports_paged(cfg):
            raise ValueError(f"paged decode unsupported for {cfg.family}/"
                             f"{cfg.attn_type}; use paged=False (declare a "
                             "cache_family to enable the paged path)")
        # pool kinds the model's cache family uses ({} when not paged):
        # "block" -> growable KV block pool, "slab" -> fixed-size state slab,
        # "segment" -> refcounted read-only shared segment
        self._pool_kinds = M.paged_pool_kinds(cfg) if paged else {}
        self._cache_kinds = set(self._pool_kinds.values())
        if paged and "block" in self._cache_kinds and max_seq % kv_block_size:
            raise ValueError(f"max_seq={max_seq} must be a multiple of "
                             f"kv_block_size={kv_block_size} for the paged "
                             "layout")
        self.paged = paged
        # family-tagged cost-model phases: GQA keeps the untagged names
        # (back-compat with every recorded cell); other families get their
        # own fit groups so one family's timing never pollutes another's
        self._family = (M.cache_family(cfg) or "gqa") if paged else "gqa"
        _tag = "" if self._family == "gqa" else "@" + self._family
        self._decode_kind = "decode" + _tag
        self._prefill_kind = "prefill" + _tag
        self._migrate_kind = "migrate" + _tag
        self.kv_block_size = kv_block_size
        self.pool = ServerPool(num_servers, ordering=ordering,
                               batching=batching, max_batch=max_batch,
                               name="serve-engine")
        self.admission = PoolAdmissionController(
            num_servers, cores_per_device=admission_cores,
            epsilon_ms=epsilon_ms, cost_model=cost_model)
        self.straggler = DeadlineAwarePolicy()
        # optional paged-KV accounting for the UNBATCHED path: generate()
        # holds block allocations for its sequence's lifetime; exhaustion
        # rejects the request before any device work is dispatched
        # (backpressure at the cache, not OOM).  The paged BATCHED path uses
        # per-server managers instead (see _PagedState).
        self.kv = (PagedKVCacheManager(num_blocks=kv_blocks,
                                       block_size=kv_block_size)
                   if kv_blocks and not self.paged else None)
        self._kv_lock = threading.Lock()
        self._seq_counter = 0
        # fault-tolerance state (see enable_fault_tolerance): recovery is
        # serialized — concurrent failure observers queue on the lock and
        # find the server already handled
        self._recovery_lock = threading.Lock()
        self._shed: set[str] = set()
        self._held: dict[str, set] = {}  # stream -> {(si | None, seq_id)}
        self.degraded_reports: list = []
        # migration state: _mig_lock serializes every _held mutation the
        # migration protocol and remove() can race on (see
        # _execute_migration); _active_jobs is the per-server active-stream
        # depth signal the work-stealing rebalancer reads
        self._mig_lock = threading.Lock()
        self._active_jobs: dict[str, int] = {}
        self._ft_params: dict | None = None  # set by enable_fault_tolerance
        self._steal_stop: threading.Event | None = None
        self._steal_min_gain_ms = 0.0
        self.migrations_completed = 0
        # max_seq must be static inside the trace (it sizes the cache pad)
        self._prefill = jax.jit(
            lambda p, b: M.apply(cfg, p, {**b, "max_seq": max_seq},
                                 mode="prefill"))
        self._decode = jax.jit(
            lambda p, b, c: M.apply(cfg, p, b, mode="decode", cache=c))
        self._streams: dict[str, StreamSpec] = {}
        # shape-bucket boundaries (tunable via tune_buckets()): batch rows
        # and prefill pad lengths default to the full pow2 ladder — exactly
        # the cells the pow2-with-clamp rules could already produce
        self._row_buckets = _pow2_ladder(max_batch)
        self.prefill_buckets = _pow2_ladder(max_seq)
        self.width_buckets: tuple[int, ...] = ()
        # cells warmed by precompile(); consulted by the safe-fallback
        # bump-up in the hot path (engine-level: the jitted step callables
        # are shared across servers, so one trace warms the whole pool)
        self._warm_decode: set[tuple[int, int]] = set()
        self._warm_prefill: set[tuple[int, int]] = set()
        if batching:
            self._slots = [_SlotState(max_batch) for _ in range(num_servers)]
            self._batch_axes = _cache_batch_axes(cfg, max_seq)
            self._insert_jit = jax.jit(self._insert_impl)
            self._decode_masked = jax.jit(self._decode_masked_impl)
        if self.paged:
            uses_blocks = "block" in self._cache_kinds
            blocks_per_seq = max_seq // kv_block_size if uses_blocks else 0
            # default block pool: every slot can hold a max_seq sequence,
            # plus the scratch block (slab-only families carry no blocks)
            num_blocks = (kv_blocks or (max_batch * blocks_per_seq + 1)
                          if uses_blocks else 0)
            # slabs: one per slot, doubled so an in-flight migration can
            # hold src+dst at once, plus scratch; segments: shared across
            # slots (refcounted) so max_batch distinct keys + scratch cover
            # the worst case
            num_slabs = (2 * max_batch + 2
                         if "slab" in self._cache_kinds else 0)
            num_segments = (max_batch + 2
                            if "segment" in self._cache_kinds else 0)
            # remembered for elastically-added servers
            self._num_blocks = num_blocks
            self._num_slabs = num_slabs
            self._num_segments = num_segments
            self._paged = [
                _PagedState(cfg, num_blocks, kv_block_size, max_batch,
                            max_seq, family=self._family,
                            num_slabs=num_slabs, num_segments=num_segments)
                for _ in range(num_servers)
            ]
            # slab-only families have no gather width: the single 0 bucket
            # keeps every bucket_up() call well-defined
            self.width_buckets = (_pow2_ladder(self._paged[0].nb_max)
                                  if uses_blocks else (0,))
            # the pools argument is donated in both jits: pool updates must
            # alias, not copy — the pool is owned by the server thread and
            # immediately replaced by the call's output
            self._insert_paged_jit = jax.jit(self._insert_paged_impl,
                                             donate_argnums=(0,))
            self._decode_paged = jax.jit(self._decode_paged_impl,
                                         donate_argnums=(2,))
            # migration primitive: gather a stream's live blocks into one
            # packed buffer (source server), scatter them into fresh blocks
            # (destination server).  Gather must NOT donate (the source
            # pool stays live until commit); scatter donates like insert.
            self._export_kv = jax.jit(self._export_kv_impl)
            self._import_kv = jax.jit(self._import_kv_impl,
                                      donate_argnums=(0,))
            self._warm_migrate: set[int] = set()

    @property
    def server(self):
        """The first pool server (single-server back-compat alias)."""
        return self.pool.servers[0]

    # -- stream admission (analysis-driven, Eqs (1)-(6) per partition) -----
    def admit(self, spec: StreamSpec, *, cell=None):
        """``cell``: optional cost-model shape hint (one CellKey broadcast
        to all segments, or a per-segment sequence) enabling CALIBRATED
        admission when the engine was built with a ``cost_model`` — declared
        worst-case segment costs are re-priced to the measured/interpolated
        cost of the bucket the stream actually runs in (never upward)."""
        segs = (GpuSegment(e=spec.prefill_ms * 0.9, m=spec.prefill_ms * 0.1),
                *(GpuSegment(e=spec.decode_ms * 0.9, m=spec.decode_ms * 0.1),)
                * spec.decode_steps)
        task = Task(name=spec.name, C=spec.cpu_ms, T=spec.period_ms,
                    D=spec.deadline_ms, segments=segs, priority=spec.priority)
        decision, device = self.admission.try_admit(task, cell=cell)
        if decision.admitted:
            self._streams[spec.name] = spec
            self.straggler.register(spec.name, spec.deadline_ms)
            # the router follows the admission's device-assignment step
            self.pool.assign(spec.name, utilization=task.G / task.T,
                             priority=spec.priority, server=device)
        return decision

    def remove(self, name: str) -> None:
        """Withdraw a stream: admission slot, router binding, and any
        paged-KV blocks still held for it (a stream evicted by failure or
        shed by degraded admission may leave reservations behind if its
        generating thread is gone; ``missing_ok`` makes the free race-safe
        against that thread's own cleanup).  Never call while the stream
        has a device call in flight.

        The held-blocks sweep runs under ``_mig_lock`` so it is atomic
        w.r.t. an in-flight migration of this stream: during the copy
        window the ledger holds BOTH (src, seq) and (dst, seq); freeing
        both here is exactly right (the stream is gone), and the migrating
        thread's commit re-checks the ledger under the same lock and
        aborts instead of double-freeing (see _execute_migration)."""
        self.admission.remove(name)
        self.pool.remove(name)
        self._streams.pop(name, None)
        self._shed.discard(name)
        self._active_jobs.pop(name, None)
        with self._mig_lock:
            held = self._held.pop(name, set())
            for si, seq_id in held:
                if si is None:
                    with self._kv_lock:
                        self.kv.free_seq(seq_id, missing_ok=True)
                else:
                    state = self._paged[si]
                    with state.lock:
                        state.mgr.free_seq(seq_id, missing_ok=True)

    # -- bucket auto-tuning (cost-model driven) ----------------------------
    def tune_buckets(self, prompt_lengths, *, steps_hint: int = 0,
                     cost_model=None, max_buckets: int = 4):
        """Pick the prefill-length and (paged) gather-width bucket
        boundaries for an expected workload: exact DP over the pow2
        candidate ladder minimizing total padding waste — or, when a fitted
        ``cost_model`` (default: the engine's own) can price the phase,
        total PREDICTED step cost, which weights waste by what it actually
        costs on this device.  The largest candidate always survives
        (coverage), so runtime clamping semantics are unchanged.  Call
        BEFORE precompile()/traffic — retuning invalidates warm cells, so
        this clears both warm sets.  Returns (prefill_buckets,
        width_buckets)."""
        model = cost_model if cost_model is not None else self.cost_model
        lengths = [int(l) for l in prompt_lengths]
        if any(l > self.max_seq for l in lengths):
            raise ValueError("prompt length exceeds max_seq")

        def priced(phase, rows):
            if model is None:
                return None
            probe = model.predict(phase, rows, _pow2_ladder(self.max_seq)[-1])
            if not math.isfinite(probe):
                return None  # phase unmeasured: fall back to padding waste
            return lambda bucket, value: model.predict(phase, rows, bucket)

        self.prefill_buckets = autotune_buckets(
            lengths or [1], _pow2_ladder(self.max_seq),
            max_buckets=max_buckets, cost_of=priced(self._prefill_kind, 1))
        if self.paged and self._paged[0].nb_max:
            bs = self.kv_block_size
            nb_max = self._paged[0].nb_max
            # widths are driven by each stream's FINAL length (the widest
            # gather its decode steps reach): ceil((len + steps + 1) / bs)
            needs = [min(nb_max, -(-(l + steps_hint + 1) // bs))
                     for l in lengths] or [1]
            wmodel = None
            if model is not None:
                probe = model.predict(self._decode_kind, 1, nb_max)
                if math.isfinite(probe):
                    wmodel = lambda bucket, value: model.predict(
                        self._decode_kind, 1, bucket)
            self.width_buckets = autotune_buckets(
                needs, _pow2_ladder(nb_max), max_buckets=max_buckets,
                cost_of=wmodel)
        self._warm_decode.clear()
        self._warm_prefill.clear()
        return self.prefill_buckets, self.width_buckets

    # -- static cell pricing (hlo_cost -> cost-model features) -------------
    def static_cell_costs(self, cells=None) -> dict:
        """Price shape cells STATICALLY: compile each cell's trace (no
        device execution) and walk the optimized HLO with
        ``analysis.hlo_cost`` for exact per-cell (flops, hbm_bytes).
        Returns {CellKey: (flops, hbm_bytes)} ready for
        ``cost_model.hlo_cell_features`` — the feed that lets a
        ``StepCostModel`` price a migration/scatter width (or any cell) it
        never measured at runtime off static analysis instead of the
        declared worst case.

        ``cells`` is an iterable of CellKeys (``("decode", rows, width)``,
        ``("prefill", rows, bucket)``, ``("migrate", width, block_size)``);
        default: every migrate width bucket — the cells a steal can hit
        cold.  Compilation reuses XLA's jit cache, so cells already warm
        from precompile()/traffic cost only the HLO walk.  Paged engines
        only (the masked-dense decode has a single full-shape cell that
        measurement always covers)."""
        from repro.analysis import hlo_cost

        if not self.paged:
            raise ValueError("static_cell_costs requires paged=True")
        if cells is None:
            cells = [(self._migrate_kind, w, self.kv_block_size)
                     for w in self.width_buckets]
        pools = jax.eval_shape(
            lambda: M.init_paged_cache(self.cfg, self._num_blocks,
                                       self.kv_block_size,
                                       num_slabs=self._num_slabs,
                                       num_segments=self._num_segments))

        def cost_of(lowered) -> tuple[float, float]:
            c = hlo_cost.analyze_text(lowered.compile().as_text())
            return (c.flops, c.hbm_bytes)

        out: dict[tuple, tuple[float, float]] = {}
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        for cell in cells:
            phase, a, b = cell
            base = phase.split("@", 1)[0]  # family-tagged phases price alike
            if base == "migrate":
                table = jax.ShapeDtypeStruct((a,), jnp.int32)
                packed = jax.eval_shape(self._export_kv_impl, pools, table,
                                        idx, idx)
                fg, bg = cost_of(self._export_kv.lower(pools, table, idx,
                                                       idx))
                fs, bs = cost_of(self._import_kv.lower(pools, packed,
                                                       table, idx, idx))
                out[cell] = (fg + fs, bg + bs)
            elif base == "decode":
                packed = jax.ShapeDtypeStruct((a, 4 + b), jnp.int32)
                out[cell] = cost_of(
                    self._decode_paged.lower(self.params, packed, pools))
            elif base == "prefill":
                batch = self._prefill_batch(np.zeros((a, b), np.int32))
                batch["lengths"] = jnp.ones((a,), jnp.int32)
                out[cell] = cost_of(self._prefill.lower(self.params, batch))
            else:
                raise ValueError(f"unknown phase in cell {cell!r}")
        return out

    # -- batched decode internals (masked-dense layout) --------------------
    def _insert_impl(self, full, batched, src_row, slot):
        """Copy row ``src_row`` of a (possibly coalesced) prefill cache into
        row ``slot`` of the slot cache."""

        def one(f, o, ax):
            row = jax.lax.dynamic_slice_in_dim(o, src_row, 1, axis=ax)
            return jax.lax.dynamic_update_slice_in_dim(
                f, row.astype(f.dtype), slot, axis=ax)

        return jax.tree.map(one, full, batched, self._batch_axes)

    def _decode_masked_impl(self, params, tokens, cache, active):
        """One batched decode step over the slot cache; rows where ``active``
        is False keep their previous cache (and their logits are garbage,
        discarded by the caller)."""
        logits, new_cache, _ = M.apply(self.cfg, params, {"tokens": tokens},
                                       mode="decode", cache=cache)

        def merge(o, n, ax):
            shape = [1] * n.ndim
            shape[ax] = n.shape[ax]
            return jnp.where(active.reshape(shape), n, o)

        return logits, jax.tree.map(merge, cache, new_cache, self._batch_axes)

    def _acquire_slot(self, si: int) -> int:
        state = self._slots[si]
        with state.cond:
            while not state.free:
                state.cond.wait()
            return state.free.pop()

    def _try_acquire_slot(self, si: int) -> int | None:
        """Non-blocking slot acquisition — the migration path must never
        deadlock holding its source slot while waiting on a destination
        slot, so no free slot means the steal is cancelled instead."""
        state = self._slots[si]
        with state.cond:
            if not state.free:
                return None
            return state.free.pop()

    def _release_slot(self, si: int, slot: int) -> None:
        state = self._slots[si]
        with state.cond:
            state.free.append(slot)
            state.cond.notify()

    def _insert_slot(self, si: int, slot: int, cache, src_row: int) -> None:
        """Runs on server ``si``'s thread (serialized with its batches)."""
        state = self._slots[si]
        if state.cache is None:
            state.cache = M.init_cache(self.cfg, self.max_batch, self.max_seq)
        state.cache = jax.block_until_ready(
            self._insert_jit(state.cache, cache, jnp.int32(src_row),
                             jnp.int32(slot)))

    def _run_decode_batch(self, si: int):
        """run_batch callable for server ``si`` (masked-dense): payloads are
        (slot, token) pairs; ONE masked device call serves them all.  The
        staging arrays are the slot state's preallocated scratch — no
        per-step host allocation."""

        def run(payloads):
            state = self._slots[si]
            toks, active = state.tok_scratch, state.active_scratch
            toks[:, 0] = 0
            active[:] = False
            for slot, token in payloads:
                toks[slot, 0] = token
                active[slot] = True
            logits, state.cache = jax.block_until_ready(
                self._decode_masked(self.params, jnp.asarray(toks),
                                    state.cache, jnp.asarray(active)))
            rows = np.asarray(logits[:, -1], np.float32)
            return [rows[slot] for slot, _ in payloads]

        return run

    # -- batched decode internals (paged pool layouts, family-generic) -----
    def _make_pools(self, state):
        return M.init_paged_cache(self.cfg, state.mgr.num_blocks,
                                  state.mgr.block_size,
                                  num_slabs=state.mgr.num_slabs,
                                  num_segments=state.mgr.num_segments)

    def _insert_paged_impl(self, pools, cache, src_row, table, slab, seg):
        """Scatter row ``src_row`` of a prefill cache into the pools,
        dispatched per pool kind: "block" entries land at ``table`` (nb_max
        entries; lanes past the sequence's reserved blocks point at the
        scratch block and carry all-zero rows, so duplicate scatter lanes
        stay deterministic); "slab" entries land in row ``slab``; "segment"
        entries in row ``seg`` (shared segments — re-staging an
        already-present key rewrites identical content, idempotent)."""
        bs = self.kv_block_size
        views = M.paged_insert_views(self.cfg, cache)

        def block_one(pool, leaf):
            # leaf (L, B, max_seq, ...) -> rows (L, nb_max, bs, ...)
            rows = jax.lax.dynamic_index_in_dim(leaf, src_row, axis=1,
                                                keepdims=False)
            rows = rows.reshape(leaf.shape[0], -1, bs, *leaf.shape[3:])
            return pool.at[:, table].set(rows.astype(pool.dtype))

        def row_one(idx):
            def f(pool, leaf):
                row = jax.lax.dynamic_index_in_dim(leaf, src_row, axis=1,
                                                   keepdims=True)
                return jax.lax.dynamic_update_slice_in_dim(
                    pool, row.astype(pool.dtype), idx, axis=1)
            return f

        out = {}
        for key, kind in self._pool_kinds.items():
            one = (block_one if kind == "block"
                   else row_one(slab if kind == "slab" else seg))
            out[key] = jax.tree.map(one, pools[key], views[key])
        return out

    def _decode_paged_impl(self, params, packed, pools):
        """One compacted paged decode step.  ``packed`` (n, 4+W) int32 rows
        are [token, length, slab, segment, block_table...] — a uniform
        header across cache families; columns a family doesn't use carry
        scratch ids and are never read.  The table width W addresses only
        the gather the live rows need; rows scatter their new KV / state
        into their own blocks/slabs (disjoint by construction — no masked
        merge).  The pool buffers are DONATED by the caller: the update
        aliases in place instead of copying the whole pool every token."""
        tokens, lengths = packed[:, :1], packed[:, 1]
        cache = dict(pools)
        cache["pos"] = lengths
        if "block" in self._cache_kinds:
            cache["block_tables"] = packed[:, 4:]
        if "slab" in self._cache_kinds:
            cache["slab_ids"] = packed[:, 2]
        if "segment" in self._cache_kinds:
            cache["segment_ids"] = packed[:, 3]
        logits, new_cache, _ = M.apply(self.cfg, params, {"tokens": tokens},
                                       mode="decode", cache=cache)
        return logits, {k: new_cache[k] for k in self._pool_kinds}

    def _insert_slot_paged(self, si: int, cache, src_row: int,
                           table: np.ndarray, slab: int = 0,
                           seg: int = 0) -> None:
        """Runs on server ``si``'s thread (serialized with its batches)."""
        state = self._paged[si]
        if state.pools is None:
            state.pools = self._make_pools(state)
        state.pools = jax.block_until_ready(
            self._insert_paged_jit(state.pools, cache, jnp.int32(src_row),
                                   jnp.asarray(table), jnp.int32(slab),
                                   jnp.int32(seg)))

    def _run_paged_decode(self, si: int):
        """run_batch callable for server ``si`` (paged): payloads are
        (token, block_table, length, slab, segment) tuples.  Slot compaction
        + length bucketing happen here: only the live rows enter the device
        call (padded to the next power of two by duplicating row 0 —
        duplicate scatter lanes write identical values and slabs are
        per-row-owned, so padding is idempotent), and the block-table gather
        is truncated to the power-of-two width that covers the longest live
        row (0 for slab-only families: no gather axis at all)."""

        def run(payloads):
            state = self._paged[si]
            bs = state.mgr.block_size
            n = len(payloads)
            n_pad = bucket_up(n, self._row_buckets)
            need = (max(-(-(length + 1) // bs)
                        for _, _, length, _, _ in payloads)
                    if state.nb_max else 0)
            w = bucket_up(need, self.width_buckets)
            # safe fallback: a cold cell mid-traffic would stall the server
            # behind XLA compilation, so bump to the cheapest WARM cell that
            # covers it (widening is sound: extra width lanes gather the
            # all-zero scratch block past each row's length, extra rows
            # duplicate row 0 idempotently).  No warm cover -> compile cold.
            cold = False
            if self._warm_decode and (n_pad, w) not in self._warm_decode:
                covers = [c for c in self._warm_decode
                          if c[0] >= n_pad and c[1] >= w]
                if covers:
                    n_pad, w = min(covers, key=lambda c: c[0] * c[1])
                else:
                    cold = True
            pack = state.pack_scratch
            for i, (token, table, length, slab, seg) in enumerate(payloads):
                pack[i, 0] = token
                pack[i, 1] = length
                pack[i, 2] = slab
                pack[i, 3] = seg
                pack[i, 4:] = table
            for i in range(n, n_pad):  # idempotent padding rows
                pack[i] = pack[0]
            t0 = time.monotonic()
            logits, state.pools = jax.block_until_ready(
                self._decode_paged(self.params,
                                   jnp.asarray(pack[:n_pad, : 4 + w]),
                                   state.pools))
            dt = time.monotonic() - t0
            if cold:  # now traced: later hits on this cell are warm
                self._warm_decode.add((n_pad, w))
            self.pool.servers[si].record_meta(
                kind=self._decode_kind, rows=n, padded=n_pad, width=w,
                compacted=n_pad < self.max_batch, seconds=dt, cold=cold)
            rows = np.asarray(logits)[:, -1]
            return [rows[i] for i in range(n)]

        return run

    def _paged_reserve(self, si: int, name: str, prompt_len: int,
                       steps: int, bucket: int
                       ) -> tuple[str, np.ndarray, int, int]:
        """Reserve every resource the job will touch up front (reject early
        rather than stall mid-generation), including the bucketed-prefill
        pad region, whose padding-token KV must land in owned blocks.
        Returns (seq_id, block table, slab id, segment id); kinds the
        family doesn't use come back as the scratch ids."""
        state = self._paged[si]
        with self._kv_lock:
            self._seq_counter += 1
            counter = self._seq_counter
        with state.lock:
            seq_id = f"{name}#{counter}"
            tokens = max(prompt_len + steps, bucket)
            # enc-dec engine frontend stubs every stream's encoder frames
            # as the same zeros (_prefill_batch), so all streams SHARE one
            # cross-attention segment — the COW-dedup the segment pool is
            # for.  Re-staging the shared key rewrites identical content.
            state.mgr.allocate(seq_id, prompt_len, segment_key="__frames__")
            try:
                state.mgr.extend(seq_id, tokens - prompt_len)
            except Exception:
                state.mgr.free_seq(seq_id)
                raise
            alloc = state.mgr.seqs[seq_id]
            table = np.full((state.nb_max,), state.scratch_block, np.int32)
            table[: len(alloc.blocks)] = alloc.blocks
            slab = (alloc.slab if alloc.slab is not None
                    else state.scratch_slab)
            seg = (alloc.segment if alloc.segment is not None
                   else state.scratch_seg)
        self._held.setdefault(name, set()).add((si, seq_id))
        return seq_id, table, slab, seg

    def _paged_release(self, si: int, seq_id: str) -> None:
        name = seq_id.rsplit("#", 1)[0]
        with self._mig_lock:
            held = self._held.get(name)
            if held is not None:
                held.discard((si, seq_id))
            state = self._paged[si]
            with state.lock:
                state.mgr.free_seq(seq_id, missing_ok=True)

    # -- live cache migration (steal / consolidate / elastic drain) --------
    def _export_kv_impl(self, pools, table, slab, seg):
        """Gather one stream's live cache out of every pool into one packed
        contiguous buffer — the single device->host transfer of the
        migration.  Block kinds gather the blocks named by ``table`` (pad
        lanes point at the source scratch block, never-read zeros, so the
        gather width can be pow2-bucketed onto a precompiled cell); slab
        and segment kinds gather their single row."""
        out = {}
        for key, kind in self._pool_kinds.items():
            if kind == "block":
                fn = lambda pool: pool[:, table]
            else:
                idx = slab if kind == "slab" else seg
                fn = (lambda i: lambda pool:
                      jax.lax.dynamic_slice_in_dim(pool, i, 1, axis=1))(idx)
            out[key] = jax.tree.map(fn, pools[key])
        return out

    def _import_kv_impl(self, pools, packed, table, slab, seg):
        """Scatter a packed export into the destination pools: block rows
        at ``table`` (the fresh blocks import_seq allocated; pad lanes
        target the destination scratch block — duplicate scratch writes are
        benign, nothing reads it), the slab row into the FRESH destination
        slab, the segment row into the destination segment (idempotent when
        the key was already resident there).  Donated like the
        decode/insert pool updates."""
        out = {}
        for key, kind in self._pool_kinds.items():
            if kind == "block":
                fn = lambda pool, rows: pool.at[:, table].set(
                    rows.astype(pool.dtype))
            else:
                idx = slab if kind == "slab" else seg
                fn = (lambda i: lambda pool, rows:
                      jax.lax.dynamic_update_slice_in_dim(
                          pool, rows.astype(pool.dtype), i, axis=1))(idx)
            out[key] = jax.tree.map(fn, pools[key], packed[key])
        return out

    def _migrate_cell(self, n_blocks: int) -> tuple[int, bool]:
        """(padded gather width, cold?) for a migration of ``n_blocks`` —
        same warm-cell bump-up discipline as the decode hot path."""
        w = bucket_up(n_blocks, self.width_buckets)
        cold = False
        if self._warm_migrate and w not in self._warm_migrate:
            covers = [c for c in self._warm_migrate if c >= w]
            if covers:
                w = min(covers)
            else:
                cold = True
        return w, cold

    def _execute_migration(self, name: str, seq_id: str, src_si: int,
                           dst_si: int, prio: int):
        """Move ``seq_id``'s live cache (blocks, state slab, shared
        segment — whatever kinds its family uses) from server ``src_si``
        to ``dst_si``; returns (new full-width block table, destination
        slab id, destination segment id).

        Two-phase commit against ``remove()`` (satellite of the protocol in
        ``kvcache``'s docstring): under ``_mig_lock`` the destination
        allocation is made and BOTH sides enter the ``_held`` ledger; the
        copy itself runs outside the lock (a gather on the source server, a
        host hop, a scatter on the destination server — each serialized
        with that server's own batches); commit re-takes the lock,
        verifies the ledger still holds the entries (a concurrent
        ``remove`` frees both sides itself — then this raises instead of
        double-freeing), and frees the source.  Any failure rolls the
        destination back, leaving the stream exactly where it was.

        A ``remove()`` that lands mid-copy may free destination blocks the
        scatter then writes: benign — the scatter targets only blocks this
        migration allocated, their content is never read unless this
        commit succeeds (then they were never freed), and a later owner's
        prefill rewrites every in-range position while attention masks the
        rest."""
        src, dst = self._paged[src_si], self._paged[dst_si]
        with self._mig_lock:
            held = self._held.get(name)
            if held is None or (src_si, seq_id) not in held:
                raise StreamShedError(
                    f"stream {name!r} gone before migration")
            with src.lock:
                exp = src.mgr.export_seq(seq_id)
                src_alloc = src.mgr.seqs[seq_id]
                src_slab = (src_alloc.slab if src_alloc.slab is not None
                            else src.scratch_slab)
                src_seg = (src_alloc.segment
                           if src_alloc.segment is not None
                           else src.scratch_seg)
            with dst.lock:
                # OutOfBlocks -> clean: all-or-nothing across every kind
                new_blocks = dst.mgr.import_seq(exp)
                dst_alloc = dst.mgr.seqs[seq_id]
                dst_slab = (dst_alloc.slab if dst_alloc.slab is not None
                            else dst.scratch_slab)
                dst_seg = (dst_alloc.segment
                           if dst_alloc.segment is not None
                           else dst.scratch_seg)
            held.add((dst_si, seq_id))
        try:
            n = len(exp.blocks)
            w, cold = self._migrate_cell(n)
            src_table = np.full((w,), src.scratch_block, np.int32)
            src_table[:n] = exp.blocks
            dst_table = np.full((w,), dst.scratch_block, np.int32)
            dst_table[:n] = new_blocks

            def gather():
                t0 = time.monotonic()
                packed = jax.block_until_ready(
                    self._export_kv(src.pools, jnp.asarray(src_table),
                                    jnp.int32(src_slab),
                                    jnp.int32(src_seg)))
                packed = jax.tree.map(np.asarray, packed)  # device -> host
                self.pool.servers[src_si].record_meta(
                    kind=self._migrate_kind, rows=n, padded=w,
                    width=self.kv_block_size,
                    seconds=time.monotonic() - t0, cold=cold)
                return packed

            packed = self.pool.servers[src_si].submit(
                gather, priority=prio, name=f"{name}/migrate-export").wait()

            def scatter():
                if dst.pools is None:
                    dst.pools = self._make_pools(dst)
                t0 = time.monotonic()
                dst.pools = jax.block_until_ready(
                    self._import_kv(dst.pools,
                                    jax.tree.map(jnp.asarray, packed),
                                    jnp.asarray(dst_table),
                                    jnp.int32(dst_slab),
                                    jnp.int32(dst_seg)))
                self.pool.servers[dst_si].record_meta(
                    kind=self._migrate_kind, rows=n, padded=w,
                    width=self.kv_block_size,
                    seconds=time.monotonic() - t0, cold=cold)

            self.pool.servers[dst_si].submit(
                scatter, priority=prio, name=f"{name}/migrate-import").wait()
        except BaseException:
            with self._mig_lock:
                held = self._held.get(name)
                if held is not None:
                    held.discard((dst_si, seq_id))
                with dst.lock:
                    dst.mgr.free_seq(seq_id, missing_ok=True)
            raise
        with self._mig_lock:
            held = self._held.get(name)
            if held is None or (dst_si, seq_id) not in held:
                # remove() raced the copy: it freed both sides already
                raise StreamShedError(
                    f"stream {name!r} removed mid-migration")
            held.discard((src_si, seq_id))
            with src.lock:
                src.mgr.free_seq(seq_id, missing_ok=True)
        self.migrations_completed += 1
        full = np.full((dst.nb_max,), dst.scratch_block, np.int32)
        full[:n] = new_blocks
        return full, dst_slab, dst_seg

    # -- batched prefill (length-bucketed) ---------------------------------
    def _run_prefill_batch(self, si: int, bucket: int):
        """run_batch callable coalescing same-bucket prefills: payloads are
        (prompt_row, true_len); ONE device call prefills them all, padded to
        ``bucket``.  Each result is (last-token logits row, the coalesced
        cache, this payload's row index) — the caller inserts its row."""

        def run(payloads):
            n = len(payloads)
            n_pad = bucket_up(n, self._row_buckets)
            # safe fallback on the ROW axis (the bucket axis was already
            # steered to a warm pad length by _generate_batched): padding
            # rows duplicate row 0 and their outputs are discarded
            cold = False
            if self._warm_prefill and (n_pad, bucket) not in self._warm_prefill:
                covers = [r for r, b in self._warm_prefill
                          if b == bucket and r >= n_pad]
                if covers:
                    n_pad = min(covers)
                else:
                    cold = True
            toks = np.zeros((n_pad, bucket), np.int32)
            lens = np.zeros((n_pad,), np.int32)
            for i, (prompt, true_len) in enumerate(payloads):
                toks[i, :true_len] = prompt
                lens[i] = true_len
            for i in range(n, n_pad):  # padding rows: discarded outputs
                toks[i] = toks[0]
                lens[i] = lens[0]
            batch = self._prefill_batch(toks)
            batch["lengths"] = jnp.asarray(lens)
            t0 = time.monotonic()
            logits, cache, _ = jax.block_until_ready(
                self._prefill(self.params, batch))
            dt = time.monotonic() - t0
            if cold:
                self._warm_prefill.add((n_pad, bucket))
            self.pool.servers[si].record_meta(
                kind=self._prefill_kind, rows=n, padded=n_pad, bucket=bucket,
                seconds=dt, cold=cold)
            rows = np.asarray(logits[np.arange(n), lens[:n] - 1], np.float32)
            return [(rows[i], cache, i) for i in range(n)]

        return run

    def precompile(self, prompt_buckets: tuple[int, ...] = (), *,
                   traffic=None) -> PrecompileReport:
        """Warm batched-decode/prefill shape cells ahead of time.

        Shape bucketing bounds the trace count to O(log(max_batch) *
        log(max_seq/block_size)) for paged decode plus O(log(max_batch))
        per prefill length bucket, but a cell first hit mid-traffic would
        stall the whole server behind XLA compilation — a serving engine
        warms them BEFORE taking load (the dummy inserts scribble on
        slot/scratch state, so never call this while streams are live).
        ``prompt_buckets`` lists prefill pad lengths to warm (snapped up
        into ``prefill_buckets``).  ``traffic`` — a
        ``cost_model.TrafficModel`` or an iterable of CellKeys — restricts
        compilation to the predicted-hit cells PLUS, always, the largest
        cell on each phase: the safe-fallback target the hot path bumps
        cold cells up to (see _run_paged_decode).  Each distinct cell is
        traced ONCE (the jitted step callables are shared across servers);
        cells already warm from an earlier call are skipped, and the report
        says how many traces were skipped vs compiled.  Pools / slot caches
        are still created on every server.  No-op unless batching."""
        if not self.batching:
            return PrecompileReport()
        hot = None
        if traffic is not None:
            hot = (set(traffic.hot_cells())
                   if hasattr(traffic, "hot_cells") else set(traffic))
        rows_ladder = self._row_buckets
        if self.paged:
            reachable_d = [(r, w) for r in rows_ladder
                           for w in self.width_buckets]
            fb_d = (rows_ladder[-1], self.width_buckets[-1])
        else:
            # masked-dense always runs the one full-shape trace
            reachable_d = [(self.max_batch, 0)]
            fb_d = reachable_d[0]
        plan_d = [c for c in reachable_d
                  if hot is None or c == fb_d
                  or (self._decode_kind, *c) in hot]
        todo_d = [c for c in plan_d if c not in self._warm_decode]
        buckets = sorted({bucket_up(b, self.prefill_buckets)
                          for b in prompt_buckets})
        reachable_p = [(r, b) for b in buckets for r in rows_ladder]
        fb_p = (rows_ladder[-1], buckets[-1]) if buckets else None
        plan_p = [c for c in reachable_p
                  if hot is None or c == fb_p
                  or (self._prefill_kind, *c) in hot]
        todo_p = [c for c in plan_p if c not in self._warm_prefill]
        # migration gather/scatter cells: one per width bucket (the traces
        # are cheap — pure gather/scatter, no model math), so a mid-traffic
        # steal never stalls a server behind XLA compilation
        reachable_m = list(self.width_buckets) if self.paged else []
        fb_m = reachable_m[-1] if reachable_m else None
        plan_m = [w for w in reachable_m
                  if hot is None or w == fb_m
                  or (self._migrate_kind, w, self.kv_block_size) in hot]
        todo_m = [w for w in plan_m if w not in self._warm_migrate]
        for si in range(len(self.pool.servers)):
            # traces are shared: run the compile plan on server 0 only;
            # the other servers just get their pools/caches initialized
            d = todo_d if si == 0 else []
            p = todo_p if si == 0 else []
            m = todo_m if si == 0 else []
            self.pool.servers[si].submit(
                lambda si=si, d=d, p=p, m=m:
                    self._precompile_server(si, d, p, m),
                name=f"precompile-{si}").wait()
        self._warm_decode.update(todo_d)
        self._warm_prefill.update(todo_p)
        if self.paged:
            self._warm_migrate.update(todo_m)
        skipped = ((len(reachable_d) - len(todo_d))
                   + (len(reachable_p) - len(todo_p))
                   + (len(reachable_m) - len(todo_m)))
        return PrecompileReport(compiled=len(todo_d) + len(todo_p)
                                + len(todo_m),
                                skipped=skipped,
                                decode_cells=tuple(todo_d),
                                prefill_cells=tuple(todo_p),
                                migrate_cells=tuple(todo_m))

    def _precompile_server(self, si: int, decode_cells, prefill_cells,
                           migrate_cells=()):
        if self.paged:
            state = self._paged[si]
            if state.pools is None:
                state.pools = self._make_pools(state)
            for rows, w in decode_cells:
                # dummy batch: every row scatters token 0 at offset 0 of
                # the scratch block/slab (idempotent duplicates; the
                # scratch segment is never read)
                pack = np.zeros((rows, 4 + w), np.int32)
                pack[:, 2] = state.scratch_slab
                pack[:, 3] = state.scratch_seg
                pack[:, 4:] = state.scratch_block
                _, state.pools = jax.block_until_ready(
                    self._decode_paged(self.params, jnp.asarray(pack),
                                       state.pools))
            for w in migrate_cells:
                # round-trip the scratch resources through gather +
                # scatter: identical content lands back where it came from
                table = jnp.full((w,), state.scratch_block, jnp.int32)
                slab = jnp.int32(state.scratch_slab)
                seg = jnp.int32(state.scratch_seg)
                packed = jax.block_until_ready(
                    self._export_kv(state.pools, table, slab, seg))
                state.pools = jax.block_until_ready(
                    self._import_kv(state.pools, packed, table, slab, seg))
        else:
            state = self._slots[si]
            if state.cache is None:
                state.cache = M.init_cache(self.cfg, self.max_batch,
                                           self.max_seq)
            for _cell in decode_cells:
                toks = jnp.zeros((self.max_batch, 1), jnp.int32)
                active = jnp.zeros((self.max_batch,), bool)  # all-masked
                _, state.cache = jax.block_until_ready(
                    self._decode_masked(self.params, toks, state.cache,
                                        active))
        for rows, bucket in prefill_cells:
            batch = self._prefill_batch(np.zeros((rows, bucket), np.int32))
            batch["lengths"] = jnp.ones((rows,), jnp.int32)
            _, cache, _ = jax.block_until_ready(
                self._prefill(self.params, batch))
            if self.paged:
                state = self._paged[si]
                table = np.full((state.nb_max,), state.scratch_block,
                                np.int32)
                self._insert_slot_paged(si, cache, 0, table,
                                        state.scratch_slab,
                                        state.scratch_seg)
            else:
                self._insert_slot(si, 0, cache, 0)

    # -- generation ---------------------------------------------------------
    def generate(self, name: str, prompt: np.ndarray, *, steps: int,
                 greedy: bool = True) -> GenerationResult:
        """Run one job of stream ``name``: prefill + ``steps`` decode
        segments, each arbitrated by the stream's server.  The calling
        thread suspends between segments (never busy-waits)."""
        if self.batching:
            return self._generate_batched(name, prompt, steps=steps)
        spec = self._streams[name]
        prio = self.straggler.boost(name, spec.priority)
        res = GenerationResult()
        batch = self._prefill_batch(prompt)

        seq_id = self._kv_reserve(name, prompt, steps)
        try:
            t0 = time.monotonic()
            req = self.pool.submit(
                name,
                lambda: jax.block_until_ready(self._prefill(self.params, batch)),
                priority=prio, name=f"{name}/prefill")
            logits, cache, _ = req.wait()
            res.prefill_latency_s = time.monotonic() - t0
            self.straggler.observe(name, res.prefill_latency_s * 1e3)

            last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            for i in range(steps):
                step_batch = {"tokens": last[:, None]}
                t1 = time.monotonic()
                req = self.pool.submit(
                    name,
                    lambda sb=step_batch, c=cache: jax.block_until_ready(
                        self._decode(self.params, sb, c)),
                    priority=prio, name=f"{name}/decode{i}")
                logits, cache, _ = req.wait()
                dt = time.monotonic() - t1
                res.decode_latencies_s.append(dt)
                self.straggler.observe(name, dt * 1e3)
                last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                res.tokens.append(int(last[0]))
        finally:
            self._kv_release(seq_id)
        return res

    def _generate_batched(self, name: str, prompt: np.ndarray, *,
                          steps: int) -> GenerationResult:
        """Continuous-batching path: length-bucketed batched prefill through
        the pool, insert into a slot (dense row) or the block pools (paged),
        then submit each decode step as a batchable request that the server
        coalesces — and, when paged, compacts — with other streams' steps.

        Stream recovery: when the stream's server dies mid-job
        (``ServerFailedError`` from any segment), the per-job _RecoveryLog
        holds the retained token prefix; after degraded-mode re-admission
        routes the stream to a survivor, the attempt re-prefills that prefix
        through the SAME bucketed prefill path and decoding resumes at the
        failed step — greedy tokens stay bit-identical to a failure-free
        run.  A stream shed by degraded admission raises StreamShedError."""
        if prompt.shape[0] != 1:
            raise ValueError("batched decode serves one sequence per stream "
                             f"job; got prompt batch {prompt.shape[0]}")
        if prompt.shape[1] + steps > self.max_seq:
            raise ValueError(f"prompt {prompt.shape[1]} + steps {steps} "
                             f"exceeds max_seq {self.max_seq}")
        res = GenerationResult()
        log = _RecoveryLog(prompt=np.asarray(prompt[0], np.int32))
        while True:
            si = self._await_server(name)
            try:
                self._attempt_batched(name, si, log, steps, res)
                return res
            except ServerFailedError:
                # the server declared itself dead (device loss / exhausted
                # transient retries) or the heartbeat monitor evicted it;
                # either way run recovery — idempotent if already handled —
                # then loop: re-admission has either moved us or shed us
                self._on_server_death(si)
                res.recoveries += 1

    def _await_server(self, name: str, timeout_s: float = 5.0) -> int:
        """The stream's current server index, waiting out an in-flight
        recovery (the evict happens before the re-assign, so a client can
        observe the gap); raises StreamShedError once the stream is shed or
        recovery never re-placed it."""
        deadline = time.monotonic() + timeout_s
        while True:
            if name in self._shed:
                raise StreamShedError(
                    f"stream {name!r} shed by degraded-mode admission")
            try:
                return self.pool.server_of(name)
            except KeyError:
                if time.monotonic() >= deadline:
                    raise StreamShedError(
                        f"stream {name!r} lost its server and was not "
                        "re-placed") from None
                time.sleep(0.001)

    def _attempt_batched(self, name: str, si: int, log: _RecoveryLog,
                         steps: int, res: GenerationResult) -> None:
        """One attempt on server ``si``: prefill the retained prefix, then
        decode until ``res`` holds ``steps`` tokens.  Owns its reservation
        and slot (released on ANY exit, so a failed attempt leaks nothing).

        Token accounting keeps recovery bit-identical: on the first attempt
        the prefill argmax is the decode-step-0 input (recorded, not
        appended); on a recovery attempt the prefix already CONTAINS that
        token, so the re-prefill's last-position argmax IS the failed step's
        output and is appended directly.  The reservation shrinks exactly in
        step: prefix_len + remaining_feeds == prompt_len + steps always."""
        spec = self._streams[name]
        prio = self.straggler.boost(name, spec.priority)
        prefix = log.retained_prefix()
        true_len = int(prefix.shape[0])
        append_first = log.first_token is not None
        feeds = steps - len(res.tokens) - (1 if append_first else 0)
        bucket = bucket_up(true_len, self.prefill_buckets)
        if self._warm_prefill:
            # traffic-aware precompile warmed a subset of pad lengths:
            # steer to the smallest warm bucket that fits rather than cold-
            # compiling the tight one (padding tokens' KV lands in owned
            # blocks; per-row true lengths mask them out of attention)
            warm = sorted({b for _r, b in self._warm_prefill
                           if b >= true_len})
            if warm:
                bucket = warm[0]

        # every submit is pinned to server object ``si`` — NOT routed by
        # stream name — so if a concurrent recovery re-binds this stream
        # mid-attempt, the next segment hits the DEAD server and raises
        # ServerFailedError instead of silently running against the new
        # server's pools with this attempt's (old-server) block table
        server = self.pool.servers[si]
        seq_id = table = None
        slab = seg = 0
        if self.paged:
            seq_id, table, slab, seg = self._paged_reserve(
                si, name, true_len, feeds, bucket)
        else:
            seq_id = self._kv_reserve(name, prefix[None, :], feeds)
        try:
            slot = self._acquire_slot(si)
            self._active_jobs[name] = si
            try:
                t0 = time.monotonic()
                req = server.submit_batch(
                    (prefix, true_len),
                    run_batch=self._run_prefill_batch(si, bucket),
                    batch_key=("prefill", si, bucket), priority=prio,
                    name=f"{name}/prefill")
                row_logits, cache, src_row = req.wait()
                if self.paged:
                    server.submit(
                        lambda: self._insert_slot_paged(
                            si, cache, src_row, table, slab, seg),
                        priority=prio, name=f"{name}/insert").wait()
                else:
                    server.submit(
                        lambda: self._insert_slot(
                            si, slot, cache, src_row),
                        priority=prio, name=f"{name}/insert").wait()
                res.prefill_latency_s = time.monotonic() - t0
                self.straggler.observe(name, res.prefill_latency_s * 1e3)

                token = int(np.argmax(row_logits))
                if append_first:  # recovery attempt: resume point reached
                    res.resumed_at_monotonic.append(time.monotonic())
                    res.tokens.append(token)
                    log.generated.append(token)
                else:
                    log.first_token = token
                length = true_len
                run_batch = (self._run_paged_decode(si) if self.paged
                             else self._run_decode_batch(si))
                i = 0
                while len(res.tokens) < steps:
                    if name in self._shed:
                        raise StreamShedError(
                            f"stream {name!r} shed by degraded-mode "
                            "admission")
                    if self.paged:
                        # planned migration (steal / consolidate / drain):
                        # the stream's own thread moves its blocks at this
                        # step boundary — no decode of this stream can be
                        # in flight, so the copy sees a quiescent sequence
                        dst = self.pool.pending_migration(name)
                        if (dst is not None and dst != si
                                and dst in self.pool.alive_servers()):
                            dst_slot = self._try_acquire_slot(dst)
                            if dst_slot is None:
                                # destination full right now: abandon the
                                # steal rather than block holding our slot
                                self.pool.cancel_migration(name)
                            else:
                                try:
                                    table, slab, seg = (
                                        self._execute_migration(
                                            name, seq_id, si, dst, prio))
                                except OutOfBlocksError:
                                    self._release_slot(dst, dst_slot)
                                    self.pool.cancel_migration(name)
                                except BaseException:
                                    self._release_slot(dst, dst_slot)
                                    raise
                                else:
                                    self._release_slot(si, slot)
                                    slot, si = dst_slot, dst
                                    server = self.pool.servers[si]
                                    run_batch = self._run_paged_decode(si)
                                    self._active_jobs[name] = si
                                    self.pool.complete_migration(name)
                    payload = ((token, table, length, slab, seg)
                               if self.paged else (slot, token))
                    t1 = time.monotonic()
                    req = server.submit_batch(
                        payload, run_batch=run_batch,
                        batch_key=("decode", si), priority=prio,
                        name=f"{name}/decode{i}")
                    row = req.wait()  # this row's logits, np.float32 (V,)
                    dt = time.monotonic() - t1
                    res.decode_latencies_s.append(dt)
                    self.straggler.observe(name, dt * 1e3)
                    token = int(np.argmax(row))
                    length += 1
                    res.tokens.append(token)
                    log.generated.append(token)
                    i += 1
            finally:
                self._active_jobs.pop(name, None)
                self._release_slot(si, slot)
        finally:
            if self.paged:
                self._paged_release(si, seq_id)
            else:
                self._kv_release(seq_id)

    # -- shared helpers -----------------------------------------------------
    def _prefill_batch(self, prompt: np.ndarray) -> dict:
        b = prompt.shape[0]
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (b, self.cfg.encoder_seq, self.cfg.d_model), self.cfg.dtype)
        return batch

    def _kv_reserve(self, name: str, prompt: np.ndarray, steps: int):
        if self.kv is None:
            return None
        with self._kv_lock:
            self._seq_counter += 1
            seq_id = f"{name}#{self._seq_counter}"
            # reserve prompt + all decode tokens up front (reject early
            # rather than stall mid-generation)
            self.kv.allocate(seq_id, prompt.shape[1])
            try:
                self.kv.extend(seq_id, steps)
            except Exception:
                self.kv.free_seq(seq_id)
                raise
        self._held.setdefault(name, set()).add((None, seq_id))
        return seq_id

    def _kv_release(self, seq_id) -> None:
        if seq_id is not None:
            held = self._held.get(seq_id.rsplit("#", 1)[0])
            if held is not None:
                held.discard((None, seq_id))
            with self._kv_lock:
                self.kv.free_seq(seq_id, missing_ok=True)

    # -- fault tolerance ----------------------------------------------------
    def enable_fault_tolerance(self, *, heartbeat_timeout_s: float = 0.5,
                               poll_s: float = 0.02, max_retries: int = 2,
                               retry_backoff_s: float = 0.005,
                               watchdog: bool = False) -> "ServeEngine":
        """Switch on failure detection + stream recovery.

        Wires the pool's HeartbeatMonitor (servers beat between device
        calls, so a call outlasting ``heartbeat_timeout_s`` is a stall —
        the monitor thread evicts the server from outside, making the
        timeout per-device-call), sets each server's transient-error retry
        budget, optionally attaches a StepTimeWatchdog, and installs
        ``_on_server_death`` as the pool's death handler so eviction flows
        into degraded-mode re-admission instead of blind re-routing.
        Returns self for chaining."""
        self._ft_params = {"max_retries": max_retries,
                           "retry_backoff_s": retry_backoff_s,
                           "watchdog": watchdog}
        for s in self.pool.servers:
            s.max_retries = max_retries
            s.retry_backoff_s = retry_backoff_s
            if watchdog and s.watchdog is None:
                s.watchdog = StepTimeWatchdog()
        self.pool.enable_failure_detection(
            timeout=heartbeat_timeout_s, poll=poll_s,
            on_death=self._on_server_death)
        return self

    def _on_server_death(self, si: int, displaced=None) -> None:
        """Single recovery entry point, reached from the heartbeat monitor
        (stall), a server's own failure callback (device loss), or a client
        thread that caught ServerFailedError.  Serialized and idempotent:
        whichever caller evicts the server runs degraded-mode re-admission;
        everyone else returns once it is done.

        Surviving displaced streams are re-bound to the device degraded
        admission proved them on (with their priced recovery segment);
        unfitting streams are shed in reverse-priority order and their
        generator threads observe ``_shed`` at the next segment boundary."""
        with self._recovery_lock:
            if displaced is None:
                displaced = self.pool.evict_server(si, reroute=False)
            if displaced is None:
                return  # another caller already recovered this server
            # migration race window: a stream whose admission slot already
            # moved to its steal destination (admission.migrate committed,
            # pool binding not yet flipped) was displaced here but will NOT
            # be re-placed by evict_device — re-bind it to its live
            # admission placement instead of dropping it
            for s in list(displaced):
                d = self.admission.placement.get(s)
                if d is not None and d != si and self.admission.alive[d]:
                    task = next(t for t in self.admission.devices[d].streams
                                if t.name == s)
                    self.pool.reassign(s, d, utilization=task.G / task.T,
                                       priority=task.priority)
                    displaced.pop(s)
            report = self.admission.evict_device(
                si, recovery_cost_ms=self._recovery_cost_ms)
            for s, d in report.moved.items():
                task = next(t for t in self.admission.devices[d].streams
                            if t.name == s)
                self.pool.reassign(s, d, utilization=task.G / task.T,
                                   priority=task.priority)
            for s in report.shed:
                self._shed.add(s)
            self.degraded_reports.append(report)

    def _recovery_cost_ms(self, task: Task) -> float:
        """Price a stream's recovery segment — the re-prefill of its
        retained prefix on the surviving device.  Declared worst case is
        the stream's own prefill cost; a fitted cost model caps it at the
        predicted cost of the largest prefill bucket (never upward,
        mirroring calibrated admission's min())."""
        spec = self._streams.get(task.name)
        declared = (spec.prefill_ms if spec is not None
                    else task.segments[0].total)
        if self.cost_model is not None:
            pred = self.cost_model.predict(self._prefill_kind, 1,
                                           self.prefill_buckets[-1])
            if math.isfinite(pred):
                pred_ms = pred * getattr(self.cost_model, "safety", 1.0) * 1e3
                declared = min(declared, pred_ms) if declared > 0 else pred_ms
        return float(declared)

    # -- work stealing / consolidation / elastic scale ---------------------
    def _migration_cost_ms(self, name: str) -> float:
        """Price a steal of ``name``: gather + scatter of a full-width
        block table (worst case — the mover pays for every lane whether
        live or scratch-padded) at the cost model's measured "migrate"
        cell, with the calibration safety margin.  0 when uncalibrated or
        unmeasured — the depth-gap rule decides instead."""
        if not self.paged or self.cost_model is None:
            return 0.0
        w = bucket_up(self._paged[0].nb_max, self.width_buckets)
        pred = self.cost_model.predict(self._migrate_kind, w,
                                       self.kv_block_size)
        if not math.isfinite(pred):
            return 0.0
        return 2.0 * pred * getattr(self.cost_model, "safety", 1.0) * 1e3

    def _steal_profitable(self, name: str, depth_src: int, depth_dst: int,
                          mc_ms: float, min_gain_ms: float) -> bool:
        """Steal only when predicted queueing relief beats the move's cost:
        the victim's remaining decode steps each save the difference
        between a depth_src-row and a (depth_dst+1)-row batched decode
        step.  Without a cost model (or an unmeasured decode phase), fall
        back to the depth-gap >= 2 rule — stealing across a 1-deep gap just
        thrashes."""
        if self.cost_model is None:
            return depth_src - depth_dst >= 2
        spec = self._streams.get(name)
        if spec is None:
            return False
        w = self.width_buckets[-1] if self.width_buckets else 0
        c_src = self.cost_model.predict(
            self._decode_kind, bucket_up(depth_src, self._row_buckets), w)
        c_dst = self.cost_model.predict(
            self._decode_kind, bucket_up(depth_dst + 1, self._row_buckets),
            w)
        if not (math.isfinite(c_src) and math.isfinite(c_dst)):
            return depth_src - depth_dst >= 2
        gain_ms = spec.decode_steps * max(0.0, c_src - c_dst) * 1e3
        return gain_ms - mc_ms >= min_gain_ms

    def rebalance_once(self, *, min_gain_ms: float | None = None) -> int:
        """One work-stealing pass: move queued-behind streams from the
        deepest server onto the shallowest until the depth gap closes or
        no move is profitable.  Returns the number of steals REQUESTED —
        each victim's own thread performs the block copy at its next
        decode-step boundary (see _attempt_batched), so depth accounting
        here counts pending migrations at their destination to avoid
        over-stealing while copies are in flight.

        Runs on the heartbeat tick (or the fallback timer thread) and
        yields to recovery: if ``_recovery_lock`` is held the pass is
        skipped — rebalancing mid-eviction would race degraded-mode
        re-admission."""
        if min_gain_ms is None:
            min_gain_ms = self._steal_min_gain_ms
        if not self._recovery_lock.acquire(blocking=False):
            return 0
        try:
            stolen = 0
            draining = self.pool.draining()
            live = [i for i in self.pool.alive_servers()
                    if i not in draining]
            if len(live) < 2:
                return 0
            while True:
                depths = {i: 0 for i in live}
                for nm, si in list(self._active_jobs.items()):
                    if si not in depths:
                        continue
                    pd = self.pool.pending_migration(nm)
                    depths[pd if pd in depths else si] += 1
                src = max(depths, key=lambda i: (depths[i], i))
                dst = min(depths, key=lambda i: (depths[i], -i))
                if depths[src] - depths[dst] < 2:
                    return stolen
                victims = sorted(
                    (nm for nm, si in list(self._active_jobs.items())
                     if si == src and nm in self._streams
                     and nm not in self._shed
                     and self.pool.pending_migration(nm) is None),
                    key=lambda nm: self._streams[nm].priority)
                moved_one = False
                for victim in victims:
                    mc = self._migration_cost_ms(victim)
                    if not self._steal_profitable(victim, depths[src],
                                                  depths[dst], mc,
                                                  min_gain_ms):
                        continue
                    decision, d = self.admission.migrate(
                        victim, dst, migration_cost_ms=mc)
                    if d < 0:
                        continue
                    if not self.pool.request_migration(victim, dst):
                        # stream vanished / destination became illegal
                        # between the admission move and the intent: put
                        # the admission slot back (best-effort — if the
                        # stream is gone this is a no-op too)
                        self.admission.migrate(victim, src)
                        continue
                    stolen += 1
                    moved_one = True
                    break
                if not moved_one:
                    return stolen
        finally:
            self._recovery_lock.release()

    def enable_work_stealing(self, *, interval_s: float = 0.05,
                             min_gain_ms: float = 0.0) -> "ServeEngine":
        """Switch on periodic rebalancing.  Piggybacks on the heartbeat
        monitor's tick when fault tolerance is enabled (one thread, one
        cadence, same teardown guarantees); otherwise runs a dedicated
        daemon timer at ``interval_s``.  ``min_gain_ms`` is the minimum
        predicted net win (queueing relief minus migration cost) before a
        steal fires.  Returns self for chaining."""
        self._steal_min_gain_ms = float(min_gain_ms)

        def tick() -> None:
            try:
                self.rebalance_once()
            except Exception:
                pass  # best-effort: never kill the timer/monitor thread

        if self.pool._monitor is not None:
            self.pool._monitor.on_tick = tick
            return self
        stop = threading.Event()
        self._steal_stop = stop

        def loop() -> None:
            while not stop.wait(interval_s):
                tick()

        threading.Thread(target=loop, daemon=True,
                         name="steal-rebalance").start()
        return self

    def consolidate(self, si: int) -> dict[str, int]:
        """Drain server ``si`` by moving every stream it owns elsewhere:
        streams with a job in flight get a migration intent (their own
        thread moves the blocks at the next step boundary); idle streams
        are re-bound directly (nothing to copy — their next job prefills
        on the new server).  Each move is re-proven by admission first; a
        stream no destination can prove STAYS PUT and keeps running on the
        draining server (consolidation is an optimization, never a shed).
        Returns {stream: destination}.  ``remove_server`` completes the
        retirement once the server is empty."""
        self.pool.begin_drain(si)
        draining = self.pool.draining()
        dests = sorted((d for d in self.pool.alive_servers()
                        if d != si and d not in draining),
                       key=self.admission.gpu_utilization)
        moved: dict[str, int] = {}
        for name in self.pool.streams_on(si):
            active = self._active_jobs.get(name) == si
            mc = self._migration_cost_ms(name) if active else 0.0
            got = -1
            for d in dests:
                _, got = self.admission.migrate(name, d,
                                                migration_cost_ms=mc)
                if got >= 0:
                    break
            if got < 0:
                continue
            if active:
                self.pool.request_migration(name, got)
            else:
                task = next(t for t in self.admission.devices[got].streams
                            if t.name == name)
                self.pool.reassign(name, got, utilization=task.G / task.T,
                                   priority=task.priority)
            moved[name] = got
            dests.sort(key=self.admission.gpu_utilization)
        return moved

    def add_server(self) -> int:
        """Elastic scale-up: grow the pool AND the admission partition by
        one device mid-traffic; returns the new server index.  The server
        inherits the pool's fault-tolerance settings (retry budget,
        watchdog, heartbeat wiring — the pool handles the monitor), gets
        its own slot/paged state, and warms its pools on its own thread —
        the jitted shape cells are shared engine-wide, so no new XLA
        traces happen; a freshly-joined server serves its first request at
        full speed."""
        with self._recovery_lock:
            si = self.pool.add_server()
            di = self.admission.add_device()
            if si != di:
                raise RuntimeError(
                    f"pool/admission index drift: server {si} vs device "
                    f"{di}")
            if self.batching:
                self._slots.append(_SlotState(self.max_batch))
            if self.paged:
                self._paged.append(_PagedState(
                    self.cfg, self._num_blocks, self.kv_block_size,
                    self.max_batch, self.max_seq, family=self._family,
                    num_slabs=self._num_slabs,
                    num_segments=self._num_segments))
            s = self.pool.servers[si]
            if self._ft_params is not None:
                s.max_retries = self._ft_params["max_retries"]
                s.retry_backoff_s = self._ft_params["retry_backoff_s"]
                if self._ft_params["watchdog"] and s.watchdog is None:
                    s.watchdog = StepTimeWatchdog()
        s.submit(lambda: self._precompile_server(si, [], [], []),
                 name=f"precompile-{si}").wait()
        return si

    def remove_server(self, si: int, *, timeout_s: float = 10.0) -> None:
        """Elastic scale-down: drain server ``si``, migrate its streams to
        proven destinations (live-KV migration for in-flight streams, a
        plain re-bind for idle ones), shed what the shrunk pool cannot
        prove, wait for the server to empty, and retire it.  Unlike
        ``consolidate`` this is a COMMITTED shrink — admission re-proves
        the whole placement via ``drain_device`` (identical machinery to
        failure eviction, priced as a cheap block copy instead of a
        re-prefill) and appends the resulting DegradedReport.  Raises
        TimeoutError if in-flight work does not clear in ``timeout_s``."""
        with self._recovery_lock:
            self.pool.begin_drain(si)
            report = self.admission.drain_device(
                si, migration_cost_ms=lambda t: self._migration_cost_ms(
                    t.name))
            for s, d in report.moved.items():
                if self._active_jobs.get(s) == si:
                    self.pool.request_migration(s, d)
                else:
                    task = next(t for t in self.admission.devices[d].streams
                                if t.name == s)
                    self.pool.reassign(s, d, utilization=task.G / task.T,
                                       priority=task.priority)
            for s in report.shed:
                self._shed.add(s)
                self.pool.remove(s)
            self.degraded_reports.append(report)
        deadline = time.monotonic() + timeout_s
        while (any(d == si for d in self._active_jobs.values())
               or self.pool.streams_on(si)):
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"server {si} did not drain within {timeout_s}s")
            time.sleep(0.005)
        self.pool.retire_server(si)

    def kv_usage(self) -> dict:
        """Per-kind pooled-cache occupancy across every manager —
        {"blocks", "slabs", "segments"} — excluding each paged server's
        permanently-held scratch resources.  Every count must return to
        zero once all streams drain (the per-family leak probe)."""
        usage = {"blocks": self.kv.blocks_in_use if self.kv is not None
                 else 0, "slabs": 0, "segments": 0}
        if self.paged:
            for st in self._paged:
                scratch = st.mgr.seqs.get("__scratch__")
                sb = len(scratch.blocks) if scratch is not None else 0
                ss = 1 if scratch is not None and scratch.slab is not None \
                    else 0
                sg = (1 if scratch is not None
                      and scratch.segment is not None else 0)
                usage["blocks"] += st.mgr.blocks_in_use - sb
                usage["slabs"] += st.mgr.slabs_in_use - ss
                usage["segments"] += st.mgr.segments_in_use - sg
        return usage

    def kv_blocks_in_use(self) -> int:
        """Total pooled-cache resources (blocks + slabs + segments) held
        across every manager, scratch excluded — i.e. the count that must
        return to zero once all streams drain (the chaos suite's leak
        check; see kv_usage() for the per-kind breakdown)."""
        return sum(self.kv_usage().values())

    def close(self) -> None:
        if self._steal_stop is not None:
            self._steal_stop.set()
        self.pool.shutdown()


def _cache_batch_axes(cfg, max_seq: int):
    """Per-leaf batch axis of the decode cache, discovered by diffing the
    shapes of a 1-row and a 2-row cache (family-agnostic: stacked layer
    leaves are (L,B,...), unstacked ones (B,...))."""
    c1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, max_seq))
    c2 = jax.eval_shape(lambda: M.init_cache(cfg, 2, max_seq))

    def axis(a, b):
        for i, (da, db) in enumerate(zip(a.shape, b.shape)):
            if da != db:
                return i
        raise ValueError(f"no batch axis found in cache leaf {a.shape}")

    return jax.tree.map(axis, c1, c2)
