"""Checkpointing: atomic, resharding-on-restore, numpy-backed.

Layout of a checkpoint directory:
    <root>/step_<N>/manifest.json     tree structure, shapes, dtypes, step
    <root>/step_<N>/arr_<k>.npy       one file per leaf
    <root>/LATEST                     name of the newest complete step dir

Writes go to ``step_<N>.tmp`` and are renamed only after the manifest is
fsync'd — a preempted/killed writer never corrupts the latest checkpoint
(restart-safety for the fault-tolerance runtime).  ``restore`` accepts a
target sharding tree and device_puts each leaf accordingly, so restoring
onto a *different* mesh (elastic rescale) is the same code path.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str | pathlib.Path, step: int, tree, *, keep_last: int = 3) -> str:
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    meta = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
        else None,
        "num_leaves": len(leaves),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8, ...):
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))  # portable view
        np.save(tmp / f"arr_{i}.npy", arr, allow_pickle=False)
        meta["leaves"].append({"shape": list(arr.shape), "dtype": true_dtype,
                               "stored": str(arr.dtype)})

    with open(tmp / MANIFEST, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)

    latest = root / "LATEST"
    latest_tmp = root / "LATEST.tmp"
    latest_tmp.write_text(final.name)
    latest_tmp.rename(latest)

    _gc(root, keep_last)
    return str(final)


def _gc(root: pathlib.Path, keep_last: int) -> None:
    steps = sorted(p for p in root.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: str | pathlib.Path) -> int | None:
    root = pathlib.Path(root)
    latest = root / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    if not (root / name / MANIFEST).exists():
        return None
    return int(name.split("_")[1])


def restore(root: str | pathlib.Path, tree_like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.  ``shardings`` (optional
    pytree of NamedSharding, same structure) reshards on load — restoring a
    checkpoint onto a different mesh (elastic shrink/grow) goes through this
    path."""
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    meta = json.loads((d / MANIFEST).read_text())

    leaves_like, treedef = _flatten(tree_like)
    if meta["num_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {meta['num_leaves']} leaves, target {len(leaves_like)}")
    shard_leaves = (_flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))

    out = []
    for i, (like, shard) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(d / f"arr_{i}.npy", allow_pickle=False)
        true_dtype = meta["leaves"][i]["dtype"]
        if str(arr.dtype) != true_dtype:
            arr = arr.view(jax.numpy.dtype(true_dtype))  # ml_dtypes view back
        want_shape = tuple(like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {i}: checkpoint {arr.shape} vs target {want_shape}")
        if shard is not None:
            out.append(jax.device_put(arr.astype(like.dtype), shard))
        else:
            out.append(jax.numpy.asarray(arr.astype(like.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), step
