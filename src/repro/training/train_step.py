"""Train-step assembly: loss + remat + AdamW + (optional) DP gradient
compression, with sharding-aware jit for the production mesh.

``build_train_step`` returns a jitted function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with in/out shardings derived from distributed.sharding.param_specs, so the
same builder serves the CPU smoke tests (mesh=None), the examples, and the
512-device dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model as M
from repro.training import optimizer as opt

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}


@dataclass(frozen=True)
class TrainSettings:
    adamw: opt.AdamWConfig = field(default_factory=opt.AdamWConfig)
    remat: bool = True
    remat_policy: str = "dots_no_batch"
    grad_accum: int = 1  # microbatch accumulation steps
    aux_weight: float = 0.01
    # beyond-paper §Perf knobs
    compress_dp_grads: bool = False  # int8+error-feedback DP reduction


def make_loss(cfg, settings: TrainSettings):
    policy = REMAT_POLICIES[settings.remat_policy]

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch, remat=settings.remat,
                         remat_policy=policy, aux_weight=settings.aux_weight)

    return loss


def _split_microbatches(batch, n: int):
    def f(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] % n == 0:
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])
        return None
    # mrope positions have batch on axis 1: handle dict-wise
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":
            out[k] = v.reshape(v.shape[0], n, v.shape[1] // n, *v.shape[2:]).swapaxes(0, 1)
        elif hasattr(v, "ndim"):
            out[k] = v.reshape(n, v.shape[0] // n, *v.shape[1:])
        else:
            out[k] = v
    return out


def train_step_fn(cfg, settings: TrainSettings):
    loss_fn = make_loss(cfg, settings)

    def step(params, opt_state, batch):
        if settings.grad_accum > 1:
            micro = _split_microbatches(batch, settings.grad_accum)

            def acc_body(carry, mb):
                gacc, lacc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / settings.grad_accum, gsum)
            loss = lsum / settings.grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)

        params, opt_state, opt_metrics = opt.update(
            grads, opt_state, params, settings.adamw)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return step


def batch_specs(cfg, batch_shapes, rules: shd.ShardingRules):
    """PartitionSpec tree for a train/serve batch: batch dim over DP axes
    (left unsharded when the batch doesn't divide them, e.g. long_500k's
    global_batch=1)."""
    import math

    n_dp = math.prod(rules.mesh.shape[a] for a in rules.batch_axes) \
        if rules.mesh is not None else 1

    def b_for(size: int):
        return rules.batch() if size % max(n_dp, 1) == 0 else None

    def spec(path, leaf):
        name = str(path[-1].key) if path else ""
        if name == "mrope_positions":  # (3, B, S)
            return P(None, b_for(leaf.shape[1]), None)
        if name in ("frames", "embeds"):  # (B, T, D)
            return P(b_for(leaf.shape[0]), None, None)
        if leaf.ndim >= 1:
            return P(b_for(leaf.shape[0]), *([None] * (leaf.ndim - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def build_train_step(cfg, settings: TrainSettings, rules: shd.ShardingRules | None,
                     batch_shapes=None):
    """jit the step.  With rules/mesh: donate + explicit shardings (used by
    the dry-run and launchers).  Without: plain jit (CPU tests)."""
    step = train_step_fn(cfg, settings)
    if rules is None or rules.mesh is None:
        # no donation on the test/CPU path: callers reuse the input trees
        return jax.jit(step)

    mesh = rules.mesh

    def wrapped(params, opt_state, batch):
        with shd.use_rules(rules):
            return step(params, opt_state, batch)

    params_shape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                  jax.random.PRNGKey(0))
    pspecs = shd.param_specs(params_shape, rules)
    opt_shape = jax.eval_shape(lambda p: opt.init(p, settings.adamw), params_shape)
    ospecs = _opt_specs(opt_shape, pspecs)
    bspecs = batch_specs(cfg, batch_shapes, rules)

    to_named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    metrics_sharding = None  # replicated scalars
    return jax.jit(
        wrapped,
        in_shardings=(to_named(pspecs), to_named(ospecs), to_named(bspecs)),
        out_shardings=(to_named(pspecs), to_named(ospecs), metrics_sharding),
        donate_argnums=(0, 1),
    )


def _opt_specs(opt_shape, pspecs):
    """Optimizer-state specs mirror the param specs leaf-for-leaf."""
    out = {}
    for k, sub in opt_shape.items():
        if k == "step":
            out[k] = P()
        else:
            out[k] = pspecs
    return out
