"""Gradient compression for data-parallel reduction (distributed-optimization
trick): 8-bit quantization with error feedback.

Scheme (per leaf, inside shard_map over the DP axes):
  1. shared scale: pmax of the local absmax over the DP axes (tiny scalar
     collective), scale = absmax / 127;
  2. q = round((g + err)/scale), clipped to [-127, 127] — int8 payload,
     carried as int16 on the wire so the psum accumulation cannot overflow
     (|sum| <= n*127, safe for n <= 257 shards);
  3. mean = psum(q) * scale / n;
  4. err' = (g + err) - q*scale  (error feedback: quantization error is
     re-injected next step — the Seide/Karimireddy condition that keeps
     compressed SGD convergent).

The wire format is 2 bytes/element vs 4 for fp32 — the win targets the
``pod`` axis (DCN) where gradient all-reduce bandwidth is the multi-pod
bottleneck.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def quantize_int8(x):
    """x fp -> (q int8, scale fp32).  Symmetric per-tensor scaling."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _psum_compressed_leaf(g, e, axes, n: int):
    corrected = g.astype(jnp.float32) + e
    absmax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axes)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int16), axes)
    mean = total.astype(jnp.float32) * scale / n
    new_err = corrected - q * scale
    return mean.astype(g.dtype), new_err


def compressed_psum_mean(grads, err, mesh, axes: tuple[str, ...]):
    """All-reduce-mean grads over ``axes`` with int8 compression + error
    feedback.  grads/err leaves must be replicated (or identically sharded)
    over ``axes``; leaves keep whatever sharding they have on other axes.

    Returns (mean_grads, new_err)."""
    n = math.prod(mesh.shape[a] for a in axes)
    if n > 257:
        raise ValueError(f"int16 wire overflows beyond 257 shards, got {n}")

    def body(g, e):
        return jax.tree.map(
            lambda gl, el: _psum_compressed_leaf(gl, el, axes, n), g, e)

    # treat every leaf as fully local per shard on `axes`; other mesh axes
    # pass through unsharded specs (caller reshards around this op)
    specs = jax.tree.map(lambda _: P(), grads)
    out = shard_map(body, mesh=mesh, in_specs=(specs, specs),
                        out_specs=jax.tree.map(lambda _: (P(), P()), grads))
    pairs = out(grads, err)
    mean = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    return mean, new_err
