"""AdamW with sharding-friendly, dtype-configurable state.

State mirrors the parameter tree leaf-for-leaf (so the parameter sharding
specs apply verbatim to the optimizer state), plus a scalar step counter.

Memory knobs that matter at 405B scale (16 GB HBM/chip on v5e):
  * ``moment_dtype='bfloat16'`` halves m/v;
  * ``master_dtype='float32'`` keeps a full-precision master copy when the
    params are bf16 (set to None to update bf16 params directly).
With bf16 params + bf16 moments + fp32 master: 2+2+2+4 = 10 bytes/param
-> 405B params = 4.05 TB, < 256 chips x 16 GB.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "bfloat16"
    master_dtype: str | None = "float32"


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    state = {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_dtype is not None:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params)
    return state


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    masters = state.get("master", params)

    def leaf(g, m, v, p, master):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        upd = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        new_master = master.astype(jnp.float32) * (1 - lr * cfg.weight_decay) - lr * upd
        return m32.astype(mdt), v32.astype(mdt), new_master

    out = jax.tree.map(leaf, grads, state["mu"], state["nu"], params, masters)
    mu = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_master = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))

    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"mu": mu, "nu": nu, "step": step}
    if cfg.master_dtype is not None:
        new_state["master"] = jax.tree.map(
            lambda nm: nm.astype(jnp.dtype(cfg.master_dtype)), new_master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
