"""Pipeline parallelism over a mesh axis (GPipe schedule, shard_map +
collective_permute).

Intended use at fleet scale: stage the layer stack over the ``pod`` axis so
only activations (MBs) cross the DCN boundary instead of gradient
all-reduces (GBs) — the multi-pod alternative to pod-level DP.

Mechanics (the standard JAX collective pipeline):
  * each pipeline rank holds ``layers_per_stage`` consecutive layers
    (weights sharded on the stacked-layer axis via shard_map in_specs);
  * the schedule runs ``num_microbatches + num_stages - 1`` ticks; at each
    tick every rank applies its stage to its current activation, then the
    activations rotate one rank forward via ppermute;
  * rank 0 injects a fresh microbatch each tick (while any remain), rank
    P-1 emits a finished microbatch per tick after the fill phase;
  * bubble fraction = (P-1)/(M+P-1), the usual GPipe cost.

``pipeline_apply`` is differentiable (ppermute transposes to the reverse
permutation), so it drops into the training loss unchanged.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def pipeline_apply(stage_fn, params, x_mb, *, mesh, axis: str, out_like=None):
    """Run a GPipe pipeline over mesh axis ``axis``.

    stage_fn(stage_params, x) -> y  applies ONE stage (its slice of
    layers).  ``params`` leaves must be stacked with a leading
    ``num_stages`` axis (shard_map shards them so each rank sees its
    stage's slice, with the leading axis collapsed to size 1).
    ``x_mb`` is (num_microbatches, mb_size, ...) and the result has the
    same shape.
    """
    n_stages = mesh.shape[axis]
    n_mb = x_mb.shape[0]
    ticks = n_mb + n_stages - 1

    def run(local_params, xs):
        # local_params leaves: (1, ...) stage slice; drop the stage axis
        sparams = jax.tree.map(lambda a: a[0], local_params)
        rank = jax.lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        # pad the microbatch stream through the drain phase
        pad = jnp.zeros((n_stages - 1, *xs.shape[1:]), xs.dtype)
        stream = jnp.concatenate([xs, pad], axis=0)

        def tick(state, x_in):
            # inject at stage 0, everyone computes, rotate forward
            state = jnp.where(rank == 0, x_in, state)
            out = stage_fn(sparams, state)
            emitted = out  # meaningful on the last rank only
            state = jax.lax.ppermute(out, axis, perm)
            return state, emitted

        state0 = jnp.zeros_like(xs[0])
        # the carry becomes rank-varying after the first ppermute: mark it
        # so (pvary only exists once the varying-axes checker does, jax >=
        # 0.6; older releases need no marking)
        if hasattr(jax.lax, "pvary"):
            state0 = jax.lax.pvary(state0, (axis,))
        _, emitted = jax.lax.scan(tick, state0, stream)
        # finished microbatch m leaves the last rank at tick m + P - 1
        outs = emitted[n_stages - 1:]
        # replicate the last rank's outputs (masked psum proves replication
        # to the varying-axes checker, unlike a broadcast ppermute)
        mask = (rank == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    pspec = jax.tree.map(lambda _: P(axis), params)
    return shard_map(
        run, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
    )(params, x_mb)


def stack_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""

    def f(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])

    return jax.tree.map(f, layer_params)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
