"""Sharding rules: logical activation/parameter shardings for the production
mesh, applied via a thread-local context so model code stays mesh-agnostic
(no-ops on CPU smoke tests).

Mesh axes (launch/mesh.py):
  single-pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16)   # pod extends the data dimension

Parallelism mapping:
  * batch            -> ('pod','data')  (DP; pod axis is DP across DCN)
  * sequence (long)  -> 'model'         (SP for prefill/decode caches)
  * attention heads / FFN columns / experts / vocab -> 'model'   (TP/EP)
  * parameters       -> TP axis over 'model'; optionally FSDP over 'data'
"""

from __future__ import annotations

import re
import threading
from dataclasses import dataclass, field
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax >= 0.6 exposes shard_map at the top level; 0.4.x keeps it experimental.
try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map

_CTX = threading.local()


@dataclass
class ShardingRules:
    mesh: Mesh | None = None
    batch_axes: tuple[str, ...] = ("data",)  # ('pod','data') multi-pod
    model_axis: str = "model"
    fsdp: bool = True  # shard the non-TP param axis over 'data'
    shard_seq: bool = False  # sequence-parallel activations/caches
    # decode long-context: shard cache sequence over (data+model)
    seq_axes: tuple[str, ...] = ("model",)
    # serving/§Perf: shard expert FFN width over the DP axes so MoE decode
    # gathers tokens instead of expert weights (models/moe._moe_decode_tpdata)
    expert_ff_fsdp: bool = False
    # serving/§Perf: 2D tensor parallelism for decode — weights stay fully
    # sharded over (data x model), activations are replicated over the batch
    # axes (psum-combined), the KV cache shards its sequence over both axes.
    # Removes the per-layer FSDP weight all-gathers that dominate decode.
    shard_batch: bool = True

    def batch(self) -> Any:
        if not self.shard_batch:
            return None
        return tuple(self.batch_axes) if len(self.batch_axes) > 1 else self.batch_axes[0]

    def fsdp_axis(self):
        return "data" if self.fsdp else None


def set_rules(rules: ShardingRules | None) -> None:
    _CTX.rules = rules


def current_rules() -> ShardingRules | None:
    return getattr(_CTX, "rules", None)


class use_rules:
    """Context manager: ``with use_rules(rules): ...``"""

    def __init__(self, rules: ShardingRules | None):
        self.rules = rules

    def __enter__(self):
        self.prev = current_rules()
        set_rules(self.rules)
        return self.rules

    def __exit__(self, *exc):
        set_rules(self.prev)


def _constrain(x, spec: P):
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# -- activation shardings ----------------------------------------------------


def shard_tokens(x):
    """(B, S) int tokens."""
    r = current_rules()
    if r is None:
        return x
    seq = r.model_axis if r.shard_seq else None
    return _constrain(x, P(r.batch(), seq))


def shard_hidden(x):
    """(B, S, D) activations: batch over DP; seq over model when SP is on."""
    r = current_rules()
    if r is None:
        return x
    seq = r.model_axis if r.shard_seq else None
    return _constrain(x, P(r.batch(), seq, None))


def shard_heads(x):
    """(B, S, N, H) per-head activations: heads over the model axis."""
    r = current_rules()
    if r is None:
        return x
    return _constrain(x, P(r.batch(), None, r.model_axis, None))


def shard_logits(x):
    """(B, S, V) logits: vocab over the model axis."""
    r = current_rules()
    if r is None:
        return x
    return _constrain(x, P(r.batch(), None, r.model_axis))


def shard_ffn(x):
    """(B, S, F) FFN activations: columns over the model axis."""
    r = current_rules()
    if r is None:
        return x
    return _constrain(x, P(r.batch(), None, r.model_axis))


def shard_cache_seq(x, *, batch_axis: int, seq_axis: int):
    """KV/conv caches: shard batch over DP and the sequence axis over the
    model axis (sequence parallelism for long contexts).  When batch is 1
    (long_500k), the sequence is spread over every mesh axis instead."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = [None] * x.ndim
    if x.shape[batch_axis] == 1:
        spec[seq_axis] = (*r.batch_axes, r.model_axis)
    else:
        spec[batch_axis] = r.batch()
        spec[seq_axis] = r.seq_axes if len(r.seq_axes) > 1 else r.seq_axes[0]
    return _constrain(x, P(*spec))


# -- parameter shardings -----------------------------------------------------

# leaf-name-pattern -> spec builder; {tp} is the model axis, {fsdp} the
# optional data axis.  Layer-stacked leaves get a leading None inserted by
# param_specs().  Patterns are matched against the '/'-joined tree path.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("tp", "fsdp")),             # (V, D): vocab over TP
    (r"pos_embed$", (None, None)),
    (r"lm_head$", ("fsdp", "tp")),           # (D, V)
    (r"w_dkv$", ("fsdp", None)),             # (D, R+Pr): replicated latent
    (r"(wq|wq_a|wq_b)$", ("fsdp", "tp", None)),  # (D, N, H)
    (r"(wk|wv)$", ("fsdp", "tp", None)),
    (r"wo$", ("tp", None, "fsdp")),          # (N, H, D)
    (r"(w_uk|w_uv)$", (None, "tp", None)),   # (R, N, H): heads over TP
    (r"w_krope$", ("fsdp", None)),
    (r"experts/(w_gate|w_up)$", ("tp", "fsdp", None)),  # (E, D, F): EP
    (r"experts/w_down$", ("tp", None, "fsdp")),         # (E, F, D)
    (r"(w_gate|w_up)$", ("fsdp", "tp")),     # (D, F)
    (r"w_down$", ("tp", "fsdp")),            # (F, D)
    (r"router$", ("fsdp", None)),            # (D, E)
    (r"in_proj$", ("fsdp", "tp")),           # SSM in projection (D, inner)
    (r"(z_proj|xbc_proj|dt_proj)$", ("fsdp", "tp")),  # split SSM projections
    (r"out_proj$", ("tp", "fsdp")),          # SSM out projection (inner, D)
    (r"conv_w$", (None, "tp")),              # (width, conv_dim)
    (r"(A_log|dt_bias|ssm_D)$", ("tp",)),    # per-head SSM params
    (r"(norm|scale|bias|b)$", (None,)),      # norms & small vectors
]


def _spec_for(path: str, shape: tuple[int, ...], rules: ShardingRules) -> P:
    tp = rules.model_axis
    fsdp = rules.fsdp_axis()
    if rules.expert_ff_fsdp and re.search(r"experts/", path):
        # serving layout: experts over TP, FFN width over the DP axes
        dp = rules.batch_axes if len(rules.batch_axes) > 1 else rules.batch_axes[0]
        pad = [None] * (len(shape) - 3)
        if re.search(r"experts/(w_gate|w_up)$", path):  # (E, D, F)
            return P(*pad, tp, None, dp)
        if re.search(r"experts/w_down$", path):  # (E, F, D)
            return P(*pad, tp, dp, None)
    for pat, proto in _PARAM_RULES:
        if re.search(pat, path):
            if len(proto) > len(shape):
                proto = proto[-len(shape):]
            axes = []
            for i, a in enumerate(proto):
                name = {"tp": tp, "fsdp": fsdp}.get(a, a) if isinstance(a, str) else a
                # never shard an axis that isn't divisible by the mesh axis
                if name is not None and rules.mesh is not None:
                    size = rules.mesh.shape[name] if not isinstance(name, tuple) else 1
                    if shape[i + (len(shape) - len(proto))] % max(size, 1) != 0:
                        name = None
                axes.append(name)
            pad = [None] * (len(shape) - len(proto))
            return P(*pad, *axes)
    return P(*([None] * len(shape)))


def param_specs(params_shape, rules: ShardingRules, *, stacked_prefix: int = 0):
    """Build a PartitionSpec pytree matching ``params_shape`` (a pytree of
    ShapeDtypeStruct, e.g. from jax.eval_shape(init_params, ...))."""

    def build(path, leaf):
        pathstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return _spec_for(pathstr, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(build, params_shape)


def named(params_specs, rules: ShardingRules):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), params_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
