"""Deterministic synthetic token pipeline.

Requirements it satisfies (the ones a real pipeline must):
  * deterministic & stateless-by-step: batch(step) is a pure function of
    (seed, step, shard) — restart/elastic-rescale resume needs no data
    state in the checkpoint beyond the step counter;
  * shardable: each data shard materializes only its slice;
  * prefetched: a background thread keeps ``prefetch`` batches ahead so
    host input never serializes with device steps (compute/IO overlap).

The token distribution is a Zipf-ish categorical over the vocab with a
deterministic per-(step, shard) PCG64 stream; labels are next-token.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig, *, shard: int = 0, num_shards: int = 1):
        if cfg.global_batch % num_shards:
            raise ValueError("global_batch must divide across shards")
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(np.random.PCG64(
            [cfg.seed, step, self.shard, 0xD1CE]))
        # zipf over vocab, clipped
        toks = rng.zipf(cfg.zipf_a, size=(self.local_batch, cfg.seq_len + 1))
        toks = (toks - 1) % cfg.vocab_size
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of ``source.batch(step)``."""

    def __init__(self, source: SyntheticLM, *, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
