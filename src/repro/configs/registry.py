"""Model configuration schema + registry for the 10 assigned architectures.

Every architecture is a selectable config (``--arch <id>`` in the launchers).
``reduced()`` yields the CPU-smoke-test variant of the same family (small
depth/width/experts/vocab); the FULL configs are exercised only through the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field

__all__ = ["ModelConfig", "ShapeSpec", "get_config", "list_configs", "SHAPES", "shapes_for"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention
    attn_type: str = "gqa"  # gqa | mla | none
    rope_theta: float = 1e4
    mrope: bool = False  # qwen2-vl multimodal rotary
    mrope_sections: tuple[int, int, int] = (16, 24, 24)

    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    conv_width: int = 4

    # hybrid (zamba2): one shared attention block applied every `attn_every`
    # layers (weights shared across applications)
    attn_every: int = 0

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper-medium: 30s audio -> 1500 frames

    # frontend stub: model consumes precomputed embeddings, not raw tokens
    embed_inputs: bool = False

    # paged-serving cache family (serving.kvcache.FAMILIES key): which pooled
    # cache layout this arch decodes under ("gqa" | "mla" | "ssm" | "hybrid" |
    # "encdec").  "" -> derived (only plain GQA stacks derive one implicitly;
    # everything else must declare or it gets NO paged path — never a silent
    # dense fallback).
    cache_family: str = ""

    mlp_type: str = "swiglu"  # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # True if the sequence-mixing backbone is sub-quadratic (SSM/hybrid):
    # eligibility for the long_500k shape
    subquadratic: bool = False

    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -----------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytical parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models.model import param_count

        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import param_count

        return param_count(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2 + (2 if self.attn_every else 0)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=128,
        )
        if self.attn_every:
            r["attn_every"] = 2
            r["num_layers"] = 5  # 2 groups of (1 mamba + 1 attn) + 1 extra
        if self.is_moe:
            r.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=32,
                     num_shared_experts=min(self.num_shared_experts, 1),
                     first_dense_layers=min(self.first_dense_layers, 1))
        if self.attn_type == "mla":
            r.update(kv_lora_rank=32, qk_rope_head_dim=8, qk_nope_head_dim=16,
                     v_head_dim=16)
        if self.ssm_state_dim:
            r.update(ssm_state_dim=16, ssm_head_dim=16)
        if self.encoder_layers:
            r.update(encoder_layers=2, encoder_seq=16)
        return dataclasses.replace(self, **r)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "granite_34b",
    "internlm2_1_8b",
    "llama3_405b",
    "internlm2_20b",
    "zamba2_7b",
    "deepseek_v2_lite_16b",
    "qwen3_moe_235b_a22b",
    "qwen2_vl_2b",
    "whisper_medium",
    "mamba2_780m",
]


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    """The assigned shape cells for this arch.  long_500k only for
    sub-quadratic backbones (skip noted in DESIGN.md §5)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def list_configs() -> list[str]:
    return list(ARCH_IDS)
