"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch, code  [arXiv:2405.04324; hf]"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,  # MQA
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",  # GPTBigCode-style 2-matrix MLP (the 34B total requires
    # it: swiglu at d_ff=24576 would give ~47B params)
    rope_theta=1e4,
    notes="Granite code 34B; multi-query attention (single KV head).",
)
