"""whisper-medium [audio]: 24L(+24L enc) d_model=1024 16H d_ff=4096
vocab=51865 — enc-dec, conv frontend stubbed  [arXiv:2212.04356]

Backbone only: the log-mel + conv1d frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (B, 1500, d_model) for the encoder.
The decoder is a standard causal transformer with cross-attention.
"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,  # decoder layers
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_type="gelu",
    tie_embeddings=True,  # whisper ties the decoder embedding and unembedding
    rope_theta=0.0,  # whisper uses learned/sinusoidal absolute positions
    embed_inputs=False,  # decoder consumes tokens; encoder consumes embeddings
    cache_family="encdec",  # paged self-KV + refcounted shared cross segments
    notes="Whisper-medium backbone; conv frontend stubbed via input_specs().",
)
