"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]

Structure here: a shared (single-weight) attention+MLP block is applied
every 6th layer; the rest are Mamba2 blocks.  (Real Zamba2 adds per-use LoRA
deltas on the shared block; omitted — noted in DESIGN.md.)
"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    attn_every=6,
    mlp_type="swiglu",
    subquadratic=True,  # Mamba2 backbone; attention is sparse-in-depth
    cache_family="hybrid",  # paged decode: attn block pools + mamba slabs
    notes="Zamba2-7B hybrid: Mamba2 layers + shared attn block every 6 layers.",
)
