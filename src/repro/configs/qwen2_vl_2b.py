"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
— M-RoPE, dynamic resolution  [arXiv:2409.12191; hf]

Backbone only: the vision patch-embed frontend is a STUB — ``input_specs()``
provides precomputed, merged token embeddings (B, S, d_model) plus the
3-stream M-RoPE position ids (3, B, S) for (temporal, height, width).
"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    mrope_sections=(16, 24, 24),  # t/h/w splits of the 128-dim rotary space
    embed_inputs=True,
    mlp_type="swiglu",
    rope_theta=1e6,
    tie_embeddings=True,
    notes="Qwen2-VL 2B backbone; vision frontend stubbed via input_specs().",
)
