"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) moe_d_ff=1536
vocab=151936, MoE 128 experts top-8  [hf:Qwen/Qwen3-30B-A3B family]"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,  # no dense layers; all layers MoE
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    num_shared_experts=0,
    moe_d_ff=1536,
    first_dense_layers=0,
    mlp_type="swiglu",
    rope_theta=1e6,
    notes="Qwen3-MoE 235B-A22B: 128 experts, top-8, no shared expert.",
)
