"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060]"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    attn_type="none",
    d_ff=0,  # no separate MLP: Mamba2 blocks only
    vocab_size=50280,
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    conv_width=4,
    tie_embeddings=True,
    subquadratic=True,
    cache_family="ssm",  # paged decode over fixed-size state-slab pools
    notes="Mamba2-780m: pure SSD blocks, d_inner=3072, 48 heads of 64.",
)
