"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(moe)=1408
vocab=102400, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared
[arXiv:2405.04434; hf]

The assignment line reads "MoE 64e top-6 ... 2 shared+160 routed top-6"; the
"160 routed" matches full DeepSeek-V2, not Lite — we follow the Lite spec
(64 routed) per the primary "MoE 64e top-6" designation (DESIGN.md §5).
First layer is dense (d_ff = 10944 in HF config; we use the dense d_ff for
that layer).
"""

from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MLA: kv heads = q heads after decompression
    d_ff=10944,  # dense-layer FFN width (layer 0)
    vocab_size=102400,
    head_dim=192,  # qk_nope (128) + qk_rope (64)
    attn_type="mla",
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    mlp_type="swiglu",
    cache_family="mla",  # paged decode over shared-latent block pools
    notes="DeepSeek-V2-Lite: MLA attention + fine-grained MoE.",
)
