"""Schedulability analysis for the synchronization-based approach under FMLP+.

The paper evaluates FMLP+ with "the FMLP+ analysis for preemptive partitioned
fixed-priority scheduling given in Section 6.4.3 of [10]" (Brandenburg's
thesis), corrected per Chen et al. [13].

FMLP+ model: the GPU mutex queue is FIFO; the lock holder runs its critical
section with (restricted) priority boosting; GPU critical sections busy-wait
on the CPU (paper §4.2); waiting for the lock suspends.

Blocking bounds implemented:

  * Remote blocking, request-driven: under FIFO, when a request of tau_i is
    enqueued, at most ONE earlier request of EVERY other task can be ahead of
    it (later requests queue behind).  Hence per request:

        B^{rd-one} = sum_{x != i, eta_x > 0} max_k G_{x,k}
        B_i^{rd}   = eta_i * B^{rd-one}

  * Remote blocking, job-driven: over the whole response window W_i, the
    GPU work other tasks can generate is bounded by their job arrivals:

        B_i^{jd} = sum_{x != i, eta_x > 0} (ceil(W_i/T_x) + 1) * G_x

    We take min(B_i^rd, B_i^jd) — the same double-bounding idea the paper
    applies to its own server analysis (Eq (2)); Brandenburg's holistic
    analysis subsumes both, and taking the min keeps the baseline from being
    strawmanned (the paper notes FMLP+ generally beats MPCP, which this
    reproduces).

  * Local blocking: boosted lower-priority critical sections on tau_i's core,
    identical in form to the MPCP case.

  * Higher-priority local interference with suspension-aware jitter,
    (C_h + G_h) demand (busy-wait), as under MPCP.

Fidelity note: see DESIGN.md §4 — validated against the discrete-event
simulator property tests.
"""

from __future__ import annotations

import math

from .server_analysis import AnalysisResult
from .task_model import System, Task, ceil_div

__all__ = ["response_time", "analyze"]

_MAX_ITERS = 10_000


def _fifo_request_driven(system: System, task: Task) -> float:
    one = sum(
        max((seg.total for seg in t.segments), default=0.0)
        for t in system.tasks
        if t is not task and t.uses_gpu
    )
    return task.eta * one


def _fifo_job_driven(system: System, task: Task, window: float) -> float:
    total = 0.0
    for t in system.tasks:
        if t is task or not t.uses_gpu:
            continue
        total += (ceil_div(window, t.T) + 1) * t.G
    return total


def _local_boost_blocking(system: System, task: Task, window: float) -> float:
    total = 0.0
    for l in system.lower_prio(task, same_core=True):
        if l.uses_gpu:
            total += (ceil_div(window, l.T) + 1) * l.G
    return total


def response_time(system: System, task: Task) -> float:
    """WCRT of ``task`` under the synchronization-based approach with FMLP+."""
    horizon = task.D
    b_rd = _fifo_request_driven(system, task)
    local_hp = system.higher_prio(task, same_core=True)

    w = task.C + task.G
    if w > horizon:
        return math.inf
    for _ in range(_MAX_ITERS):
        b_remote = min(b_rd, _fifo_job_driven(system, task, w)) if task.uses_gpu else 0.0
        nxt = task.C + task.G + b_remote + _local_boost_blocking(system, task, w)
        for h in local_hp:
            demand = h.C + h.G
            # suspension-aware jitter only for tasks that self-suspend
            jitter = max(h.D - demand, 0.0) if h.uses_gpu else 0.0
            nxt += ceil_div(w + jitter, h.T) * demand
        if nxt > horizon:
            return math.inf
        if nxt <= w + 1e-12:
            return nxt
        w = nxt
    return math.inf


def analyze(system: System) -> AnalysisResult:
    res = AnalysisResult()
    for task in sorted(system.tasks, key=lambda t: -t.priority):
        w = response_time(system, task)
        res.response_times[task.name] = w
        if math.isinf(w) or w > task.D + 1e-9:
            res.schedulable = False
    return res
