"""Planned (non-failure) stream migration: the analysis/simulator-level
description of a live cross-server move.

This is the performance twin of :mod:`repro.core.faults`: where a
:class:`~repro.core.faults.DeviceFault` describes an *involuntary* loss of
a device (detection gap, re-prefill recovery, every resident task
displaced), a :class:`StreamMigration` describes a *voluntary* move of ONE
task — work stealing, consolidation, or an elastic drain — with no
detection gap and a one-time migration cost (the gather→host→scatter copy
of its live KV blocks).

Three layers consume this module:

  * the RUNTIME (``serving.engine`` + ``core.dispatch.pool``) performs the
    real move: ``ServeEngine._execute_migration`` copies the blocks,
    ``ServerPool`` rebinds the stream, decode resumes on the destination
    bit-identically;
  * the SIMULATOR (``core.simulator`` via ``migrations=``) replays a
    schedule at job granularity: every job of the migrated task released
    at or after ``at_ms`` runs on device ``to`` / core ``core``, and the
    ``cost`` segment is folded into the first such job once;
  * the ANALYSIS (``core.server_analysis.analyze_pool_under_migrations``)
    prices the same schedule into a migration-delay-augmented bound that
    is property-tested to dominate the simulated WCRT.

The destination CPU core is part of the event itself (not chosen
independently by each consumer) so simulator and analysis agree on
placement and the post-move partitions stay core-disjoint — the same
discipline ``DeviceFault.to`` follows for the failover target.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from .task_model import GpuSegment, System

__all__ = ["StreamMigration", "seeded_stream_migrations"]


@dataclass(frozen=True)
class StreamMigration:
    """One planned migration event for the simulator/analysis pair.

    At ``at_ms`` task ``task`` is reassigned from its current device to
    device ``to``; its next job (the first released at or after ``at_ms``)
    additionally carries the one-time ``cost`` segment — the block
    gather/copy/scatter the runtime performs before decode resumes.

    ``core`` is the destination CPU core for the task's normal segments
    (``-1`` keeps its current core, legal only when that core already
    belongs to the destination partition).  Carrying the core in the event
    keeps simulated and analyzed placement identical.
    """

    task: str
    at_ms: float
    to: int
    cost: GpuSegment = field(default_factory=lambda: GpuSegment(0.0, 0.0))
    core: int = -1

    def __post_init__(self) -> None:
        if self.at_ms < 0:
            raise ValueError("at_ms must be >= 0")
        if self.to < 0:
            raise ValueError("to must be a valid device index")


def _dest_core(system: System, placement: dict[str, tuple[int, int]],
               dest: int) -> int:
    """Least-loaded CPU core of the destination partition (ties by index):
    the cores of tasks currently placed on ``dest`` plus its server core."""
    cores = {c for _, (d, c) in placement.items() if d == dest}
    cores.add(system.server_cores[dest])
    load = {c: 0.0 for c in cores}
    for t in system.tasks:
        d, c = placement[t.name]
        if c in load:
            load[c] += t.C / t.T
    return min(sorted(load), key=lambda c: (load[c], c))


def seeded_stream_migrations(system: System, seed: int, *,
                             num_migrations: int = 1, horizon_ms: float,
                             cost_scale: float = 0.25
                             ) -> list[StreamMigration]:
    """Deterministic random migration schedule for a multi-device system:
    move ``num_migrations`` GPU-using tasks to seeded-random other devices
    at seeded-random instants, each landing on the least-loaded CPU core
    of its destination partition (so the post-move system stays
    core-disjoint and ``analyze_pool`` still decomposes).  The migration
    cost is priced at ``cost_scale`` x the largest single GPU segment in
    the system — a stand-in for the gather/copy/scatter of the longest
    live block list, which is far cheaper than a re-prefill."""
    rng = random.Random(seed)
    if system.num_gpus < 2:
        raise ValueError("migration needs at least 2 devices")
    placement = {t.name: (t.device, t.core) for t in system.tasks}
    seg_max = max((s.total for t in system.tasks for s in t.segments),
                  default=0.0)
    cost = GpuSegment(e=0.9 * seg_max * cost_scale,
                      m=0.1 * seg_max * cost_scale)
    migrations: list[StreamMigration] = []
    t_ms = 0.0
    for _ in range(num_migrations):
        cand = sorted(t.name for t in system.tasks if t.uses_gpu)
        if not cand:
            break
        victim = rng.choice(cand)
        src = placement[victim][0]
        dest = rng.choice([d for d in range(system.num_gpus) if d != src])
        core = _dest_core(system, placement, dest)
        t_ms += rng.uniform(0.1, 0.4) * horizon_ms / max(num_migrations, 1)
        migrations.append(StreamMigration(task=victim, at_ms=t_ms, to=dest,
                                          cost=cost, core=core))
        placement[victim] = (dest, core)
    return migrations
