"""Random taskset generation per Table 2 of the paper (§6.3).

Base parameters (each taskset draws from these ranges):

  Number of CPU cores N_P                    : 4 or 8
  Number of tasks n                          : U[2*N_P, 5*N_P]
  Task utilization U_i                       : U[0.05, 0.2]
  Task period/deadline T_i = D_i             : U[30, 500] ms
  Percentage of GPU-using tasks              : U[10, 30] %
  Ratio of GPU segment length to normal WCET : U[10, 30] %   (G_i / C_i)
  Number of GPU segments per task eta_i      : U{1, 2, 3}
  Ratio of misc ops in a segment             : U[10, 20] %   (G^m / G_{i,j})
  GPU server overhead eps                    : 50 us

Construction (paper text): U_i = (C_i + G_i)/T_i.  CPU-only: C_i = U_i*T_i,
G_i = 0.  GPU-using: the drawn ratio r = G_i/C_i fixes C_i = U_i*T_i/(1+r)
and G_i = C_i*r; G_i is split into eta_i random-sized pieces; each piece is
split into (G^e, G^m) by the misc ratio, assuming G_{i,j} = G^e + G^m.
Priorities are Rate-Monotonic with arbitrary tie-breaking.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from .task_model import GpuSegment, Task

__all__ = ["GenParams", "generate_taskset", "assign_rm_priorities"]


@dataclass
class GenParams:
    num_cores: int = 4
    num_tasks: tuple[int, int] | None = None  # default [2*N_P, 5*N_P]
    util: tuple[float, float] = (0.05, 0.2)
    period_ms: tuple[float, float] = (30.0, 500.0)
    pct_gpu_tasks: tuple[float, float] = (0.10, 0.30)
    gpu_ratio: tuple[float, float] = (0.10, 0.30)  # G_i / C_i
    num_segments: tuple[int, int] = (1, 3)
    misc_ratio: tuple[float, float] = (0.10, 0.20)  # G^m_{i,j} / G_{i,j}
    epsilon_ms: float = 0.050
    # bimodal utilization experiment (Fig. 12): fraction of tasks drawn from
    # the "large" range; None disables bimodal mode.
    bimodal_large_fraction: float | None = None
    util_large: tuple[float, float] = (0.2, 0.5)
    # how G_i is split across the eta_i segments: "uniform" (simplex, the
    # paper's setup) or "heavy" (Pareto-weighted — one dominant long-context
    # segment per task, the adversarial blocking shape).
    seg_split: str = "uniform"

    def task_count_range(self) -> tuple[int, int]:
        if self.num_tasks is not None:
            return self.num_tasks
        return (2 * self.num_cores, 5 * self.num_cores)


def _split_random(total: float, n: int, rng: random.Random,
                  mode: str = "uniform") -> list[float]:
    """Split ``total`` into n random-sized positive pieces.  "uniform" draws
    from the uniform simplex; "heavy" draws Pareto(alpha=1.2) weights so one
    piece usually dominates (heavy-tailed segment lengths)."""
    if n == 1:
        return [total]
    if mode == "heavy":
        weights = [rng.paretovariate(1.2) for _ in range(n)]
        s = sum(weights)
        return [total * w / s for w in weights]
    if mode != "uniform":
        raise ValueError(f"unknown seg_split {mode!r}; use 'uniform' or 'heavy'")
    cuts = sorted(rng.random() for _ in range(n - 1))
    pts = [0.0, *cuts, 1.0]
    return [total * (pts[k + 1] - pts[k]) for k in range(n)]


def assign_rm_priorities(tasks: list[Task]) -> list[Task]:
    """Rate-Monotonic: shorter period = higher priority; unique priorities
    (arbitrary tie-break by index, per the paper)."""
    order = sorted(range(len(tasks)), key=lambda k: (tasks[k].T, k))
    out = list(tasks)
    n = len(tasks)
    for rank, k in enumerate(order):
        out[k] = out[k].with_priority(n - rank)  # larger = higher priority
    return out


def generate_taskset(params: GenParams, rng: random.Random | int) -> list[Task]:
    if isinstance(rng, int):  # int seed accepted for deterministic replay
        rng = random.Random(rng)
    lo, hi = params.task_count_range()
    n = rng.randint(lo, hi)
    pct_gpu = rng.uniform(*params.pct_gpu_tasks)
    n_gpu = round(n * pct_gpu)
    gpu_idx = set(rng.sample(range(n), n_gpu))

    tasks: list[Task] = []
    for i in range(n):
        T = rng.uniform(*params.period_ms)
        if params.bimodal_large_fraction is not None and rng.random() < params.bimodal_large_fraction:
            u = rng.uniform(*params.util_large)
        else:
            u = rng.uniform(*params.util)
        if i in gpu_idx:
            r = rng.uniform(*params.gpu_ratio)
            C = u * T / (1.0 + r)
            G = C * r
            eta = rng.randint(*params.num_segments)
            segs = []
            for g in _split_random(G, eta, rng, params.seg_split):
                mr = rng.uniform(*params.misc_ratio)
                segs.append(GpuSegment(e=g * (1 - mr), m=g * mr))
            tasks.append(Task(name=f"tau{i}", C=C, T=T, D=T, segments=tuple(segs)))
        else:
            tasks.append(Task(name=f"tau{i}", C=u * T, T=T, D=T))
    return assign_rm_priorities(tasks)
