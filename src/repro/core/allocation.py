"""Task allocation (paper §5.3): bin-packing heuristics with the GPU server.

Under partitioned scheduling the allocation problem is bin-packing
(NP-complete), so the paper uses decreasing-utilization heuristics.  Under
the server-based approach the GPU server is a first-class schedulable entity
whose utilization is Eq (8):

    U_server = sum_{tau_i : eta_i > 0} (G_i^m + 2 eta_i eps) / T_i

and it is sorted/allocated together with regular tasks (the paper's
experiments use worst-fit decreasing, WFD).

Packing utilizations reflect where CPU demand actually lands:
  * sync approach   : task occupies (C_i + G_i)/T_i on its own core
                      (busy-wait through the whole GPU segment).
  * server approach : task occupies C_i/T_i; the server pseudo-task carries
                      U_server (Eq (8)) onto whichever core it is packed.

Multi-accelerator pools add a device-assignment level above the core level:
:func:`allocate_pool` first packs GPU-using tasks onto devices by
accelerator utilization (WFD at the device level), then runs the per-device
core allocation above within each device's private core group.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from .task_model import System, Task, server_utilization

__all__ = ["allocate", "allocate_pool", "AllocationError"]

SERVER_NAME = "__gpu_server__"


class AllocationError(RuntimeError):
    pass


def _pack(items: list[tuple[str, float]], num_cores: int, heuristic: str) -> dict[str, int]:
    """Pack (name, util) items onto cores.  Returns name -> core."""
    items = sorted(items, key=lambda kv: -kv[1])  # decreasing utilization
    load = [0.0] * num_cores
    out: dict[str, int] = {}
    for name, u in items:
        if heuristic == "wfd":  # worst-fit: emptiest core
            core = min(range(num_cores), key=lambda c: load[c])
        elif heuristic == "ffd":  # first-fit: first core that stays <= 1
            core = next((c for c in range(num_cores) if load[c] + u <= 1.0 + 1e-12), None)
            if core is None:
                core = min(range(num_cores), key=lambda c: load[c])
        elif heuristic == "bfd":  # best-fit: fullest core that still fits
            fits = [c for c in range(num_cores) if load[c] + u <= 1.0 + 1e-12]
            core = max(fits, key=lambda c: load[c]) if fits else min(
                range(num_cores), key=lambda c: load[c]
            )
        else:
            raise ValueError(f"unknown heuristic {heuristic!r}")
        load[core] += u
        out[name] = core
    return out


def allocate(
    tasks: list[Task],
    num_cores: int,
    *,
    approach: str,
    epsilon: float = 0.0,
    heuristic: str = "wfd",
) -> System:
    """Allocate tasks (and, for the server-based approach, the GPU server) to
    cores and return the resulting ``System``."""
    if approach == "sync":
        items = [(t.name, (t.C + t.G) / t.T) for t in tasks]
        placement = _pack(items, num_cores, heuristic)
        placed = [t.with_core(placement[t.name]) for t in tasks]
        return System(tasks=placed, num_cores=num_cores, epsilon=0.0, server_core=-1)
    if approach == "server":
        items = [(t.name, t.C / t.T) for t in tasks]
        u_server = server_utilization(tasks, epsilon)
        items.append((SERVER_NAME, u_server))
        placement = _pack(items, num_cores, heuristic)
        placed = [t.with_core(placement[t.name]) for t in tasks]
        return System(
            tasks=placed,
            num_cores=num_cores,
            epsilon=epsilon,
            server_core=placement[SERVER_NAME],
        )
    raise ValueError(f"unknown approach {approach!r}")


def allocate_pool(
    tasks: list[Task],
    num_devices: int,
    cores_per_device: int,
    *,
    epsilon: float = 0.0,
    heuristic: str = "wfd",
    device_heuristic: str = "wfd",
) -> System:
    """Two-level allocation for a multi-accelerator server pool.

    Level 1 — device assignment (the pool's routing step): GPU-using tasks
    are packed onto devices by decreasing accelerator utilization G_i/T_i
    (worst-fit decreasing by default, the paper's WFD discipline applied at
    the device level); CPU-only tasks are then spread across the devices'
    core groups by CPU utilization the same way.

    Level 2 — per-device core allocation: within each device's private core
    group of ``cores_per_device`` cores, tasks plus that device's GPU-server
    pseudo-task are packed exactly as in :func:`allocate` (server approach).

    The result is ONE ``System`` with ``num_devices * cores_per_device``
    cores, core-disjoint device partitions (each task's ``device`` set), and
    one server core per device — the shape ``server_analysis.analyze_pool``
    and ``simulator.simulate`` (server modes) consume.
    """
    if num_devices < 1:
        raise AllocationError(f"need >= 1 device, got {num_devices}")
    gpu = sorted((t for t in tasks if t.uses_gpu), key=lambda t: -(t.G / t.T))
    cpu_only = sorted((t for t in tasks if not t.uses_gpu),
                      key=lambda t: -(t.C / t.T))
    dev_gpu_load = [0.0] * num_devices
    dev_cpu_load = [0.0] * num_devices
    by_device: list[list[Task]] = [[] for _ in range(num_devices)]
    for t in gpu:
        if device_heuristic == "wfd":
            d = min(range(num_devices), key=lambda i: dev_gpu_load[i])
        elif device_heuristic == "ffd":
            d = next((i for i in range(num_devices)
                      if dev_gpu_load[i] + t.G / t.T <= 1.0 + 1e-12),
                     min(range(num_devices), key=lambda i: dev_gpu_load[i]))
        else:
            raise ValueError(f"unknown device heuristic {device_heuristic!r}")
        dev_gpu_load[d] += t.G / t.T
        dev_cpu_load[d] += t.C / t.T
        by_device[d].append(t)
    for t in cpu_only:
        d = min(range(num_devices), key=lambda i: dev_cpu_load[i])
        dev_cpu_load[d] += t.C / t.T
        by_device[d].append(t)

    placed: list[Task] = []
    server_cores: list[int] = []
    for d in range(num_devices):
        sub = allocate(by_device[d], cores_per_device, approach="server",
                       epsilon=epsilon, heuristic=heuristic)
        offset = d * cores_per_device
        placed.extend(t.with_core(t.core + offset).with_device(d)
                      for t in sub.tasks)
        server_cores.append(sub.server_core + offset)
    return System(
        tasks=placed,
        num_cores=num_devices * cores_per_device,
        epsilon=epsilon,
        server_cores=tuple(server_cores),
    )
