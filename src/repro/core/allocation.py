"""Task allocation (paper §5.3): bin-packing heuristics with the GPU server.

Under partitioned scheduling the allocation problem is bin-packing
(NP-complete), so the paper uses decreasing-utilization heuristics.  Under
the server-based approach the GPU server is a first-class schedulable entity
whose utilization is Eq (8):

    U_server = sum_{tau_i : eta_i > 0} (G_i^m + 2 eta_i eps) / T_i

and it is sorted/allocated together with regular tasks (the paper's
experiments use worst-fit decreasing, WFD).

Packing utilizations reflect where CPU demand actually lands:
  * sync approach   : task occupies (C_i + G_i)/T_i on its own core
                      (busy-wait through the whole GPU segment).
  * server approach : task occupies C_i/T_i; the server pseudo-task carries
                      U_server (Eq (8)) onto whichever core it is packed.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

from .task_model import System, Task, server_utilization

__all__ = ["allocate", "AllocationError"]

SERVER_NAME = "__gpu_server__"


class AllocationError(RuntimeError):
    pass


def _pack(items: list[tuple[str, float]], num_cores: int, heuristic: str) -> dict[str, int]:
    """Pack (name, util) items onto cores.  Returns name -> core."""
    items = sorted(items, key=lambda kv: -kv[1])  # decreasing utilization
    load = [0.0] * num_cores
    out: dict[str, int] = {}
    for name, u in items:
        if heuristic == "wfd":  # worst-fit: emptiest core
            core = min(range(num_cores), key=lambda c: load[c])
        elif heuristic == "ffd":  # first-fit: first core that stays <= 1
            core = next((c for c in range(num_cores) if load[c] + u <= 1.0 + 1e-12), None)
            if core is None:
                core = min(range(num_cores), key=lambda c: load[c])
        elif heuristic == "bfd":  # best-fit: fullest core that still fits
            fits = [c for c in range(num_cores) if load[c] + u <= 1.0 + 1e-12]
            core = max(fits, key=lambda c: load[c]) if fits else min(
                range(num_cores), key=lambda c: load[c]
            )
        else:
            raise ValueError(f"unknown heuristic {heuristic!r}")
        load[core] += u
        out[name] = core
    return out


def allocate(
    tasks: list[Task],
    num_cores: int,
    *,
    approach: str,
    epsilon: float = 0.0,
    heuristic: str = "wfd",
) -> System:
    """Allocate tasks (and, for the server-based approach, the GPU server) to
    cores and return the resulting ``System``."""
    if approach == "sync":
        items = [(t.name, (t.C + t.G) / t.T) for t in tasks]
        placement = _pack(items, num_cores, heuristic)
        placed = [t.with_core(placement[t.name]) for t in tasks]
        return System(tasks=placed, num_cores=num_cores, epsilon=0.0, server_core=-1)
    if approach == "server":
        items = [(t.name, t.C / t.T) for t in tasks]
        u_server = server_utilization(tasks, epsilon)
        items.append((SERVER_NAME, u_server))
        placement = _pack(items, num_cores, heuristic)
        placed = [t.with_core(placement[t.name]) for t in tasks]
        return System(
            tasks=placed,
            num_cores=num_cores,
            epsilon=epsilon,
            server_core=placement[SERVER_NAME],
        )
    raise ValueError(f"unknown approach {approach!r}")
