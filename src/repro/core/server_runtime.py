"""Executable accelerator-server runtime (the paper's §5.1, with real threads).

This is the mechanism the serving engine builds on: a dedicated server thread
owns the accelerator; clients submit requests and *suspend* (wait on an
event/future) instead of busy-waiting; the server dequeues requests in task-
priority order, executes them one at a time (the accelerator is
non-preemptive: one XLA execution at a time), and notifies the client on
completion.

The request's "GPU segment" is an arbitrary callable.  For JAX use, the
callable typically performs an async dispatch plus a blocking wait
(``jax.block_until_ready``) — the *server* thread blocks (suspends in OS
terms) while the device computes, exactly like the paper's server calling
``clFinish()``.  Client threads never touch the device.

Beyond-paper extensions (used by serving; each is off by default):
  * FIFO ordering mode (the paper's own future-work suggestion, which its
    Fig. 15 identifies as preferable when periods are similar).
  * deadline-aware ordering (EDF on absolute deadlines) for straggler
    mitigation in serving.
  * per-request timing stats, so epsilon can be *measured* (overheads
    benchmark mirrors the paper's §6.2).
"""

from __future__ import annotations

import heapq
import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.dispatch.policy import ORDERINGS, request_key

__all__ = ["AcceleratorServer", "CellStats", "Request", "ServerStats",
           "cell_key", "BATCH_META_CAP"]

# Ring-buffer capacity of the raw per-call shape-decision log.  Sustained
# traffic makes one entry per device call, so an unbounded list is a memory
# leak; the capped ring keeps the recent window for debugging while the
# running per-cell aggregates (``ServerStats.cell_stats``) carry the full
# history the cost model consumes.
BATCH_META_CAP = 4096


def cell_key(meta: dict) -> tuple | None:
    """Canonical cost-model cell of one ``batch_meta`` entry.

    Decode calls map to ``("decode", padded_rows, table_width)`` and
    bucketed prefills to ``("prefill", padded_rows, len_bucket)`` — i.e. the
    post-bucketing shape that names the jit trace the call ran under, which
    is exactly the granularity ``analysis.cost_model`` prices.  Entries
    without a recognizable shape decision return None (not aggregated).
    """
    kind = meta.get("kind")
    if kind == "decode" and "padded" in meta and "width" in meta:
        return ("decode", int(meta["padded"]), int(meta["width"]))
    if kind == "prefill" and "padded" in meta and "bucket" in meta:
        return ("prefill", int(meta["padded"]), int(meta["bucket"]))
    return None


@dataclass
class CellStats:
    """Running aggregate of one shape cell's device calls (Welford over the
    measured call durations, when the dispatcher reports them)."""

    calls: int = 0
    rows: int = 0  # sum of TRUE (pre-padding) rows across calls
    timed: int = 0  # calls that carried a ``seconds`` measurement
    mean_s: float = 0.0
    m2_s: float = 0.0
    min_s: float = math.inf
    max_s: float = 0.0

    def add(self, meta: dict) -> None:
        self.calls += 1
        self.rows += int(meta.get("rows", 0))
        s = meta.get("seconds")
        if s is not None:
            self.timed += 1
            d = s - self.mean_s
            self.mean_s += d / self.timed
            self.m2_s += d * (s - self.mean_s)
            self.min_s = min(self.min_s, s)
            self.max_s = max(self.max_s, s)

    def merge(self, other: "CellStats") -> None:
        """Fold ``other`` into self (parallel Welford merge) — used to pool
        per-server aggregates into one cost-model input."""
        self.calls += other.calls
        self.rows += other.rows
        if other.timed:
            n1, n2 = self.timed, other.timed
            d = other.mean_s - self.mean_s
            self.timed = n1 + n2
            self.mean_s += d * n2 / self.timed
            self.m2_s += other.m2_s + d * d * n1 * n2 / self.timed
            self.min_s = min(self.min_s, other.min_s)
            self.max_s = max(self.max_s, other.max_s)

    @property
    def var_s(self) -> float:
        return self.m2_s / self.timed if self.timed > 1 else 0.0


@dataclass(order=False)
class Request:
    """One accelerator request (a GPU access segment)."""

    fn: Callable[[], Any]
    priority: int = 0  # larger = higher priority
    deadline: float | None = None  # absolute (time.monotonic) deadline, for EDF
    name: str = ""
    # filled by the server:
    result: Any = None
    error: BaseException | None = None
    submit_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    _done: threading.Event = field(default_factory=threading.Event, repr=False)

    def wait(self, timeout: float | None = None) -> Any:
        """Suspend the caller until the request completes (no busy-wait)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.name!r} not done within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def waiting_time(self) -> float:
        """Definition 1: release -> begin execution."""
        return self.start_t - self.submit_t

    @property
    def handling_time(self) -> float:
        return self.end_t - self.submit_t


@dataclass
class ServerStats:
    completed: int = 0
    max_queue_len: int = 0
    wakeup_latencies: list[float] = field(default_factory=list)  # submit -> dequeue
    notify_latencies: list[float] = field(default_factory=list)  # fn done -> client wakeable
    # batch dequeue (BatchingServer): device calls made, and how many
    # requests each one coalesced
    batches: int = 0
    batch_sizes: list[int] = field(default_factory=list)
    # shape decisions the run_batch callable reports per device call
    # (BatchingServer.record_meta): e.g. paged decode {rows, padded, width,
    # compacted, seconds} or bucketed prefill {rows, padded, bucket,
    # seconds}.  Capped ring buffer — the recent window only; the per-cell
    # aggregates below carry the full history.
    batch_meta: deque = field(
        default_factory=lambda: deque(maxlen=BATCH_META_CAP))
    # running per-cell aggregate keyed by ``cell_key(meta)`` — the cost
    # model's measurement input (analysis.cost_model.StepCostModel.ingest)
    cell_stats: dict = field(default_factory=dict)

    def record_meta(self, meta: dict) -> None:
        """Log one device call's shape decision: append to the bounded ring
        and fold into the matching cell aggregate."""
        self.batch_meta.append(meta)
        key = cell_key(meta)
        if key is not None:
            cell = self.cell_stats.get(key)
            if cell is None:
                cell = self.cell_stats[key] = CellStats()
            cell.add(meta)


class AcceleratorServer:
    """Dedicated server thread owning one accelerator (one mesh slice)."""

    def __init__(self, *, ordering: str = "priority", name: str = "gpu-server"):
        if ordering not in ORDERINGS:
            raise ValueError(ordering)
        self.ordering = ordering
        self._lock = threading.Condition()
        self._queue: list[tuple[Any, int, Request]] = []
        self._seq = 0
        self._stop = False
        self.stats = ServerStats()
        self._thread = threading.Thread(target=self._serve, name=name, daemon=True)
        self._thread.start()

    # -- client API ------------------------------------------------------
    def _enqueue(self, req: Request) -> Request:
        """Stamp, queue, and wake the server (shared by all submit paths)."""
        req.submit_t = time.monotonic()
        with self._lock:
            if self._stop:
                raise RuntimeError("server stopped")
            self._seq += 1
            heapq.heappush(self._queue, (self._key(req), self._seq, req))
            self.stats.max_queue_len = max(self.stats.max_queue_len, len(self._queue))
            self._lock.notify()
        return req

    def submit(
        self,
        fn: Callable[[], Any],
        *,
        priority: int = 0,
        deadline: float | None = None,
        name: str = "",
    ) -> Request:
        return self._enqueue(
            Request(fn=fn, priority=priority, deadline=deadline, name=name))

    def call(self, fn: Callable[[], Any], *, priority: int = 0, name: str = "") -> Any:
        """Submit and suspend until completion (the common client pattern)."""
        return self.submit(fn, priority=priority, name=name).wait()

    def shutdown(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        with self._lock:
            if not drain:
                self._queue.clear()
            self._stop = True
            self._lock.notify()
        self._thread.join(timeout)

    def __enter__(self) -> "AcceleratorServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- internals ---------------------------------------------------------
    def _key(self, req: Request):
        return request_key(self.ordering, priority=req.priority,
                           deadline=req.deadline)

    def _dequeue_locked(self) -> list[Request]:
        """Pop the next dispatch unit (called with the lock held).  The base
        server serves one request per device call; BatchingServer overrides
        this to coalesce same-shape requests."""
        _, _, req = heapq.heappop(self._queue)
        return [req]

    def _execute(self, batch: list[Request]) -> None:
        """Run one dispatch unit on the accelerator (server thread only)."""
        req = batch[0]
        req.start_t = time.monotonic()
        self.stats.wakeup_latencies.append(req.start_t - req.submit_t)
        try:
            req.result = req.fn()  # non-preemptive accelerator execution
        except BaseException as e:  # noqa: BLE001 - surfaced to the client
            req.error = e
        t0 = time.monotonic()
        req.end_t = t0
        req._done.set()  # wake the client (it was suspended, not polling)
        self.stats.notify_latencies.append(time.monotonic() - t0)
        self.stats.completed += 1

    def _serve(self) -> None:
        while True:
            with self._lock:
                while not self._queue and not self._stop:
                    self._lock.wait()  # server suspends when idle
                if not self._queue and self._stop:
                    return
                batch = self._dequeue_locked()
            self._execute(batch)
