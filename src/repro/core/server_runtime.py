"""Executable accelerator-server runtime (the paper's §5.1, with real threads).

This is the mechanism the serving engine builds on: a dedicated server thread
owns the accelerator; clients submit requests and *suspend* (wait on an
event/future) instead of busy-waiting; the server dequeues requests in task-
priority order, executes them one at a time (the accelerator is
non-preemptive: one XLA execution at a time), and notifies the client on
completion.

The request's "GPU segment" is an arbitrary callable.  For JAX use, the
callable typically performs an async dispatch plus a blocking wait
(``jax.block_until_ready``) — the *server* thread blocks (suspends in OS
terms) while the device computes, exactly like the paper's server calling
``clFinish()``.  Client threads never touch the device.

Beyond-paper extensions (used by serving; each is off by default):
  * FIFO ordering mode (the paper's own future-work suggestion, which its
    Fig. 15 identifies as preferable when periods are similar).
  * deadline-aware ordering (EDF on absolute deadlines) for straggler
    mitigation in serving.
  * per-request timing stats, so epsilon can be *measured* (overheads
    benchmark mirrors the paper's §6.2).
  * fault tolerance: every device call runs through :meth:`_attempt`, which
    retries ``core.faults.TransientDeviceError`` with bounded exponential
    backoff and escalates to a server-wide failure on
    ``core.faults.DeviceLostError`` (or retry exhaustion).  A failed server
    wakes every suspended client with ``ServerFailedError`` — queued AND
    in-flight — so the serving engine can recover streams onto survivors.
    ``fail()`` is also callable from OUTSIDE the server thread: that is how
    the heartbeat monitor kills a server stuck in a stalled device call
    (the per-device-call timeout — the server beats between calls, so a
    call outlasting the heartbeat timeout is declared a stall).  An
    optional ``runtime.straggler.StepTimeWatchdog`` observes every call's
    duration for slow-step (degraded-health) flagging.
"""

from __future__ import annotations

import heapq
import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.dispatch.policy import ORDERINGS, request_key
from repro.core.faults import (DeviceLostError, ServerFailedError,
                               TransientDeviceError)

__all__ = ["AcceleratorServer", "CellStats", "Request", "ServerStats",
           "cell_key", "BATCH_META_CAP"]

# Ring-buffer capacity of the raw per-call shape-decision log.  Sustained
# traffic makes one entry per device call, so an unbounded list is a memory
# leak; the capped ring keeps the recent window for debugging while the
# running per-cell aggregates (``ServerStats.cell_stats``) carry the full
# history the cost model consumes.
BATCH_META_CAP = 4096


def cell_key(meta: dict) -> tuple | None:
    """Canonical cost-model cell of one ``batch_meta`` entry.

    Decode calls map to ``("decode", padded_rows, table_width)`` and
    bucketed prefills to ``("prefill", padded_rows, len_bucket)`` — i.e. the
    post-bucketing shape that names the jit trace the call ran under, which
    is exactly the granularity ``analysis.cost_model`` prices.  KV-block
    migration copies (one gather or scatter of a stream's live blocks) map
    to ``("migrate", padded_table_width, block_size)`` — ``padded`` is the
    pow2-bucketed number of blocks moved, the axis that sizes the copy.
    Entries without a recognizable shape decision return None (not
    aggregated).

    Non-GQA cache families tag their kinds ``"<base>@<family>"`` (e.g.
    ``"decode@mla"``): the base kind before the ``@`` decides which shape
    fields apply, and the TAGGED kind is kept as the cell's phase — each
    family's cells stay separate in the cost model (their step costs differ:
    latent rows, state slabs, segment gathers), while plain GQA keeps the
    untagged phase for back-compat."""
    kind = meta.get("kind")
    base = kind.split("@", 1)[0] if isinstance(kind, str) else kind
    if base == "decode" and "padded" in meta and "width" in meta:
        return (kind, int(meta["padded"]), int(meta["width"]))
    if base == "prefill" and "padded" in meta and "bucket" in meta:
        return (kind, int(meta["padded"]), int(meta["bucket"]))
    if base == "migrate" and "padded" in meta and "width" in meta:
        return (kind, int(meta["padded"]), int(meta["width"]))
    return None


@dataclass
class CellStats:
    """Running aggregate of one shape cell's device calls (Welford over the
    measured call durations, when the dispatcher reports them)."""

    calls: int = 0
    rows: int = 0  # sum of TRUE (pre-padding) rows across calls
    timed: int = 0  # calls that carried a ``seconds`` measurement
    mean_s: float = 0.0
    m2_s: float = 0.0
    min_s: float = math.inf
    max_s: float = 0.0

    def add(self, meta: dict) -> None:
        self.calls += 1
        self.rows += int(meta.get("rows", 0))
        s = meta.get("seconds")
        if s is not None:
            self.timed += 1
            d = s - self.mean_s
            self.mean_s += d / self.timed
            self.m2_s += d * (s - self.mean_s)
            self.min_s = min(self.min_s, s)
            self.max_s = max(self.max_s, s)

    def merge(self, other: "CellStats") -> None:
        """Fold ``other`` into self (parallel Welford merge) — used to pool
        per-server aggregates into one cost-model input."""
        self.calls += other.calls
        self.rows += other.rows
        if other.timed:
            n1, n2 = self.timed, other.timed
            d = other.mean_s - self.mean_s
            self.timed = n1 + n2
            self.mean_s += d * n2 / self.timed
            self.m2_s += other.m2_s + d * d * n1 * n2 / self.timed
            self.min_s = min(self.min_s, other.min_s)
            self.max_s = max(self.max_s, other.max_s)

    @property
    def var_s(self) -> float:
        return self.m2_s / self.timed if self.timed > 1 else 0.0


@dataclass(order=False)
class Request:
    """One accelerator request (a GPU access segment)."""

    fn: Callable[[], Any]
    priority: int = 0  # larger = higher priority
    deadline: float | None = None  # absolute (time.monotonic) deadline, for EDF
    name: str = ""
    # filled by the server:
    result: Any = None
    error: BaseException | None = None
    submit_t: float = 0.0
    start_t: float = 0.0
    end_t: float = 0.0
    _done: threading.Event = field(default_factory=threading.Event, repr=False)

    def wait(self, timeout: float | None = None) -> Any:
        """Suspend the caller until the request completes (no busy-wait)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.name!r} not done within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def waiting_time(self) -> float:
        """Definition 1: release -> begin execution."""
        return self.start_t - self.submit_t

    @property
    def handling_time(self) -> float:
        return self.end_t - self.submit_t


@dataclass
class ServerStats:
    completed: int = 0
    max_queue_len: int = 0
    wakeup_latencies: list[float] = field(default_factory=list)  # submit -> dequeue
    notify_latencies: list[float] = field(default_factory=list)  # fn done -> client wakeable
    # batch dequeue (BatchingServer): device calls made, and how many
    # requests each one coalesced
    batches: int = 0
    batch_sizes: list[int] = field(default_factory=list)
    # shape decisions the run_batch callable reports per device call
    # (BatchingServer.record_meta): e.g. paged decode {rows, padded, width,
    # compacted, seconds} or bucketed prefill {rows, padded, bucket,
    # seconds}.  Capped ring buffer — the recent window only; the per-cell
    # aggregates below carry the full history.
    batch_meta: deque = field(
        default_factory=lambda: deque(maxlen=BATCH_META_CAP))
    # running per-cell aggregate keyed by ``cell_key(meta)`` — the cost
    # model's measurement input (analysis.cost_model.StepCostModel.ingest)
    cell_stats: dict = field(default_factory=dict)

    def record_meta(self, meta: dict) -> None:
        """Log one device call's shape decision: append to the bounded ring
        and fold into the matching cell aggregate."""
        self.batch_meta.append(meta)
        key = cell_key(meta)
        if key is not None:
            cell = self.cell_stats.get(key)
            if cell is None:
                cell = self.cell_stats[key] = CellStats()
            cell.add(meta)


class AcceleratorServer:
    """Dedicated server thread owning one accelerator (one mesh slice)."""

    def __init__(self, *, ordering: str = "priority", name: str = "gpu-server"):
        if ordering not in ORDERINGS:
            raise ValueError(ordering)
        self.ordering = ordering
        self.name = name
        self._lock = threading.Condition()
        self._queue: list[tuple[Any, int, Request]] = []
        self._seq = 0
        self._stop = False
        self.stats = ServerStats()
        # -- fault tolerance (all optional; defaults preserve old behavior) --
        self.fault_hook: Callable[[], None] | None = None  # injection point
        self.max_retries = 2  # transient-error retries before escalation
        self.retry_backoff_s = 0.005  # base of the exponential backoff
        self.on_failure: Callable[["AcceleratorServer"], None] | None = None
        self.beat: Callable[[], None] | None = None  # heartbeat tick
        self.beat_interval_s = 0.05
        self.watchdog = None  # runtime.straggler.StepTimeWatchdog, if any
        self.failed = False
        self.fail_cause: BaseException | None = None
        self._inflight: list[Request] | None = None
        self._thread = threading.Thread(target=self._serve, name=name, daemon=True)
        self._thread.start()

    # -- client API ------------------------------------------------------
    def _enqueue(self, req: Request) -> Request:
        """Stamp, queue, and wake the server (shared by all submit paths)."""
        req.submit_t = time.monotonic()
        with self._lock:
            if self.failed:
                raise ServerFailedError(
                    f"server {self.name!r} failed: {self.fail_cause}",
                    server=self.name)
            if self._stop:
                raise RuntimeError("server stopped")
            self._seq += 1
            heapq.heappush(self._queue, (self._key(req), self._seq, req))
            self.stats.max_queue_len = max(self.stats.max_queue_len, len(self._queue))
            self._lock.notify()
        return req

    def submit(
        self,
        fn: Callable[[], Any],
        *,
        priority: int = 0,
        deadline: float | None = None,
        name: str = "",
    ) -> Request:
        return self._enqueue(
            Request(fn=fn, priority=priority, deadline=deadline, name=name))

    def call(self, fn: Callable[[], Any], *, priority: int = 0, name: str = "") -> Any:
        """Submit and suspend until completion (the common client pattern)."""
        return self.submit(fn, priority=priority, name=name).wait()

    @property
    def qlen(self) -> int:
        """Requests currently queued (not in flight) — the depth signal the
        work-stealing rebalancer reads."""
        with self._lock:
            return len(self._queue)

    def shutdown(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        with self._lock:
            if not drain:
                # Wake abandoned clients instead of leaving them suspended
                # forever on a queue that will never be served.
                for _, _, req in self._queue:
                    if not req.done:
                        req.error = ServerFailedError(
                            f"server {self.name!r} shut down before serving "
                            f"request {req.name!r}", server=self.name)
                        req.end_t = time.monotonic()
                        req._done.set()
                self._queue.clear()
            self._stop = True
            self._lock.notify_all()
        self._thread.join(timeout)

    def fail(self, cause: BaseException | None = None) -> None:
        """Declare this server dead (callable from ANY thread).

        Every queued AND in-flight request completes with
        :class:`ServerFailedError`, waking suspended clients so they can run
        stream recovery; later submissions are rejected with the same error.
        Idempotent — only the first call has effect.  ``on_failure`` fires
        once, outside the lock (it may call back into the pool).

        The heartbeat monitor calls this from its own thread when the server
        misses beats (a device call stalled past the timeout); the server
        thread calls it on :class:`DeviceLostError`.  If the stalled call
        ever returns, its result is discarded — the request already
        completed with the failure error (``req.done`` guard).
        """
        with self._lock:
            if self.failed:
                return
            self.failed = True
            self.fail_cause = cause
            victims = [req for _, _, req in self._queue]
            self._queue.clear()
            if self._inflight is not None:
                victims.extend(self._inflight)
            now = time.monotonic()
            for req in victims:
                if not req.done:
                    req.error = ServerFailedError(
                        f"server {self.name!r} failed: {cause}",
                        server=self.name)
                    req.end_t = now
                    req._done.set()
            self._stop = True
            self._lock.notify_all()
        cb = self.on_failure
        if cb is not None:
            cb(self)

    def __enter__(self) -> "AcceleratorServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- internals ---------------------------------------------------------
    def _key(self, req: Request):
        return request_key(self.ordering, priority=req.priority,
                           deadline=req.deadline)

    def _dequeue_locked(self) -> list[Request]:
        """Pop the next dispatch unit (called with the lock held).  The base
        server serves one request per device call; BatchingServer overrides
        this to coalesce same-shape requests."""
        _, _, req = heapq.heappop(self._queue)
        return [req]

    def _attempt(self, fn: Callable[[], Any]) -> Any:
        """Run one device call with fault injection, bounded transient
        retry, and watchdog observation (server thread only).

        :class:`TransientDeviceError` is retried up to ``max_retries`` times
        with exponential backoff; exhaustion escalates to
        :class:`DeviceLostError` (the caller declares the server dead).
        """
        attempts = 0
        while True:
            try:
                t0 = time.monotonic()
                if self.fault_hook is not None:
                    self.fault_hook()
                result = fn()
                if self.watchdog is not None:
                    self.watchdog.observe(time.monotonic() - t0)
                return result
            except TransientDeviceError as e:
                attempts += 1
                if attempts > self.max_retries:
                    raise DeviceLostError(
                        f"transient retries exhausted after {self.max_retries}"
                        f" retries: {e}") from e
                time.sleep(self.retry_backoff_s * (2 ** (attempts - 1)))

    def _complete(self, req: Request, result: Any,
                  error: BaseException | None) -> None:
        """Finish one request, unless a concurrent ``fail()`` beat us to it
        (then the client already woke with ServerFailedError and this — e.g.
        a stalled call's eventual return — is discarded)."""
        with self._lock:
            if req.done:
                return
            req.result = result
            req.error = error
            t0 = time.monotonic()
            req.end_t = t0
            req._done.set()  # wake the client (it was suspended, not polling)
        self.stats.notify_latencies.append(time.monotonic() - t0)
        self.stats.completed += 1

    def _execute(self, batch: list[Request]) -> None:
        """Run one dispatch unit on the accelerator (server thread only)."""
        req = batch[0]
        req.start_t = time.monotonic()
        self.stats.wakeup_latencies.append(req.start_t - req.submit_t)
        try:
            result = self._attempt(req.fn)  # non-preemptive accelerator run
            error: BaseException | None = None
        except DeviceLostError as e:
            self.fail(e)
            return
        except BaseException as e:  # noqa: BLE001 - surfaced to the client
            result, error = None, e
        self._complete(req, result, error)

    def _serve(self) -> None:
        while True:
            with self._lock:
                while not self._queue and not self._stop:
                    if self.beat is not None:
                        self.beat()
                        self._lock.wait(self.beat_interval_s)
                    else:
                        self._lock.wait()  # server suspends when idle
                if not self._queue and self._stop:
                    return
                batch = self._dequeue_locked()
                self._inflight = batch
            if self.beat is not None:
                self.beat()  # last beat before a (possibly stalling) call
            self._execute(batch)
            with self._lock:
                self._inflight = None
                if self.failed:
                    return
