"""Schedulability analysis for the synchronization-based approach under MPCP.

The paper (§6.3) evaluates the synchronization-based baseline with the MPCP
analysis of Lakshmanan et al. [28] ("Coordinated task scheduling, allocation
and synchronization on multiprocessors", RTSS'09), modified per the
self-suspension corrections of Chen et al. [13].

Model recap (paper §4): the GPU is a single mutex; a GPU access segment is a
critical section executed *entirely on the CPU* (busy-wait) at the boosted
global priority ceiling pi_B + pi_i.  Waiting for the lock itself is
suspension-based (footnote 2).  Hence:

  * CPU demand of tau_i on its own core:  C_i + G_i  (busy-wait).
  * Remote blocking (lock wait) per request: priority-queued with
    non-preemptive lower-priority holder — the same recurrence structure as
    the paper's Eq (3) with eps = 0:

        B^{w,0}   = max_{pi_l < pi_i, k} G_{l,k}
        B^{w,n+1} = max_{pi_l < pi_i, k} G_{l,k}
                    + sum_{pi_h > pi_i} sum_k (ceil(B^{w,n}/T_h) + 1) G_{h,k}

    The total is request-driven only: B_i^remote = eta_i * B^w.  (The paper
    observes this is exactly where [28] is pessimistic: "it computes an upper
    bound by the sum of the maximum per-request delay, similarly to the
    request-driven analysis shown in Eq. 3" — we keep that pessimism to stay
    faithful to the baseline used in the paper.)
  * Local blocking: lower-priority tasks on tau_i's core execute their GPU
    critical sections at boosted priority (> any normal priority), so every
    such gcs instance in the window preempts tau_i:

        B_i^local = sum_{l in P(i), pi_l < pi_i} (ceil(W/T_l) + 1) * G_l

    (G_l is all-CPU busy-wait time under this model.)
  * Higher-priority interference on the local core, with the Chen/Bletsas
    suspension-aware jitter (hp tasks suspend while waiting for the lock):
    ceil((W + (D_h - (C_h + G_h))) / T_h) * (C_h + G_h).

Fidelity note (also in DESIGN.md §4): a clause-by-clause reconstruction of
[28] is not possible from the paper text alone; the above is the standard
form of that analysis with the paper's stated corrections, and is validated
against the discrete-event simulator (analysis bound >= simulated response
time) in tests/test_simulator_property.py.
"""

from __future__ import annotations

import math

from .server_analysis import AnalysisResult
from .task_model import System, Task, ceil_div

__all__ = ["remote_blocking_per_request", "response_time", "analyze"]

_MAX_ITERS = 10_000


def remote_blocking_per_request(system: System, task: Task, *, horizon: float) -> float:
    """Per-request lock-waiting bound under MPCP (priority-ordered queue)."""
    if not task.uses_gpu:
        return 0.0
    first = max(
        (seg.total for t in system.lower_prio(task) for seg in t.segments),
        default=0.0,
    )
    b = first
    for _ in range(_MAX_ITERS):
        hp = 0.0
        for h in system.higher_prio(task):
            if h.uses_gpu:
                hp += (ceil_div(b, h.T) + 1) * h.G
        nxt = first + hp
        if nxt > horizon:
            return math.inf
        if nxt <= b + 1e-12:
            return nxt
        b = nxt
    return math.inf


def _local_boost_blocking(system: System, task: Task, window: float) -> float:
    """Boosted-priority gcs preemptions by local lower-priority tasks."""
    total = 0.0
    for l in system.lower_prio(task, same_core=True):
        if l.uses_gpu:
            total += (ceil_div(window, l.T) + 1) * l.G
    return total


def response_time(system: System, task: Task, *, use_deadline_jitter: bool = True) -> float:
    """WCRT of ``task`` under the synchronization-based approach with MPCP."""
    horizon = task.D
    b_remote_one = remote_blocking_per_request(system, task, horizon=horizon)
    if math.isinf(b_remote_one):
        return math.inf
    b_remote = task.eta * b_remote_one

    local_hp = system.higher_prio(task, same_core=True)

    w = task.C + task.G + b_remote
    if w > horizon:
        return math.inf
    for _ in range(_MAX_ITERS):
        nxt = task.C + task.G + b_remote + _local_boost_blocking(system, task, w)
        for h in local_hp:
            demand = h.C + h.G  # busy-wait: gcs consumes CPU
            # suspension-aware jitter (Chen et al.) — only GPU-using tasks
            # self-suspend (while waiting for the lock)
            jitter = max(h.D - demand, 0.0) if h.uses_gpu else 0.0
            nxt += ceil_div(w + jitter, h.T) * demand
        if nxt > horizon:
            return math.inf
        if nxt <= w + 1e-12:
            return nxt
        w = nxt
    return math.inf


def analyze(system: System) -> AnalysisResult:
    res = AnalysisResult()
    for task in sorted(system.tasks, key=lambda t: -t.priority):
        w = response_time(system, task)
        res.response_times[task.name] = w
        if math.isinf(w) or w > task.D + 1e-9:
            res.schedulable = False
    return res
