"""Shared fault model: the exception vocabulary of the serving stack and the
analysis/simulator-level device-failure description.

Three layers consume this module:

  * the RUNTIME (``core.server_runtime`` / ``core.dispatch``) raises and
    handles the exceptions — a device call that raises
    :class:`TransientDeviceError` is retried with bounded backoff; one that
    raises :class:`DeviceLostError` (or exhausts its retries, or stalls past
    the heartbeat timeout) marks the whole server failed, and every queued or
    in-flight request on it completes with :class:`ServerFailedError` so
    suspended clients wake and can run stream recovery;
  * the SIMULATOR (``core.simulator``) takes a list of :class:`DeviceFault`
    events and replays them exactly: at ``at_ms`` the device stops mid-work,
    at ``at_ms + detect_ms`` its orphaned requests are re-submitted to the
    surviving device ``to`` with the ``recovery`` re-prefill segment folded
    in, and all later requests of its tasks follow;
  * the ANALYSIS (``core.server_analysis.analyze_pool_under_faults``) prices
    the same events into a per-task recovery-augmented response-time bound
    that is property-tested to dominate the simulated WCRT.

The runtime-side *injection* harness (scripted/seeded schedules of death,
stall, slow-step and transient errors against a live ``ServerPool``) lives
in ``runtime.faultinject``; it re-exports these exceptions so schedule
authors import one module.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from .task_model import GpuSegment, System

__all__ = [
    "DeviceFault",
    "DeviceLostError",
    "ServerFailedError",
    "StreamShedError",
    "TransientDeviceError",
    "seeded_device_faults",
]


class DeviceLostError(RuntimeError):
    """The accelerator behind a server is gone (fatal): the device call
    failed in a way retry cannot fix, or transient retries were exhausted.
    Raising this inside a device call declares the server dead."""


class TransientDeviceError(RuntimeError):
    """A device call failed in a way worth retrying (e.g. a transient
    interconnect error).  The server retries with bounded exponential
    backoff before escalating to :class:`DeviceLostError`."""


class ServerFailedError(RuntimeError):
    """Completion status of a request whose server died before (or while)
    serving it.  Clients suspended on ``Request.wait()`` receive this and
    should re-route the work — the serving engine's stream recovery path.

    ``server`` carries the failed server's name for diagnostics."""

    def __init__(self, message: str, *, server: str = ""):
        super().__init__(message)
        self.server = server


class StreamShedError(RuntimeError):
    """The stream was shed by degraded-mode admission (the shrunk pool can
    no longer prove its deadline) — its job is aborted, not retried."""


@dataclass(frozen=True)
class DeviceFault:
    """One device-death event for the simulator/analysis pair.

    At ``at_ms`` device ``device`` dies mid-work (its in-flight segment
    never completes, its queue freezes).  Detection takes ``detect_ms``
    (heartbeat timeout); at ``at_ms + detect_ms`` every orphaned request is
    re-submitted to surviving device ``to`` with the ``recovery`` segment's
    cost folded in (the re-prefill of the retained token prefix), and all
    of the dead device's tasks are re-routed to ``to`` from then on.

    The single-target ``to`` mirrors how degraded admission typically lands
    a dead device's streams, and keeps the post-failure partitions
    core-disjoint so ``analyze_pool`` still decomposes.
    """

    device: int
    at_ms: float
    detect_ms: float
    to: int
    recovery: GpuSegment = field(default_factory=lambda: GpuSegment(0.0, 0.0))

    def __post_init__(self) -> None:
        if self.device == self.to:
            raise ValueError(f"device {self.device} cannot fail over to itself")
        if self.at_ms < 0 or self.detect_ms < 0:
            raise ValueError("at_ms and detect_ms must be >= 0")


def seeded_device_faults(system: System, seed: int, *, num_faults: int = 1,
                         horizon_ms: float, detect_ms: float = 1.0,
                         recovery_scale: float = 1.0) -> list[DeviceFault]:
    """Deterministic random fault schedule for a multi-device system: kill
    ``num_faults`` distinct devices at seeded-random instants inside the
    horizon, each failing over to the lowest-index surviving device.  The
    recovery segment is priced at ``recovery_scale`` x the largest single
    GPU segment in the system (a conservative stand-in for the re-prefill
    of the longest retained prefix)."""
    rng = random.Random(seed)
    devices = list(range(system.num_gpus))
    if num_faults >= len(devices):
        raise ValueError(f"cannot kill {num_faults} of {len(devices)} devices")
    dead: list[int] = []
    seg_max = max((s.total for t in system.tasks for s in t.segments),
                  default=0.0)
    rec = GpuSegment(e=0.9 * seg_max * recovery_scale,
                     m=0.1 * seg_max * recovery_scale)
    faults = []
    t = 0.0
    for _ in range(num_faults):
        victim = rng.choice([d for d in devices if d not in dead])
        dead.append(victim)
        survivors = [d for d in devices if d not in dead]
        t += rng.uniform(0.05, 0.45) * horizon_ms
        faults.append(DeviceFault(device=victim, at_ms=t,
                                  detect_ms=detect_ms, to=survivors[0],
                                  recovery=rec))
    return faults
