"""Analysis-driven admission control (beyond-paper, built from the paper's
analysis).

A serving deployment declares each workload stream as a sporadic task
(period, deadline, CPU-side cost, device-segment costs).  A new stream is
admitted iff the server-based analysis (Eqs (1)-(6)) proves every admitted
stream still meets its deadline.  This turns the paper's offline
schedulability test into an online admission test — the GPU server has
central knowledge of all requests (paper §7 notes this enables exactly this
kind of feature).

Streams are allocated to cores (and, across pods, to per-pod servers) with
the paper's WFD-with-server packing (§5.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from . import server_analysis
from .allocation import allocate, allocate_pool
from .task_model import GpuSegment, Task
from .taskset_gen import assign_rm_priorities

__all__ = [
    "AdmissionController",
    "AdmissionDecision",
    "DegradedReport",
    "PoolAdmissionController",
    "check_pool",
]


@dataclass
class AdmissionDecision:
    admitted: bool
    reason: str = ""
    response_times: dict[str, float] = field(default_factory=dict)


@dataclass
class DegradedReport:
    """Outcome of degraded-mode admission after a device eviction.

    ``moved`` maps each surviving displaced stream to its new device —
    re-proven schedulable there WITH its recovery segment (the priced
    re-prefill of the retained prefix) appended.  ``shed`` lists every
    stream dropped to make the shrunk pool schedulable, in the order shed —
    lowest-priority victims first (graceful degradation), a displaced
    stream itself only when no lower-priority victim was left.
    ``reasons`` keeps the last rejection message per displaced stream that
    needed shedding; ``recovery_ms`` the priced recovery cost per moved
    stream."""

    device: int
    moved: dict[str, int] = field(default_factory=dict)
    shed: list[str] = field(default_factory=list)
    reasons: dict[str, str] = field(default_factory=dict)
    recovery_ms: dict[str, float] = field(default_factory=dict)


class AdmissionController:
    """Holds the currently-admitted stream set for one accelerator (pod).

    ``min_batch`` > 1 switches on the AMORTIZED-overhead admission mode
    (``server_analysis.amortized_server_overhead``): when the dispatcher
    guarantees that every device call coalesces at least ``min_batch``
    requests (e.g. a BatchingServer fed by >= min_batch always-saturated
    decode streams), each request's share of the server invocation cost
    drops from eps to eps/min_batch, so the analysis runs with that
    effective epsilon and admits strictly more task sets.  This is an
    OPTIMISTIC mode — sound only while the batch-size guarantee holds; with
    the default min_batch=1 it is exactly the paper's unconditional bound.

    ``cost_model`` switches on CALIBRATED admission: a stream admitted with
    a shape-cell hint (``try_admit(stream, cell=...)``) has its GPU
    segments re-priced at ``min(declared, safety * predicted)`` for that
    cell (``analysis.cost_model.StepCostModel.recost``) before the
    Eqs (1)-(6) check runs.  Declared costs are the full-width worst case
    (the (max_batch, nb_max) trace); the calibrated cost is the measured/
    interpolated cost of the bucket the stream actually runs in, so
    calibrated mode admits a superset of the worst-case sets (the analysis
    is monotone in segment costs and min() never re-prices upward) while
    the per-server bounds still dominate execution that honors the
    calibrated costs.  Streams admitted without a cell keep their declared
    costs — an empty or absent model is exactly the uncalibrated mode.
    """

    def __init__(self, num_cores: int, *, epsilon_ms: float = 0.05,
                 heuristic: str = "wfd", min_batch: int = 1,
                 cost_model=None):
        if min_batch < 1:
            raise ValueError(f"min_batch must be >= 1, got {min_batch}")
        self.num_cores = num_cores
        self.epsilon = epsilon_ms
        self.heuristic = heuristic
        self.min_batch = min_batch
        self.cost_model = cost_model
        self.streams: list[Task] = []

    @property
    def effective_epsilon(self) -> float:
        """Per-request server overhead after batch amortization: every eps
        term in Eqs (1)-(6) is one server invocation charged to one request,
        so a guaranteed batch of b divides each share by b (the 2*eta*eps
        handling term becomes ``amortized_server_overhead(task, eps, b)``).
        """
        return self.epsilon / self.min_batch

    def _check(self, tasks: list[Task]) -> AdmissionDecision:
        tasks = assign_rm_priorities(tasks)
        system = allocate(
            tasks,
            self.num_cores,
            approach="server",
            epsilon=self.effective_epsilon,
            heuristic=self.heuristic,
        )
        res = server_analysis.analyze(system)
        if res.schedulable:
            return AdmissionDecision(True, "schedulable", res.response_times)
        misses = [n for n, w in res.response_times.items() if not w <= _deadline(tasks, n)]
        return AdmissionDecision(False, f"deadline miss for {misses}", res.response_times)

    def try_admit(self, stream: Task, *, cell=None) -> AdmissionDecision:
        """``cell``: the cost-model shape cell(s) this stream's GPU
        segments run in (one CellKey broadcast to every segment, or a
        per-segment sequence); only meaningful with ``cost_model`` set."""
        if any(t.name == stream.name for t in self.streams):
            return AdmissionDecision(False, f"duplicate stream name {stream.name!r}")
        if self.cost_model is not None and cell is not None:
            stream = self.cost_model.recost(stream, cell)
        decision = self._check([*self.streams, stream])
        if decision.admitted:
            # the CALIBRATED task is what was proven schedulable; later
            # admission checks must re-analyze against that pricing
            self.streams.append(stream)
        return decision

    def remove(self, name: str) -> None:
        self.streams = [t for t in self.streams if t.name != name]

    def utilization(self) -> float:
        return sum(t.U for t in self.streams)


def _deadline(tasks: list[Task], name: str) -> float:
    for t in tasks:
        if t.name == name:
            return t.D
    return float("inf")


def check_pool(tasks: list[Task], num_devices: int, cores_per_device: int,
               *, epsilon_ms: float = 0.05, heuristic: str = "wfd",
               ) -> tuple["server_analysis.PoolAnalysisResult", "object"]:
    """Offline pool schedulability check: run the device-assignment step
    (``allocation.allocate_pool``), then the per-server analysis
    (``server_analysis.analyze_pool``) on the resulting partitioned system.
    Returns (analysis, system) so callers can also simulate the placement."""
    tasks = assign_rm_priorities(tasks)
    system = allocate_pool(tasks, num_devices, cores_per_device,
                           epsilon=epsilon_ms, heuristic=heuristic)
    return server_analysis.analyze_pool(system), system


class PoolAdmissionController:
    """Online admission for a multi-accelerator ServerPool.

    A new stream is placed on a device by worst-fit on declared accelerator
    utilization (the paper's WFD discipline, applied at the device level —
    the same device-assignment order ``allocation.allocate_pool`` uses
    offline), and admitted iff the server-based analysis (Eqs (1)-(6))
    applied WITHIN that device's partition proves every stream already on
    the device still makes its deadline.  Partitioned assignment means the
    other devices' analyses are untouched by construction — admission is
    O(one partition), and an admitted stream's device is stable for its
    lifetime (the dispatch.ServerPool router pins it).
    """

    def __init__(self, num_devices: int, *, cores_per_device: int = 2,
                 epsilon_ms: float = 0.05, heuristic: str = "wfd",
                 min_batch: int = 1, cost_model=None):
        # kept for add_device(): an elastically-joined device gets a
        # controller built exactly like the originals
        self.cores_per_device = cores_per_device
        self.epsilon_ms = epsilon_ms
        self.heuristic = heuristic
        self.min_batch = min_batch
        self.cost_model = cost_model
        self.devices = [
            AdmissionController(cores_per_device, epsilon_ms=epsilon_ms,
                                heuristic=heuristic, min_batch=min_batch,
                                cost_model=cost_model)
            for _ in range(num_devices)
        ]
        self.placement: dict[str, int] = {}
        self.alive = [True] * num_devices

    @property
    def num_devices(self) -> int:
        return len(self.devices)

    def gpu_utilization(self, device: int) -> float:
        return sum(t.G / t.T for t in self.devices[device].streams)

    def device_of(self, name: str) -> int:
        return self.placement[name]

    def try_admit(self, stream: Task, *,
                  cell=None) -> tuple[AdmissionDecision, int]:
        """Returns (decision, device); device is -1 when rejected.
        ``cell`` is the calibrated-admission shape hint, forwarded to the
        per-device controller (see ``AdmissionController.try_admit``)."""
        if stream.name in self.placement:
            return (AdmissionDecision(
                False, f"duplicate stream name {stream.name!r}"), -1)
        order = sorted((d for d in range(self.num_devices) if self.alive[d]),
                       key=self.gpu_utilization)
        last = AdmissionDecision(False, "no surviving devices")
        for d in order:
            decision = self.devices[d].try_admit(stream, cell=cell)
            if decision.admitted:
                self.placement[stream.name] = d
                return decision, d
            last = decision
        return last, -1

    def remove(self, name: str) -> None:
        d = self.placement.pop(name, None)
        if d is not None:
            self.devices[d].remove(name)

    # -- planned migration / elastic membership ----------------------------
    def migrate(self, name: str, dst: int | None = None, *,
                migration_cost_ms: float = 0.0,
                ) -> tuple[AdmissionDecision, int]:
        """Re-prove an admitted stream on another device before moving it.

        The candidate is the stream's admitted task with the priced
        migration segment appended — one extra GPU request of
        ``migration_cost_ms`` (the gather/copy/scatter of its live KV
        blocks), which also pays the server's 2*eps handling share, so the
        move enters Eqs (1)-(6) exactly like
        ``analyze_pool_under_migrations`` prices it.  With ``dst`` given,
        only that device is tried (work stealing names its target);
        otherwise worst-fit order over the other live devices
        (consolidation lets admission choose).  On success the stream's
        admission slot moves atomically: removed from the source
        controller, the augmented task admitted at the destination —
        keeping the cost segment in the destination's stream set is
        deliberately conservative, matching the analysis side appending it
        to every later phase.  Returns (decision, device); device is -1
        when no destination can prove it (the stream stays put, nothing
        changes)."""
        src = self.placement.get(name)
        if src is None:
            return AdmissionDecision(False, f"unknown stream {name!r}"), -1
        task = next(t for t in self.devices[src].streams if t.name == name)
        mc = float(migration_cost_ms)
        cand = (replace(task, segments=(*task.segments,
                                        GpuSegment(e=0.9 * mc, m=0.1 * mc)))
                if mc > 0 else task)
        if dst is not None:
            order = [dst]
            if not (0 <= dst < self.num_devices) or not self.alive[dst]:
                return AdmissionDecision(False,
                                         f"device {dst} is not alive"), -1
            if dst == src:
                return AdmissionDecision(False, "already there"), -1
        else:
            order = sorted((d for d in range(self.num_devices)
                            if self.alive[d] and d != src),
                           key=self.gpu_utilization)
        last = AdmissionDecision(False, "no destination device")
        for d in order:
            decision = self.devices[d].try_admit(cand)
            if decision.admitted:
                self.devices[src].remove(name)
                self.placement[name] = d
                return decision, d
            last = decision
        return last, -1

    def add_device(self) -> int:
        """Grow the pool by one admission partition (elastic scale-up);
        returns its device index.  The new device starts empty and
        immediately participates in worst-fit placement."""
        self.devices.append(AdmissionController(
            self.cores_per_device, epsilon_ms=self.epsilon_ms,
            heuristic=self.heuristic, min_batch=self.min_batch,
            cost_model=self.cost_model))
        self.alive.append(True)
        return len(self.devices) - 1

    def drain_device(self, device: int, *, migration_cost_ms=0.0,
                     ) -> DegradedReport:
        """Elastic scale-down: re-prove every stream of ``device`` on the
        remaining devices and mark the device gone.  This is exactly
        ``evict_device`` with the extra segment priced as a migration copy
        instead of a recovery re-prefill — a planned drain moves live KV
        blocks (cheap) where a failure re-prefills (expensive); the
        displacement, shedding, and schedulability machinery is identical.
        """
        return self.evict_device(device, recovery_cost_ms=migration_cost_ms)

    # -- degraded-mode admission (device failure) --------------------------
    def evict_device(self, device: int, *, recovery_cost_ms=0.0,
                     ) -> DegradedReport:
        """Re-run admission for a shrunk pool after device ``device`` died.

        Its streams are displaced and re-admitted on the survivors in
        DECREASING priority order, each with a recovery segment appended —
        one extra GPU request of ``recovery_cost_ms`` (a float, or a
        ``Task -> float`` callable so the engine can price each stream's
        re-prefill via the calibrated cost model).  The appended segment
        also pays the server's per-request 2*eps handling share, so the
        recovery delay enters Eqs (1)-(6) exactly like any other segment.

        When a displaced stream fails admission everywhere, the globally
        LOWEST-priority admitted stream (strictly below the displaced one)
        is shed and the admission retried; only when no such victim
        remains is the displaced stream itself shed.  Idempotent: evicting
        an already-dead device reports nothing new."""
        if not (0 <= device < self.num_devices):
            raise ValueError(f"device {device} outside pool of "
                             f"{self.num_devices}")
        report = DegradedReport(device=device)
        if not self.alive[device]:
            return report
        self.alive[device] = False
        ctrl = self.devices[device]
        displaced = sorted(ctrl.streams, key=lambda t: -t.priority)
        ctrl.streams = []
        for t in displaced:
            self.placement.pop(t.name, None)
        price = (recovery_cost_ms if callable(recovery_cost_ms)
                 else (lambda _t, _rc=float(recovery_cost_ms): _rc))
        for t in displaced:
            rc = float(price(t))
            report.recovery_ms[t.name] = rc
            cand = (replace(t, segments=(*t.segments, GpuSegment(e=rc, m=0.0)))
                    if rc > 0 else t)
            while True:
                decision, d = self.try_admit(cand)
                if decision.admitted:
                    report.moved[t.name] = d
                    break
                report.reasons[t.name] = decision.reason
                victim = self._lowest_priority_admitted(below=t.priority)
                if victim is None:
                    report.shed.append(t.name)
                    break
                self.remove(victim.name)
                report.shed.append(victim.name)
        return report

    def _lowest_priority_admitted(self, *, below: int) -> Task | None:
        cands = [t for d in range(self.num_devices) if self.alive[d]
                 for t in self.devices[d].streams if t.priority < below]
        return min(cands, key=lambda t: t.priority) if cands else None


class MultiPodAdmission(PoolAdmissionController):
    """Historical alias (§7 future work, pod vocabulary): one GPU server
    per pod/accelerator, worst-fit placement — exactly
    :class:`PoolAdmissionController` with pod-flavored names."""

    def __init__(self, num_pods: int, *, cores_per_pod: int = 2,
                 epsilon_ms: float = 0.05):
        super().__init__(num_pods, cores_per_device=cores_per_pod,
                         epsilon_ms=epsilon_ms)

    @property
    def pods(self) -> list[AdmissionController]:
        return self.devices
