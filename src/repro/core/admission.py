"""Analysis-driven admission control (beyond-paper, built from the paper's
analysis).

A serving deployment declares each workload stream as a sporadic task
(period, deadline, CPU-side cost, device-segment costs).  A new stream is
admitted iff the server-based analysis (Eqs (1)-(6)) proves every admitted
stream still meets its deadline.  This turns the paper's offline
schedulability test into an online admission test — the GPU server has
central knowledge of all requests (paper §7 notes this enables exactly this
kind of feature).

Streams are allocated to cores (and, across pods, to per-pod servers) with
the paper's WFD-with-server packing (§5.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from . import server_analysis
from .allocation import allocate
from .task_model import Task
from .taskset_gen import assign_rm_priorities

__all__ = ["AdmissionController", "AdmissionDecision"]


@dataclass
class AdmissionDecision:
    admitted: bool
    reason: str = ""
    response_times: dict[str, float] = field(default_factory=dict)


class AdmissionController:
    """Holds the currently-admitted stream set for one accelerator (pod)."""

    def __init__(self, num_cores: int, *, epsilon_ms: float = 0.05, heuristic: str = "wfd"):
        self.num_cores = num_cores
        self.epsilon = epsilon_ms
        self.heuristic = heuristic
        self.streams: list[Task] = []

    def _check(self, tasks: list[Task]) -> AdmissionDecision:
        tasks = assign_rm_priorities(tasks)
        system = allocate(
            tasks,
            self.num_cores,
            approach="server",
            epsilon=self.epsilon,
            heuristic=self.heuristic,
        )
        res = server_analysis.analyze(system)
        if res.schedulable:
            return AdmissionDecision(True, "schedulable", res.response_times)
        misses = [n for n, w in res.response_times.items() if not w <= _deadline(tasks, n)]
        return AdmissionDecision(False, f"deadline miss for {misses}", res.response_times)

    def try_admit(self, stream: Task) -> AdmissionDecision:
        if any(t.name == stream.name for t in self.streams):
            return AdmissionDecision(False, f"duplicate stream name {stream.name!r}")
        decision = self._check([*self.streams, stream])
        if decision.admitted:
            self.streams.append(stream)
        return decision

    def remove(self, name: str) -> None:
        self.streams = [t for t in self.streams if t.name != name]

    def utilization(self) -> float:
        return sum(t.U for t in self.streams)


def _deadline(tasks: list[Task], name: str) -> float:
    for t in tasks:
        if t.name == name:
            return t.D
    return float("inf")


class MultiPodAdmission:
    """Beyond-paper (§7 future work): one GPU server per pod/accelerator;
    new streams are placed on the pod where they fit, by worst-fit on
    accelerator utilization (the paper's own WFD discipline, applied at the
    pod level)."""

    def __init__(self, num_pods: int, *, cores_per_pod: int = 2,
                 epsilon_ms: float = 0.05):
        self.pods = [AdmissionController(cores_per_pod, epsilon_ms=epsilon_ms)
                     for _ in range(num_pods)]
        self.placement: dict[str, int] = {}

    def gpu_utilization(self, pod: int) -> float:
        return sum(t.G / t.T for t in self.pods[pod].streams)

    def try_admit(self, stream: Task) -> tuple[AdmissionDecision, int]:
        """Try pods in worst-fit (emptiest accelerator first) order."""
        order = sorted(range(len(self.pods)), key=self.gpu_utilization)
        last = AdmissionDecision(False, "no pods")
        for p in order:
            decision = self.pods[p].try_admit(stream)
            if decision.admitted:
                self.placement[stream.name] = p
                return decision, p
            last = decision
        return last, -1

    def remove(self, name: str) -> None:
        pod = self.placement.pop(name, None)
        if pod is not None:
            self.pods[pod].remove(name)
