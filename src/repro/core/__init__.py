"""Core layer: the paper's contribution.

  * task_model       — τ_i = (C,T,D,G,η) with (G^e, G^m) segments (§3)
  * server_analysis  — the server-based schedulability analysis (§5.2)
  * mpcp_analysis    — synchronization-based baseline, MPCP (§4, §6.3)
  * fmlp_analysis    — synchronization-based baseline, FMLP+ (§6.3)
  * taskset_gen      — Table-2 random taskset generator
  * allocation       — WFD/FFD/BFD packing with the GPU server (§5.3, Eq 8)
  * simulator        — discrete-event ground truth for all three protocols
  * server_runtime   — executable server (threads; used by repro.serving)
  * admission        — analysis-driven admission control (beyond paper)
"""

from . import (  # noqa: F401
    admission,
    allocation,
    fmlp_analysis,
    mpcp_analysis,
    server_analysis,
    simulator,
    taskset_gen,
)
from .server_runtime import AcceleratorServer, Request  # noqa: F401
from .task_model import GpuSegment, System, Task, server_utilization  # noqa: F401
