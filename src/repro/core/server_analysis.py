"""Schedulability analysis for the server-based approach (Kim et al. 2017, §5.2).

Implements, exactly as in the paper:

  Lemma 1   each GPU request costs at most 2*eps of extra CPU time.
  Lemma 2 / Eq (1)   B_i^gpu = B_i^w + G_i + 2*eta_i*eps          (eta_i > 0)
  Eq (2)    B_i^w = min(B_i^rd, B_i^jd)        (the "improved" double bound)
  Lemma 3 / Eq (3)   request-driven per-request waiting bound (recurrence)
  Lemma 4 / Eq (4)   job-driven waiting bound (uses the response time W_i)
  Eq (5)    response time, task on a different core than the GPU server
  Eq (6)    response time, task on the same core as the GPU server
  Lemma 5   (Bletsas et al.) self-suspension jitter form used in (5)/(6)

Conventions: larger ``priority`` = higher priority; times in ms; a response
time of ``math.inf`` means the recurrence exceeded the deadline (task deemed
unschedulable, matching how the paper's experiments count schedulability).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

from .faults import DeviceFault
from .migration import StreamMigration
from .task_model import System, Task, ceil_div

__all__ = [
    "request_driven_bound",
    "job_driven_bound",
    "waiting_bound",
    "fifo_waiting_bound",
    "edf_waiting_bound",
    "gpu_handling_time",
    "response_time",
    "analyze",
    "analyze_fifo_server",
    "analyze_edf_server",
    "analyze_pool",
    "analyze_pool_under_faults",
    "analyze_pool_under_migrations",
    "amortized_server_overhead",
    "AnalysisResult",
    "FaultedAnalysisResult",
    "MigratedAnalysisResult",
    "PoolAnalysisResult",
]

_MAX_ITERS = 10_000


def _lp_max_segment(system: System, task: Task) -> float:
    """max_{pi_l < pi_i, 1<=k<=eta_l} (G_{l,k} + eps): the longest GPU segment
    (plus one server invocation) among lower-priority tasks.  Zero when no
    lower-priority task uses the GPU (max over the empty set)."""
    eps = system.epsilon
    vals = [
        seg.total + eps
        for t in system.lower_prio(task)
        for seg in t.segments
    ]
    return max(vals, default=0.0)


def _hp_interference(system: System, task: Task, window: float) -> float:
    """sum_{pi_h > pi_i, 1<=k<=eta_h} (ceil(window/T_h) + 1) * (G_{h,k} + eps).

    Carry-in '+1' per Lemmas 3/4.  Applies to *all* higher-priority tasks in
    the system regardless of core: the server's queue is ordered by task
    priority globally.
    """
    eps = system.epsilon
    total = 0.0
    for h in system.higher_prio(task):
        if not h.uses_gpu:
            continue
        n_jobs = ceil_div(window, h.T) + 1
        total += n_jobs * sum(seg.total + eps for seg in h.segments)
    return total


def request_driven_bound(system: System, task: Task, *, horizon: float) -> float:
    """B_{i,j}^{rd} via the recurrence of Eq (3).

    The bound is identical for every j (Eq (3) does not depend on j), so the
    total request-driven bound is  B_i^rd = eta_i * B_{i,j}^rd.
    Returns ``inf`` if the recurrence exceeds ``horizon``.
    """
    if not task.uses_gpu:
        return 0.0
    first = _lp_max_segment(system, task)
    b = first  # B^{rd,0}: the first term of the equation (paper, Lemma 3)
    for _ in range(_MAX_ITERS):
        nxt = first + _hp_interference(system, task, b)
        if nxt > horizon:
            return math.inf
        if nxt <= b + 1e-12:
            return nxt
        b = nxt
    return math.inf


def job_driven_bound(system: System, task: Task, W_i: float) -> float:
    """B_i^{jd} per Eq (4): job-level waiting bound over a window of W_i.

      B_i^jd = eta_i * max_{lp}(G_{l,k}+eps)
             + sum_{hp h,k} (ceil(W_i/T_h)+1) (G_{h,k}+eps)
    """
    if not task.uses_gpu:
        return 0.0
    if math.isinf(W_i):
        return math.inf
    return task.eta * _lp_max_segment(system, task) + _hp_interference(system, task, W_i)


def waiting_bound(system: System, task: Task, W_i: float, *, horizon: float) -> float:
    """B_i^w = min(B_i^rd, B_i^jd)  (Eq (2), the improved double bound)."""
    if not task.uses_gpu:
        return 0.0
    b_rd = request_driven_bound(system, task, horizon=horizon)
    total_rd = task.eta * b_rd if not math.isinf(b_rd) else math.inf
    b_jd = job_driven_bound(system, task, W_i)
    return min(total_rd, b_jd)


def gpu_handling_time(system: System, task: Task, W_i: float, *, horizon: float) -> float:
    """B_i^gpu per Eq (1)."""
    if not task.uses_gpu:
        return 0.0
    b_w = waiting_bound(system, task, W_i, horizon=horizon)
    if math.isinf(b_w):
        return math.inf
    return b_w + task.G + 2 * task.eta * system.epsilon


def _server_interference(system: System, task: Task, window: float) -> float:
    """Last term of Eq (6): CPU demand of the GPU server on its own core.

      sum_{tau_j != tau_i, eta_j > 0}
          ceil((W + (D_j - (G_j^m + 2 eta_j eps))) / T_j) * (G_j^m + 2 eta_j eps)

    The (D_j - exec) term is the Lemma-5 self-suspension jitter of the server
    work generated by tau_j (the server suspends during CPU-inactive spans).
    """
    eps = system.epsilon
    total = 0.0
    for t in system.tasks:
        if t is task or not t.uses_gpu:
            continue
        exec_j = t.Gm + 2 * t.eta * eps
        jitter = max(t.D - exec_j, 0.0)
        total += ceil_div(window + jitter, t.T) * exec_j
    return total


def response_time(
    system: System,
    task: Task,
    hp_response: dict[str, float],
    *,
    use_deadline_jitter: bool = False,
) -> float:
    """Worst-case response time of ``task`` per Eq (5) (different core than the
    server) or Eq (6) (same core as the server).

    ``hp_response`` maps task name -> already-computed response time W_h for
    every higher-priority task (analysis proceeds in decreasing priority
    order).  Per the note under Lemma 5, D_h may be used instead of W_h; we do
    so when ``use_deadline_jitter`` or when W_h did not converge.
    """
    on_server_core = task.core == system.server_core
    horizon = task.D

    def jitter(h: Task) -> float:
        # (W_h - C_h) accounts for "the self-suspending effect of
        # higher-priority GPU-using tasks" (paper, proof of Thm 1).  A
        # CPU-only task never self-suspends, so it carries no jitter
        # (classic RTA; also the Chen et al. correction's scope).
        if not h.uses_gpu:
            return 0.0
        w_h = hp_response.get(h.name, math.inf)
        if use_deadline_jitter or math.isinf(w_h):
            w_h = h.D
        return max(w_h - h.C, 0.0)

    local_hp = [h for h in system.higher_prio(task, same_core=True)]

    w = task.C + gpu_handling_time(system, task, task.C, horizon=horizon)
    if math.isinf(w):
        return math.inf
    for _ in range(_MAX_ITERS):
        b_gpu = gpu_handling_time(system, task, w, horizon=horizon)
        if math.isinf(b_gpu):
            return math.inf
        nxt = task.C + b_gpu
        for h in local_hp:
            nxt += ceil_div(w + jitter(h), h.T) * h.C
        if on_server_core:
            nxt += _server_interference(system, task, w)
        if nxt > horizon:
            return math.inf
        if nxt <= w + 1e-12:
            return nxt
        w = nxt
    return math.inf


def fifo_waiting_bound(system: System, task: Task, W_i: float) -> float:
    """Beyond-paper (the paper's §7/Fig-15 future-work suggestion): the
    waiting bound when the GPU server's queue is FIFO-ordered instead of
    priority-ordered.

    Request-driven: when a request of tau_i enqueues, at most one earlier
    request of EVERY other task is ahead (later arrivals queue behind):
        B^rd = eta_i * sum_{x != i} max_k (G_{x,k} + eps)
    Job-driven: other tasks' total GPU demand during W_i bounds the same
    quantity; take the min (Eq (2)'s double-bound idea applies verbatim).
    """
    if not task.uses_gpu:
        return 0.0
    eps = system.epsilon
    others = [t for t in system.tasks if t is not task and t.uses_gpu]
    rd_one = sum(max((s.total + eps for s in t.segments), default=0.0)
                 for t in others)
    b_rd = task.eta * rd_one
    if math.isinf(W_i):
        return b_rd
    b_jd = sum((ceil_div(W_i, t.T) + 1) * sum(s.total + eps for s in t.segments)
               for t in others)
    return min(b_rd, b_jd)


def edf_waiting_bound(system: System, task: Task, W_i: float) -> float:
    """Beyond-paper: waiting bound for an EDF-ordered server queue (the
    ``dispatch.policy`` 'edf' ordering serving is already wired for).

    Only the ORDER-AGNOSTIC job-driven argument survives EDF: a request of
    tau_i can be overtaken by any request with an earlier absolute deadline,
    including ones that arrive after it, so the FIFO "at most one earlier
    request per other task" count does not hold.  What does hold for any
    work-conserving single-server queue: while tau_i's request waits, the
    server only serves requests of OTHER tasks that arrived inside tau_i's
    response window (plus one carry-in each) — the job-driven term of Eq (4)
    with eps per segment, exactly ``fifo_waiting_bound``'s second leg."""
    if not task.uses_gpu:
        return 0.0
    if math.isinf(W_i):
        return math.inf
    eps = system.epsilon
    return sum((ceil_div(W_i, t.T) + 1) * sum(s.total + eps for s in t.segments)
               for t in system.tasks if t is not task and t.uses_gpu)


def _analyze_ordered_server(system: System, waiting) -> "AnalysisResult":
    """Shared response-time recurrence for the non-priority server queue
    orderings; ``waiting(system, task, w)`` supplies the ordering-specific
    B_i^w term, everything else is Eqs (5)/(6) verbatim."""
    res = AnalysisResult()
    for task in sorted(system.tasks, key=lambda t: -t.priority):
        horizon = task.D
        local_hp = system.higher_prio(task, same_core=True)
        on_server_core = task.core == system.server_core

        def jitter(h: Task) -> float:
            if not h.uses_gpu:
                return 0.0
            w_h = res.response_times.get(h.name, math.inf)
            return max((h.D if math.isinf(w_h) else w_h) - h.C, 0.0)

        w = task.C
        converged = False
        for _ in range(_MAX_ITERS):
            b_w = waiting(system, task, w)
            b_gpu = (b_w + task.G + 2 * task.eta * system.epsilon
                     if task.uses_gpu else 0.0)
            nxt = task.C + b_gpu
            for h in local_hp:
                nxt += ceil_div(w + jitter(h), h.T) * h.C
            if on_server_core:
                nxt += _server_interference(system, task, w)
            if nxt > horizon:
                break
            if nxt <= w + 1e-12:
                converged = True
                w = nxt
                break
            w = nxt
        res.response_times[task.name] = w if converged else math.inf
        res.gpu_handling[task.name] = (
            waiting(system, task, res.response_times[task.name])
            + task.G + 2 * task.eta * system.epsilon
            if task.uses_gpu and converged else
            (math.inf if task.uses_gpu else 0.0))
        if not converged:
            res.schedulable = False
    return res


def analyze_fifo_server(system: System) -> "AnalysisResult":
    """Full-system analysis with the FIFO-ordered server: identical response
    time recurrences, FIFO waiting bound."""
    return _analyze_ordered_server(system, fifo_waiting_bound)


def analyze_edf_server(system: System) -> "AnalysisResult":
    """Full-system analysis with the EDF-ordered server: identical response
    time recurrences, order-agnostic (job-driven-only) waiting bound."""
    return _analyze_ordered_server(system, edf_waiting_bound)


@dataclass
class AnalysisResult:
    """Outcome of a full-system analysis."""

    response_times: dict[str, float] = field(default_factory=dict)
    gpu_handling: dict[str, float] = field(default_factory=dict)
    schedulable: bool = True

    def wcrt(self, name: str) -> float:
        return self.response_times[name]


def analyze(system: System, *, use_deadline_jitter: bool = False) -> AnalysisResult:
    """Analyze every task (decreasing priority order) under the server-based
    approach.  The system is schedulable iff every W_i <= D_i."""
    res = AnalysisResult()
    for task in sorted(system.tasks, key=lambda t: -t.priority):
        w = response_time(
            system, task, res.response_times, use_deadline_jitter=use_deadline_jitter
        )
        res.response_times[task.name] = w
        res.gpu_handling[task.name] = gpu_handling_time(
            system, task, w if not math.isinf(w) else task.D, horizon=task.D
        )
        if math.isinf(w) or w > task.D + 1e-9:
            res.schedulable = False
    return res


@dataclass
class PoolAnalysisResult:
    """Per-device analyses of a multi-accelerator pool, plus the merged
    view (stream names are globally unique, so the merge is a plain union)."""

    per_device: dict[int, AnalysisResult] = field(default_factory=dict)
    response_times: dict[str, float] = field(default_factory=dict)
    gpu_handling: dict[str, float] = field(default_factory=dict)
    schedulable: bool = True

    def wcrt(self, name: str) -> float:
        return self.response_times[name]


def analyze_pool(system: System, *,
                 use_deadline_jitter: bool = False) -> PoolAnalysisResult:
    """Per-server schedulability analysis of a multi-accelerator pool.

    Because stream-to-server assignment is partitioned (dispatch.ServerPool;
    ``allocation.allocate_pool`` builds core-disjoint device partitions),
    each server's queue contains only its own tasks and Eqs (1)-(6) apply
    verbatim WITHIN each device partition: ``System.subsystem(d)`` carves
    out device d's tasks plus its server core (and raises if a core is
    shared across partitions, which would invalidate the decomposition).
    The pool is schedulable iff every partition is.

    The bounds are also sound for the *batched* dispatcher (runtime
    ``dispatch.BatchingServer``, simulator mode 'server_batched'): batching
    only lets same-shape requests join the head request's device call —
    G^e/G^m and the 2*eps overhead are paid at most once per request, never
    more — so every per-request term in Eqs (1)-(6) still upper-bounds its
    batched counterpart (see ``amortized_server_overhead`` for the tighter
    overhead when a minimum batch size is guaranteed).

    CALIBRATED admission (``core.admission`` with a
    ``analysis.cost_model.StepCostModel``) re-prices each stream's GPU
    segments at ``min(declared, predicted-for-its-bucket)`` before this
    analysis runs.  Soundness is unchanged because every equation here is
    monotone non-decreasing in the G^e/G^m inputs: the bounds computed on
    calibrated costs dominate any execution whose device calls run within
    those calibrated costs, exactly as the declared-cost bounds dominate
    executions within declared costs.  What calibration changes is only
    WHICH cost vector is being promised — the measured per-bucket cost of
    the trace the stream actually runs in, rather than the full-width
    worst case (property-tested in tests/test_cost_model.py).
    """
    res = PoolAnalysisResult()
    for d in range(system.num_gpus):
        sub = analyze(system.subsystem(d),
                      use_deadline_jitter=use_deadline_jitter)
        res.per_device[d] = sub
        res.response_times.update(sub.response_times)
        res.gpu_handling.update(sub.gpu_handling)
        res.schedulable = res.schedulable and sub.schedulable
    return res


@dataclass
class FaultedAnalysisResult:
    """Recovery-augmented pool analysis under a device-fault schedule.

    ``phases[k]`` is the plain ``analyze_pool`` result of phase system S_k
    (S_0 = the original partitioned system; S_{k+1} applies fault k:
    every task of the dead device migrates to the failover target with the
    fault's recovery segment appended).  ``response_times`` carries the
    per-task recovery-augmented bound; ``recovery_delay`` its excess over
    the fault-free phase-0 bound."""

    phases: list[PoolAnalysisResult] = field(default_factory=list)
    response_times: dict[str, float] = field(default_factory=dict)
    recovery_delay: dict[str, float] = field(default_factory=dict)
    schedulable: bool = True

    def wcrt(self, name: str) -> float:
        return self.response_times[name]


def analyze_pool_under_faults(
    system: System, faults: list[DeviceFault], *,
    use_deadline_jitter: bool = False,
) -> FaultedAnalysisResult:
    """Per-task response-time bounds that survive a device-fault schedule.

    Failure model (``core.faults.DeviceFault``): at ``at_ms`` a device dies
    mid-work; ``detect_ms`` later every task assigned to it migrates to the
    single surviving target ``to``, and each migrated task pays one extra
    GPU request — the ``recovery`` segment, the re-prefill of its retained
    token prefix (priced by the calibrated cost model at the serving
    layer).  The single-target migration keeps the post-failure device
    partitions core-disjoint, so ``analyze_pool``'s per-server
    decomposition applies verbatim to every phase system.

    The bound for task tau_i is

        W_i^ft  =  sum_k W_i(S_k)  +  sum_{faults f that migrate tau_i} detect(f)

    which dominates any execution under the schedule: a job wholly inside
    phase k finishes within W_i(S_k); a job straddling the k -> k+1
    boundary waited at most W_i(S_k) before the fault, then the detection
    gap, and its residual work — re-issued on the target including the
    recovery re-prefill — is no more than a fresh job of the *augmented*
    task, which S_{k+1} bounds by W_i(S_{k+1}).  Each phase term appears at
    most once per job, so the sum (plus the detection gaps this task
    actually suffers) covers every case.  It is deliberately conservative
    — the price of keeping Eqs (1)-(6) untouched inside each phase.

    The companion simulator (``core.simulator.simulate(..., faults=)``)
    replays the same schedule with strictly *weaker* semantics (recovery
    cost folded into the re-submitted segment, no extra server invocation),
    so this bound must dominate simulated WCRT — property-tested in
    tests/test_simulator_property.py.
    """
    res = FaultedAnalysisResult()
    phase_tasks: list[list[Task]] = [list(system.tasks)]
    detect = {t.name: 0.0 for t in system.tasks}
    for f in sorted(faults, key=lambda f: f.at_ms):
        nxt = []
        for t in phase_tasks[-1]:
            if t.device == f.device:
                segs = ((*t.segments, f.recovery) if f.recovery.total > 0
                        else t.segments)
                nxt.append(replace(t, device=f.to, segments=segs))
                detect[t.name] += f.detect_ms
            else:
                nxt.append(t)
        phase_tasks.append(nxt)
    for pt in phase_tasks:
        res.phases.append(analyze_pool(
            replace(system, tasks=list(pt)),
            use_deadline_jitter=use_deadline_jitter))
    for t in system.tasks:
        total = detect[t.name]
        for ph in res.phases:
            total += ph.response_times.get(t.name, 0.0)
        res.response_times[t.name] = total
        res.recovery_delay[t.name] = (
            total - res.phases[0].response_times.get(t.name, 0.0))
        if math.isinf(total) or total > t.D + 1e-9:
            res.schedulable = False
    return res


@dataclass
class MigratedAnalysisResult:
    """Migration-augmented pool analysis under a planned-migration schedule.

    ``phases[k]`` is the plain ``analyze_pool`` result of phase system S_k
    (S_0 = the original partitioned system; S_{k+1} applies migration k:
    the one named task moves to its destination device/core with the
    migration-cost segment appended).  ``response_times`` carries the
    per-task migration-augmented bound; ``migration_delay`` its excess
    over the migration-free phase-0 bound."""

    phases: list[PoolAnalysisResult] = field(default_factory=list)
    response_times: dict[str, float] = field(default_factory=dict)
    migration_delay: dict[str, float] = field(default_factory=dict)
    schedulable: bool = True

    def wcrt(self, name: str) -> float:
        return self.response_times[name]


def analyze_pool_under_migrations(
    system: System, migrations: list[StreamMigration], *,
    use_deadline_jitter: bool = False,
) -> MigratedAnalysisResult:
    """Per-task response-time bounds that survive a planned-migration
    schedule (work stealing / consolidation / elastic drain).

    Migration model (``core.migration.StreamMigration``): at ``at_ms`` one
    task is reassigned to device ``to`` on destination core ``core``
    (``-1`` keeps its current core), and its next job additionally pays the
    one-time ``cost`` segment — the gather/copy/scatter of its live KV
    blocks.  Unlike a fault there is no detection gap (the move is
    initiated by the pool, not discovered), and only the named task moves.
    The event carries its destination core so the phase partitions stay
    core-disjoint and ``analyze_pool``'s per-server decomposition applies
    verbatim to every phase system.

    The bound for task tau_i is

        W_i^mig  =  sum_k W_i(S_k)

    which dominates any execution under the schedule, by the same
    straddle-job argument ``analyze_pool_under_faults`` documents: a job
    wholly inside phase k finishes within W_i(S_k); a job of the migrated
    task straddling the k -> k+1 boundary waited at most W_i(S_k) before
    the move, and its remaining work — resumed on the destination with the
    migration copy folded in — is no more than a fresh job of the
    *augmented* task, which S_{k+1} bounds by W_i(S_{k+1}).  For a
    non-migrated task at the SOURCE server, the straddling job's residual
    interference is within the carry-in terms Eqs (3)/(4) already charge
    (one extra request per interfering task, and the lower-priority
    blocking term eta_i * lp_max present in both legs of Eq (2)); at the
    destination the augmented task is a member of S_{k+1} outright.
    Appending the cost segment to every later phase (rather than one job)
    is deliberately conservative, mirroring the recovery-segment treatment
    in the faults analysis.

    The companion simulator (``core.simulator.simulate(..., migrations=)``)
    replays the same schedule with strictly *weaker* semantics (job-
    granularity placement, cost folded once into the first post-move job),
    so this bound must dominate simulated WCRT — property-tested in
    tests/test_migration.py.
    """
    res = MigratedAnalysisResult()
    phase_tasks: list[list[Task]] = [list(system.tasks)]
    for m in sorted(migrations, key=lambda m: m.at_ms):
        nxt = []
        for t in phase_tasks[-1]:
            if t.name == m.task:
                segs = ((*t.segments, m.cost) if m.cost.total > 0
                        else t.segments)
                core = m.core if m.core >= 0 else t.core
                nxt.append(replace(t, device=m.to, core=core,
                                   segments=segs))
            else:
                nxt.append(t)
        phase_tasks.append(nxt)
    for pt in phase_tasks:
        res.phases.append(analyze_pool(
            replace(system, tasks=list(pt)),
            use_deadline_jitter=use_deadline_jitter))
    for t in system.tasks:
        total = 0.0
        for ph in res.phases:
            total += ph.response_times.get(t.name, 0.0)
        res.response_times[t.name] = total
        res.migration_delay[t.name] = (
            total - res.phases[0].response_times.get(t.name, 0.0))
        if math.isinf(total) or total > t.D + 1e-9:
            res.schedulable = False
    return res


def amortized_server_overhead(task: Task, epsilon: float,
                              min_batch: int = 1) -> float:
    """Lemma 1's per-job server overhead, 2*eta_i*eps, amortized by batched
    dispatch: when the dispatcher guarantees every request of tau_i rides a
    device call coalescing >= ``min_batch`` requests, the per-request share
    drops to 2*eps/min_batch.  With min_batch=1 (no guarantee) this is
    exactly the term Eq (1) uses — the default, and the only value that is
    sound unconditionally; larger values are an OPTIMISTIC what-if for
    capacity planning, not a schedulability bound."""
    if min_batch < 1:
        raise ValueError(f"min_batch must be >= 1, got {min_batch}")
    return 2.0 * task.eta * epsilon / min_batch
