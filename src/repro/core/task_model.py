"""Task model from Kim et al. 2017, Section 3.

A sporadic task with constrained deadline is

    tau_i := (C_i, T_i, D_i, G_i, eta_i)

where C_i is the WCET of all *normal* (CPU) execution segments, T_i the
minimum inter-arrival time, D_i <= T_i the relative deadline, G_i the
accumulated worst-case duration of all GPU access segments when the task
runs alone, and eta_i the number of GPU access segments per job.

Each GPU access segment j is further decomposed (Section 3):

    G_{i,j} := (G^e_{i,j}, G^m_{i,j})

G^e is the WCET of pure accelerator operations needing no CPU intervention
(kernel execution, DMA transfers); G^m is the WCET of the miscellaneous
CPU-side operations (issuing copies, launching kernels, completion
notification).  G_{i,j} <= G^e + G^m since the two need not lie on the same
control path and may overlap in asynchronous mode.

Utilization: U_i = (C_i + G_i) / T_i.

All times are in milliseconds (float).  Priorities are integers; following
the paper, *larger value = higher priority* and priorities are unique.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

__all__ = [
    "GpuSegment",
    "Task",
    "System",
    "server_utilization",
]


@dataclass(frozen=True)
class GpuSegment:
    """One GPU access segment G_{i,j} = (G^e, G^m)."""

    e: float  # G^e_{i,j}: pure accelerator time (no CPU intervention)
    m: float  # G^m_{i,j}: miscellaneous CPU-side time

    def __post_init__(self) -> None:
        if self.e < 0 or self.m < 0:
            raise ValueError(f"negative GPU segment components: {self}")

    @property
    def total(self) -> float:
        """G_{i,j}.  We take the paper's conservative synchronous-mode value
        G_{i,j} = G^e + G^m (the paper's generator also assumes this:
        'assuming G_{i,j} = G^e_{i,j} + G^m_{i,j}', Section 6.3)."""
        return self.e + self.m


@dataclass(frozen=True)
class Task:
    """Sporadic task tau_i.  ``segments`` has length eta_i."""

    name: str
    C: float  # total WCET of normal execution segments
    T: float  # minimum inter-arrival time (period)
    D: float  # relative deadline, D <= T
    segments: tuple[GpuSegment, ...] = ()
    priority: int = 0  # unique; larger = higher priority
    core: int = -1  # CPU core (partitioned scheduling); -1 = unassigned
    device: int = 0  # accelerator index (multi-GPU pools; 0 when single)

    def __post_init__(self) -> None:
        if self.C < 0:
            raise ValueError(f"{self.name}: negative C")
        if self.T <= 0:
            raise ValueError(f"{self.name}: non-positive T")
        if not (0 < self.D <= self.T):
            raise ValueError(f"{self.name}: need 0 < D <= T, got D={self.D} T={self.T}")

    # -- paper notation ------------------------------------------------
    @property
    def eta(self) -> int:
        """eta_i: number of GPU access segments."""
        return len(self.segments)

    @property
    def G(self) -> float:
        """G_i = sum_j G_{i,j}."""
        return sum(s.total for s in self.segments)

    @property
    def Gm(self) -> float:
        """G^m_i = sum_j G^m_{i,j} (misc CPU ops across all segments)."""
        return sum(s.m for s in self.segments)

    @property
    def Ge(self) -> float:
        """G^e_i = sum_j G^e_{i,j}."""
        return sum(s.e for s in self.segments)

    @property
    def U(self) -> float:
        """U_i = (C_i + G_i) / T_i."""
        return (self.C + self.G) / self.T

    @property
    def uses_gpu(self) -> bool:
        return self.eta > 0

    def with_core(self, core: int) -> "Task":
        return replace(self, core=core)

    def with_priority(self, priority: int) -> "Task":
        return replace(self, priority=priority)

    def with_device(self, device: int) -> "Task":
        return replace(self, device=device)


def server_utilization(tasks: list[Task], epsilon: float) -> float:
    """Eq. (8): U_server = sum_{tau_i: eta_i > 0} (G^m_i + 2 eta_i eps)/T_i."""
    return sum((t.Gm + 2 * t.eta * epsilon) / t.T for t in tasks if t.uses_gpu)


@dataclass
class System:
    """A partitioned system: tasks pinned to cores, one or more accelerators.

    ``epsilon`` is the GPU-server overhead bound (only meaningful for the
    server-based approach).  ``server_core`` is the core hosting the GPU
    server task (single-accelerator server-based approach).  A multi-
    accelerator pool sets ``server_cores`` (one server core per device);
    each task's ``device`` attribute names the accelerator its segments run
    on.  ``server_core``/``server_cores`` are kept consistent: for a
    single-device system either spelling works.
    """

    tasks: list[Task]
    num_cores: int
    epsilon: float = 0.0
    server_core: int = -1
    server_cores: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        prios = [t.priority for t in self.tasks]
        if len(set(prios)) != len(prios):
            raise ValueError("task priorities must be unique")
        for t in self.tasks:
            if not (0 <= t.core < self.num_cores):
                raise ValueError(f"{t.name}: core {t.core} outside 0..{self.num_cores - 1}")
        if not self.server_cores and self.server_core >= 0:
            self.server_cores = (self.server_core,)
        if self.server_cores and self.server_core < 0:
            self.server_core = self.server_cores[0]
        for t in self.tasks:
            if not (0 <= t.device < max(self.num_gpus, 1)):
                raise ValueError(
                    f"{t.name}: device {t.device} outside 0..{self.num_gpus - 1}")

    @property
    def num_gpus(self) -> int:
        return max(len(self.server_cores), 1)

    def device_tasks(self, device: int) -> list[Task]:
        return [t for t in self.tasks if t.device == device]

    def subsystem(self, device: int) -> "System":
        """The single-accelerator System of one device partition (its tasks
        plus its server core), for per-server analysis.  Core indices stay
        global.  Raises if the partition shares a core with another device
        (then per-device analysis would miss CPU interference)."""
        mine = {t.core for t in self.device_tasks(device)}
        for t in self.tasks:
            if t.device != device and t.core in mine:
                raise ValueError(
                    f"core {t.core} shared across devices {device} and "
                    f"{t.device}; partition is not core-disjoint")
        return System(
            tasks=[t.with_device(0) for t in self.device_tasks(device)],
            num_cores=self.num_cores,
            epsilon=self.epsilon,
            server_core=self.server_cores[device] if self.server_cores else -1,
        )

    # -- helpers used by every analysis ---------------------------------
    def local_tasks(self, core: int) -> list[Task]:
        return [t for t in self.tasks if t.core == core]

    def higher_prio(self, task: Task, *, same_core: bool | None = None) -> list[Task]:
        out = [t for t in self.tasks if t.priority > task.priority]
        if same_core is True:
            out = [t for t in out if t.core == task.core]
        elif same_core is False:
            out = [t for t in out if t.core != task.core]
        return out

    def lower_prio(self, task: Task, *, same_core: bool | None = None) -> list[Task]:
        out = [t for t in self.tasks if t.priority < task.priority]
        if same_core is True:
            out = [t for t in out if t.core == task.core]
        elif same_core is False:
            out = [t for t in out if t.core != task.core]
        return out

    def gpu_tasks(self) -> list[Task]:
        return [t for t in self.tasks if t.uses_gpu]

    @property
    def server_utilization(self) -> float:
        return server_utilization(self.tasks, self.epsilon)

    def core_utilization(self, core: int, *, approach: str) -> float:
        """CPU utilization of ``core``.

        Under the synchronization-based approach GPU segments busy-wait, so
        they consume CPU on the task's core: U = (C+G)/T.  Under the
        server-based approach the task suspends; only C/T is consumed on the
        task's core, while G^m + 2*eta*eps per period lands on the server's
        core.
        """
        u = 0.0
        for t in self.local_tasks(core):
            if approach == "sync":
                u += (t.C + t.G) / t.T
            elif approach == "server":
                u += t.C / t.T
            else:
                raise ValueError(approach)
        if approach == "server" and core == self.server_core:
            u += self.server_utilization
        return u


# Ceiling with a guard against float fuzz: ceil(x) where x is a ratio of
# millisecond floats. Without the guard, 3.0000000000000004 would ceil to 4
# and silently inflate interference terms.
def ceil_div(a: float, b: float) -> int:
    if b <= 0:
        raise ValueError("non-positive divisor")
    x = a / b
    c = math.ceil(x)
    if c - x > 1 - 1e-9 and c - 1 >= x - 1e-9:
        c -= 1
    return max(c, 0)
