"""Queue-ordering policy for accelerator servers.

One definition of request order, shared by the executable runtime
(``core.server_runtime.AcceleratorServer``) and the discrete-event
simulator (``core.simulator._GpuServer``): a request is dequeued by
ascending ``(request_key(...), arrival_seq)``, so ties always break FIFO.

  * ``priority`` — the paper's §5.1 server: task-priority order
    (larger priority value = served first).
  * ``fifo``     — the paper's §7 / Fig. 15 future-work variant: arrival
    order (key is constant; the arrival sequence number decides).
  * ``edf``      — beyond-paper: earliest absolute deadline first, used by
    serving for straggler mitigation; requests without a deadline sort
    last.
"""

from __future__ import annotations

import math

__all__ = ["ORDERINGS", "request_key"]

ORDERINGS = ("priority", "fifo", "edf")


def request_key(ordering: str, *, priority: int = 0,
                deadline: float | None = None) -> float:
    """Heap key for one request under ``ordering`` (smaller = served first)."""
    if ordering == "priority":
        return -priority
    if ordering == "edf":
        return deadline if deadline is not None else math.inf
    if ordering == "fifo":
        return 0.0
    raise ValueError(f"unknown ordering {ordering!r}; expected one of {ORDERINGS}")
