"""Batched accelerator dispatch: one device call for many requests.

The paper's Lemma 1 charges every GPU request 2*eps of server CPU
(receive/wake-up + completion/notify).  When several admitted streams sit
in the same phase — decode, where every step has the same shape — their
requests can ride one device call: the server pays the dispatch overhead
once per *batch*, and the accelerator runs one kernel over the stacked
inputs instead of k sequential kernels.  That is what closes the gap
between bounded-access predictability and throughput (GCAPS/RTGPU make the
same observation for fine-grain GPU sharing).

Mechanics: a batchable request carries a ``batch_key`` (shape class) and a
``payload`` instead of a closure.  When the server dequeues a batchable
head, it drains every queued request with the same key — up to
``max_batch`` — and hands all payloads to the head's ``run_batch``
callable, which performs ONE accelerator call and returns one result per
payload, in order.  Requests with different keys (or plain ``submit``
requests) are never coalesced, and dequeue order still follows the
server's ordering policy, so a batch can only *join* the head request,
never delay it: the head starts exactly when it would have unbatched.

All callers of one ``batch_key`` must supply the same ``run_batch``
semantics (the head's callable serves the whole batch).

Shape decisions stay visible: a ``run_batch`` that compacts rows, pads to a
power-of-two bucket, or narrows the KV gather to the live block-table width
reports what it chose via :meth:`BatchingServer.record_meta`; the entries
land in ``stats.batch_meta`` next to ``batch_sizes`` so the analysis side
(and tests) can audit that compaction/bucketing only ever SHRANK the device
call — the declared per-request WCET is the full-width call, which is what
keeps the per-server bounds (Eqs (1)-(6)) sound under both knobs.

The measurement -> fit -> admission loop rides the same channel.  Each meta
entry carries the call's timed duration (``seconds``) next to its shape
decision; ``ServerStats.record_meta`` folds it into a bounded ring buffer
plus a running per-cell aggregate keyed by ``server_runtime.cell_key`` —
``("decode", padded_rows, table_width)`` or ``("prefill", padded_rows,
len_bucket)``, the post-bucketing shape naming the jit trace that ran.
``analysis.cost_model.StepCostModel.ingest`` consumes those aggregates to
fit per-cell step-cost surfaces, which in turn drive calibrated admission
(``core.admission`` with ``cost_model=``), bucket auto-tuning
(``cost_model.autotune_buckets`` -> ``ServeEngine.tune_buckets``), and
traffic-aware precompilation (``ServeEngine.precompile(traffic=...)``).
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Any, Callable, Hashable

from repro.core.faults import DeviceLostError
from repro.core.server_runtime import AcceleratorServer, Request

__all__ = ["BatchRequest", "BatchingServer"]


@dataclass(order=False)
class BatchRequest(Request):
    """A request eligible for same-key coalescing."""

    batch_key: Hashable = None
    payload: Any = None
    run_batch: Callable[[list[Any]], list[Any]] | None = None


class BatchingServer(AcceleratorServer):
    """AcceleratorServer whose dequeue coalesces same-``batch_key`` requests
    into one device call (continuous batching for same-shape work)."""

    def __init__(self, *, ordering: str = "priority", max_batch: int = 8,
                 name: str = "batch-server"):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        super().__init__(ordering=ordering, name=name)

    # -- client API ------------------------------------------------------
    def submit_batch(
        self,
        payload: Any,
        *,
        run_batch: Callable[[list[Any]], list[Any]],
        batch_key: Hashable,
        priority: int = 0,
        deadline: float | None = None,
        name: str = "",
    ) -> BatchRequest:
        """Submit a batchable request; returns a waitable Request whose
        result is ``run_batch(payloads)[i]`` for this request's position in
        whatever batch it lands in."""
        if batch_key is None:
            raise ValueError("batch_key must be hashable and non-None")
        return self._enqueue(
            BatchRequest(fn=None, priority=priority, deadline=deadline,
                         name=name, batch_key=batch_key, payload=payload,
                         run_batch=run_batch))

    def record_meta(self, **decision) -> None:
        """Called by ``run_batch`` callables (on this server's thread) to
        surface per-call shape decisions — compaction, padding bucket, KV
        gather width, measured ``seconds`` — into the bounded
        ``stats.batch_meta`` ring and the running ``stats.cell_stats``
        per-cell aggregates the cost model consumes."""
        self.stats.record_meta(decision)

    # -- internals ---------------------------------------------------------
    def _dequeue_locked(self) -> list[Request]:
        _, _, head = heapq.heappop(self._queue)
        if not isinstance(head, BatchRequest):
            return [head]
        batch = [head]
        deferred = []
        while self._queue and len(batch) < self.max_batch:
            item = heapq.heappop(self._queue)
            req = item[2]
            if isinstance(req, BatchRequest) and req.batch_key == head.batch_key:
                batch.append(req)
            else:
                deferred.append(item)
        for item in deferred:
            heapq.heappush(self._queue, item)
        return batch

    def _execute(self, batch: list[Request]) -> None:
        head = batch[0]
        if not isinstance(head, BatchRequest):
            super()._execute(batch)
            return
        start = time.monotonic()
        for r in batch:
            r.start_t = start
            self.stats.wakeup_latencies.append(start - r.submit_t)
        results: list[Any] = []
        error: BaseException | None = None
        payloads = [r.payload for r in batch]
        try:
            results = self._attempt(lambda: head.run_batch(payloads))
            if len(results) != len(batch):
                raise RuntimeError(
                    f"run_batch returned {len(results)} results for a batch "
                    f"of {len(batch)}")
        except DeviceLostError as e:
            self.fail(e)  # fails the whole batch (it is in-flight)
            return
        except BaseException as e:  # noqa: BLE001 - surfaced to every client
            error = e
        with self._lock:
            t0 = time.monotonic()
            for i, r in enumerate(batch):
                if r.done:
                    continue  # a concurrent fail() already woke this client
                if error is not None:
                    r.error = error
                else:
                    r.result = results[i]
                r.end_t = t0
                r._done.set()
        self.stats.notify_latencies.append(time.monotonic() - t0)
        self.stats.completed += len(batch)
        self.stats.batches += 1
        self.stats.batch_sizes.append(len(batch))
