"""ServerPool: one accelerator server per device / mesh slice.

The paper partitions tasks to cores and gives the single GPU one server
task; here the accelerators themselves are plural, and the same partitioned
discipline applies one level up: every *stream* is assigned to exactly one
server when it is admitted, and all of its requests go through that server
for its lifetime.  Partitioned assignment is what keeps the analysis
compositional — each server's queue contains only its own streams, so
Eqs (1)-(6) apply within the partition (``server_analysis.analyze_pool``)
and admission of a stream on device d cannot disturb deadlines on device
d' != d.

Routing is priority-aware worst-fit: a new stream lands on the server with
the least declared device utilization, ties broken toward the server with
the fewest already-assigned streams of equal-or-higher priority (so
high-priority streams spread out instead of queueing behind each other),
then by index.  The caller may also pin a stream to an explicit server —
the serving engine does this to follow the admission controller's
device-assignment step (``allocation.allocate_pool``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Hashable

from repro.core.dispatch.batching import BatchingServer, BatchRequest
from repro.core.server_runtime import AcceleratorServer, CellStats, Request

__all__ = ["ServerPool", "StreamAssignment"]


@dataclass
class StreamAssignment:
    server: int
    utilization: float
    priority: int


class ServerPool:
    """A fixed set of accelerator servers plus the stream router."""

    def __init__(self, num_servers: int, *, ordering: str = "priority",
                 batching: bool = False, max_batch: int = 8,
                 name: str = "pool"):
        if num_servers < 1:
            raise ValueError(f"num_servers must be >= 1, got {num_servers}")
        self.batching = batching
        if batching:
            self.servers: list[AcceleratorServer] = [
                BatchingServer(ordering=ordering, max_batch=max_batch,
                               name=f"{name}-{i}")
                for i in range(num_servers)
            ]
        else:
            self.servers = [
                AcceleratorServer(ordering=ordering, name=f"{name}-{i}")
                for i in range(num_servers)
            ]
        self._assign_lock = threading.Lock()
        self._streams: dict[str, StreamAssignment] = {}

    # -- routing (partitioned, priority-aware worst-fit) -------------------
    def _route(self, utilization: float, priority: int) -> int:
        def load(i: int) -> tuple[float, int, int]:
            util = sum(a.utilization for a in self._streams.values()
                       if a.server == i)
            hp = sum(1 for a in self._streams.values()
                     if a.server == i and a.priority >= priority)
            return (util, hp, i)

        return min(range(len(self.servers)), key=load)

    def assign(self, stream: str, *, utilization: float = 0.0,
               priority: int = 0, server: int | None = None) -> int:
        """Bind ``stream`` to a server for its lifetime; returns the index.
        ``server`` pins the choice (e.g. from the admission controller's
        device assignment); otherwise the router picks worst-fit."""
        with self._assign_lock:
            if stream in self._streams:
                raise ValueError(f"stream {stream!r} already assigned")
            if server is None:
                server = self._route(utilization, priority)
            elif not (0 <= server < len(self.servers)):
                raise ValueError(f"server {server} outside pool of "
                                 f"{len(self.servers)}")
            self._streams[stream] = StreamAssignment(server, utilization, priority)
            return server

    def remove(self, stream: str) -> None:
        with self._assign_lock:
            self._streams.pop(stream, None)

    def server_of(self, stream: str) -> int:
        return self._streams[stream].server

    def server_for(self, stream: str) -> AcceleratorServer:
        return self.servers[self._streams[stream].server]

    # -- dispatch ----------------------------------------------------------
    def submit(self, stream: str, fn: Callable[[], Any], *, priority: int = 0,
               deadline: float | None = None, name: str = "") -> Request:
        return self.server_for(stream).submit(
            fn, priority=priority, deadline=deadline, name=name)

    def submit_batch(self, stream: str, payload: Any, *,
                     run_batch: Callable[[list[Any]], list[Any]],
                     batch_key: Hashable, priority: int = 0,
                     deadline: float | None = None,
                     name: str = "") -> BatchRequest:
        server = self.server_for(stream)
        if not isinstance(server, BatchingServer):
            raise TypeError("pool was built with batching=False")
        return server.submit_batch(payload, run_batch=run_batch,
                                   batch_key=batch_key, priority=priority,
                                   deadline=deadline, name=name)

    # -- measurement export ------------------------------------------------
    def cell_stats(self) -> dict:
        """Per-cell device-call aggregates merged across every server in the
        pool — one measurement table for the whole device fleet, in the
        shape ``analysis.cost_model.StepCostModel.ingest`` consumes.  The
        servers share jitted step functions (one engine), so same-cell calls
        on different devices price identically and pooling them is sound."""
        merged: dict = {}
        for s in self.servers:
            for key, cell in s.stats.cell_stats.items():
                if key in merged:
                    merged[key].merge(cell)
                else:
                    acc = CellStats()
                    acc.merge(cell)
                    merged[key] = acc
        return merged

    # -- lifecycle ---------------------------------------------------------
    def shutdown(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        for s in self.servers:
            s.shutdown(drain=drain, timeout=timeout)

    def __len__(self) -> int:
        return len(self.servers)

    def __enter__(self) -> "ServerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
