"""ServerPool: one accelerator server per device / mesh slice.

The paper partitions tasks to cores and gives the single GPU one server
task; here the accelerators themselves are plural, and the same partitioned
discipline applies one level up: every *stream* is assigned to exactly one
server when it is admitted, and all of its requests go through that server
for its lifetime.  Partitioned assignment is what keeps the analysis
compositional — each server's queue contains only its own streams, so
Eqs (1)-(6) apply within the partition (``server_analysis.analyze_pool``)
and admission of a stream on device d cannot disturb deadlines on device
d' != d.

Routing is priority-aware worst-fit: a new stream lands on the server with
the least declared device utilization, ties broken toward the server with
the fewest already-assigned streams of equal-or-higher priority (so
high-priority streams spread out instead of queueing behind each other),
then by index.  The caller may also pin a stream to an explicit server —
the serving engine does this to follow the admission controller's
device-assignment step (``allocation.allocate_pool``).

Fault tolerance: a server can die mid-traffic (its device call raises
``DeviceLostError``, exhausts transient retries, or stalls past the
heartbeat timeout).  ``evict_server(si)`` is the single choke point — it
marks the server dead for routing, fails it (waking every suspended
client with ``ServerFailedError``), and displaces its streams: either
re-routed worst-fit onto survivors or handed back to the caller so
degraded-mode admission can place (or shed) them.
``enable_failure_detection`` wires a ``HeartbeatMonitor``: each server
thread beats between device calls, so a call outlasting the timeout is a
stall and the monitor thread evicts the server from outside.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Hashable

from repro.core.dispatch.batching import BatchingServer, BatchRequest
from repro.core.server_runtime import AcceleratorServer, CellStats, Request

__all__ = ["ServerPool", "StreamAssignment"]


@dataclass
class StreamAssignment:
    server: int
    utilization: float
    priority: int


class ServerPool:
    """A fixed set of accelerator servers plus the stream router."""

    def __init__(self, num_servers: int, *, ordering: str = "priority",
                 batching: bool = False, max_batch: int = 8,
                 name: str = "pool"):
        if num_servers < 1:
            raise ValueError(f"num_servers must be >= 1, got {num_servers}")
        self.batching = batching
        if batching:
            self.servers: list[AcceleratorServer] = [
                BatchingServer(ordering=ordering, max_batch=max_batch,
                               name=f"{name}-{i}")
                for i in range(num_servers)
            ]
        else:
            self.servers = [
                AcceleratorServer(ordering=ordering, name=f"{name}-{i}")
                for i in range(num_servers)
            ]
        self._assign_lock = threading.Lock()
        self._streams: dict[str, StreamAssignment] = {}
        self._alive = [True] * num_servers
        self._monitor = None  # HeartbeatMonitor when detection is enabled

    # -- routing (partitioned, priority-aware worst-fit) -------------------
    def _route(self, utilization: float, priority: int) -> int:
        def load(i: int) -> tuple[float, int, int]:
            util = sum(a.utilization for a in self._streams.values()
                       if a.server == i)
            hp = sum(1 for a in self._streams.values()
                     if a.server == i and a.priority >= priority)
            return (util, hp, i)

        candidates = [i for i in range(len(self.servers)) if self._alive[i]]
        if not candidates:
            raise RuntimeError("no surviving servers in the pool")
        return min(candidates, key=load)

    def assign(self, stream: str, *, utilization: float = 0.0,
               priority: int = 0, server: int | None = None) -> int:
        """Bind ``stream`` to a server for its lifetime; returns the index.
        ``server`` pins the choice (e.g. from the admission controller's
        device assignment); otherwise the router picks worst-fit."""
        with self._assign_lock:
            if stream in self._streams:
                raise ValueError(f"stream {stream!r} already assigned")
            if server is None:
                server = self._route(utilization, priority)
            elif not (0 <= server < len(self.servers)):
                raise ValueError(f"server {server} outside pool of "
                                 f"{len(self.servers)}")
            elif not self._alive[server]:
                raise ValueError(f"server {server} has failed")
            self._streams[stream] = StreamAssignment(server, utilization, priority)
            return server

    def remove(self, stream: str) -> None:
        with self._assign_lock:
            self._streams.pop(stream, None)

    def server_of(self, stream: str) -> int:
        return self._streams[stream].server

    def server_for(self, stream: str) -> AcceleratorServer:
        return self.servers[self._streams[stream].server]

    # -- fault tolerance ---------------------------------------------------
    def alive_servers(self) -> list[int]:
        return [i for i in range(len(self.servers)) if self._alive[i]]

    def evict_server(self, si: int, *, cause: BaseException | None = None,
                     reroute: bool = True) -> dict[str, int | None] | None:
        """Declare server ``si`` dead and displace its streams.

        Idempotent and safe to call from any thread — the heartbeat monitor
        calls it on stall, the server's own thread on fatal device error,
        the engine's recovery path when a client wakes with
        ``ServerFailedError``; whichever races first wins and the rest see
        ``None`` (already evicted — nothing displaced by *this* call).  The
        server is failed (all its suspended clients wake), and every stream
        assigned to it is displaced in decreasing priority: with
        ``reroute=True`` each is re-bound worst-fit among survivors
        (returned as ``{stream: new_server}``); with ``reroute=False`` the
        bindings are dropped and returned as ``{stream: None}`` so the
        caller (degraded-mode admission) decides placement — or shedding —
        itself.
        """
        if not (0 <= si < len(self.servers)):
            raise ValueError(f"server {si} outside pool of {len(self.servers)}")
        with self._assign_lock:
            if not self._alive[si]:
                return None
            self._alive[si] = False
            displaced = sorted(
                (name for name, a in self._streams.items() if a.server == si),
                key=lambda n: -self._streams[n].priority)
            if not any(self._alive):
                reroute = False  # nowhere left to put them
            moved: dict[str, int | None] = {}
            for name in displaced:
                a = self._streams.pop(name)
                if reroute:
                    new = self._route(a.utilization, a.priority)
                    self._streams[name] = StreamAssignment(
                        new, a.utilization, a.priority)
                    moved[name] = new
                else:
                    moved[name] = None
        if self._monitor is not None:
            self._monitor.unregister(self.servers[si].name)
        self.servers[si].fail(cause)  # reentrant-safe: _alive already False
        return moved

    def reassign(self, stream: str, server: int, *, utilization: float = 0.0,
                 priority: int = 0) -> None:
        """Re-bind a (possibly displaced) stream to an explicit live server
        — the degraded-admission path after ``evict_server(reroute=False)``."""
        with self._assign_lock:
            if not (0 <= server < len(self.servers)) or not self._alive[server]:
                raise ValueError(f"server {server} is not alive")
            self._streams[stream] = StreamAssignment(
                server, utilization, priority)

    def enable_failure_detection(
        self, *, timeout: float = 1.0, poll: float = 0.05,
        on_death: Callable[[int, dict], None] | None = None,
    ) -> "Any":
        """Wire a ``HeartbeatMonitor`` across the pool: every server thread
        beats between device calls (and each ``poll``-ish interval while
        idle), so a single device call outlasting ``timeout`` is a stall
        and the monitor thread evicts that server from outside — the
        per-device-call timeout.  Detection covers every death path: stall
        (monitor thread) and fatal device error / retry exhaustion (the
        server's own thread, via ``fail`` -> ``on_failure``).

        With ``on_death`` set, eviction uses ``reroute=False`` and
        ``on_death(si, displaced)`` receives the dropped bindings — the
        serving engine hangs degraded-mode admission here.  Whichever path
        evicts first is the only one that fires ``on_death``.  Returns the
        monitor (owned by the pool; ``shutdown`` closes it)."""
        from repro.runtime.fault_tolerance import HeartbeatMonitor

        index_of = {s.name: i for i, s in enumerate(self.servers)}
        reroute = on_death is None

        def _report(si: int, cause: BaseException) -> None:
            displaced = self.evict_server(si, cause=cause, reroute=reroute)
            if displaced is not None and on_death is not None:
                on_death(si, displaced)

        def _stalled(worker: str) -> None:
            _report(index_of[worker], TimeoutError(
                f"no heartbeat from {worker!r} for {timeout}s"))

        monitor = HeartbeatMonitor(timeout=timeout, poll=poll,
                                   on_failure=_stalled)
        self._monitor = monitor
        for i, s in enumerate(self.servers):
            monitor.register(s.name)
            s.beat = (lambda name=s.name: monitor.beat(name))
            s.beat_interval_s = min(s.beat_interval_s, max(poll, 1e-3))
            s.on_failure = (lambda server, si=i:
                            _report(si, server.fail_cause))
        return monitor

    def attach_fault_injector(self, injector: "Any") -> None:
        """Install a ``runtime.faultinject.FaultInjector``'s per-server
        hooks into every server's device-call path."""
        injector.attach(self)

    # -- dispatch ----------------------------------------------------------
    def submit(self, stream: str, fn: Callable[[], Any], *, priority: int = 0,
               deadline: float | None = None, name: str = "") -> Request:
        return self.server_for(stream).submit(
            fn, priority=priority, deadline=deadline, name=name)

    def submit_batch(self, stream: str, payload: Any, *,
                     run_batch: Callable[[list[Any]], list[Any]],
                     batch_key: Hashable, priority: int = 0,
                     deadline: float | None = None,
                     name: str = "") -> BatchRequest:
        server = self.server_for(stream)
        if not isinstance(server, BatchingServer):
            raise TypeError("pool was built with batching=False")
        return server.submit_batch(payload, run_batch=run_batch,
                                   batch_key=batch_key, priority=priority,
                                   deadline=deadline, name=name)

    # -- measurement export ------------------------------------------------
    def cell_stats(self) -> dict:
        """Per-cell device-call aggregates merged across every server in the
        pool — one measurement table for the whole device fleet, in the
        shape ``analysis.cost_model.StepCostModel.ingest`` consumes.  The
        servers share jitted step functions (one engine), so same-cell calls
        on different devices price identically and pooling them is sound."""
        merged: dict = {}
        for s in self.servers:
            for key, cell in s.stats.cell_stats.items():
                if key in merged:
                    merged[key].merge(cell)
                else:
                    acc = CellStats()
                    acc.merge(cell)
                    merged[key] = acc
        return merged

    # -- lifecycle ---------------------------------------------------------
    def shutdown(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Close the pool.  The monitor goes down FIRST — servers stop
        beating the moment they are told to stop, and a monitor left
        running would race eviction callbacks into a half-torn-down pool.
        With ``drain=True`` every server then finishes its queued and
        in-flight work before joining; with ``drain=False`` pending
        requests are failed (clients wake) and only in-flight work runs
        out."""
        if self._monitor is not None:
            self._monitor.close()
            self._monitor = None
        for s in self.servers:
            s.shutdown(drain=drain, timeout=timeout)

    def __len__(self) -> int:
        return len(self.servers)

    def __enter__(self) -> "ServerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
