"""ServerPool: one accelerator server per device / mesh slice.

The paper partitions tasks to cores and gives the single GPU one server
task; here the accelerators themselves are plural, and the same partitioned
discipline applies one level up: every *stream* is assigned to exactly one
server when it is admitted, and all of its requests go through that server
for its lifetime.  Partitioned assignment is what keeps the analysis
compositional — each server's queue contains only its own streams, so
Eqs (1)-(6) apply within the partition (``server_analysis.analyze_pool``)
and admission of a stream on device d cannot disturb deadlines on device
d' != d.

Routing is priority-aware worst-fit: a new stream lands on the server with
the least declared device utilization, ties broken toward the server with
the fewest already-assigned streams of equal-or-higher priority (so
high-priority streams spread out instead of queueing behind each other),
then by index.  The caller may also pin a stream to an explicit server —
the serving engine does this to follow the admission controller's
device-assignment step (``allocation.allocate_pool``).

Fault tolerance: a server can die mid-traffic (its device call raises
``DeviceLostError``, exhausts transient retries, or stalls past the
heartbeat timeout).  ``evict_server(si)`` is the single choke point — it
marks the server dead for routing, fails it (waking every suspended
client with ``ServerFailedError``), and displaces its streams: either
re-routed worst-fit onto survivors or handed back to the caller so
degraded-mode admission can place (or shed) them.
``enable_failure_detection`` wires a ``HeartbeatMonitor``: each server
thread beats between device calls, so a call outlasting the timeout is a
stall and the monitor thread evicts the server from outside.

Planned migration (work stealing / consolidation / elastic scale): the
"for its lifetime" pinning above has one sanctioned exception — a stream
may be MOVED between servers through a two-step protocol that keeps the
partitioned-analysis story intact:

  1. ``request_migration(stream, dst)`` records the intent (admission has
     already re-proven the stream on ``dst`` with its migration cost);
  2. the stream's own generating thread observes ``pending_migration`` at
     its next decode-step boundary, copies its live KV blocks across
     (``ServeEngine._execute_migration``), and calls
     ``complete_migration`` — the binding flips only after the blocks
     landed, so requests are never routed at a server that does not hold
     the stream's state.  ``cancel_migration`` abandons the intent (e.g.
     destination pool exhausted); the stream stays where it was.

The STEAL POLICY lives in ``ServeEngine.rebalance_once`` (piggybacked on
the heartbeat tick): pick the deepest and shallowest live queues by
active-stream count, stop when the gap is < 2, move the lowest-priority
stream of the deep server iff the cost model prices the migration copy
below the predicted queueing-delay saving — steal only when it pays.

Elastic membership: ``add_server()`` grows the pool mid-traffic;
``begin_drain(si)`` takes a server out of routing (existing streams keep
running until migrated away); ``retire_server(si)`` removes an empty
drained server.  Draining servers accept no new assignments and are never
a migration destination.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Hashable

from repro.core.dispatch.batching import BatchingServer, BatchRequest
from repro.core.server_runtime import AcceleratorServer, CellStats, Request

__all__ = ["ServerPool", "StreamAssignment"]


@dataclass
class StreamAssignment:
    server: int
    utilization: float
    priority: int


class ServerPool:
    """A fixed set of accelerator servers plus the stream router."""

    def __init__(self, num_servers: int, *, ordering: str = "priority",
                 batching: bool = False, max_batch: int = 8,
                 name: str = "pool"):
        if num_servers < 1:
            raise ValueError(f"num_servers must be >= 1, got {num_servers}")
        self.batching = batching
        self._name = name
        self._ordering = ordering
        self._max_batch = max_batch
        if batching:
            self.servers: list[AcceleratorServer] = [
                BatchingServer(ordering=ordering, max_batch=max_batch,
                               name=f"{name}-{i}")
                for i in range(num_servers)
            ]
        else:
            self.servers = [
                AcceleratorServer(ordering=ordering, name=f"{name}-{i}")
                for i in range(num_servers)
            ]
        self._assign_lock = threading.Lock()
        self._streams: dict[str, StreamAssignment] = {}
        self._alive = [True] * num_servers
        self._monitor = None  # HeartbeatMonitor when detection is enabled
        self._detection = None  # (timeout, poll, on_death) once enabled
        self._draining: set[int] = set()
        self._migrations: dict[str, int] = {}  # stream -> destination

    # -- routing (partitioned, priority-aware worst-fit) -------------------
    def _route(self, utilization: float, priority: int) -> int:
        def load(i: int) -> tuple[float, int, int]:
            util = sum(a.utilization for a in self._streams.values()
                       if a.server == i)
            hp = sum(1 for a in self._streams.values()
                     if a.server == i and a.priority >= priority)
            return (util, hp, i)

        candidates = [i for i in range(len(self.servers))
                      if self._alive[i] and i not in self._draining]
        if not candidates:
            raise RuntimeError("no surviving servers in the pool")
        return min(candidates, key=load)

    def assign(self, stream: str, *, utilization: float = 0.0,
               priority: int = 0, server: int | None = None) -> int:
        """Bind ``stream`` to a server for its lifetime; returns the index.
        ``server`` pins the choice (e.g. from the admission controller's
        device assignment); otherwise the router picks worst-fit."""
        with self._assign_lock:
            if stream in self._streams:
                raise ValueError(f"stream {stream!r} already assigned")
            if server is None:
                server = self._route(utilization, priority)
            elif not (0 <= server < len(self.servers)):
                raise ValueError(f"server {server} outside pool of "
                                 f"{len(self.servers)}")
            elif not self._alive[server]:
                raise ValueError(f"server {server} has failed")
            elif server in self._draining:
                raise ValueError(f"server {server} is draining")
            self._streams[stream] = StreamAssignment(server, utilization, priority)
            return server

    def remove(self, stream: str) -> None:
        with self._assign_lock:
            self._streams.pop(stream, None)
            self._migrations.pop(stream, None)

    def server_of(self, stream: str) -> int:
        return self._streams[stream].server

    def server_for(self, stream: str) -> AcceleratorServer:
        return self.servers[self._streams[stream].server]

    def streams_on(self, si: int) -> list[str]:
        with self._assign_lock:
            return [n for n, a in self._streams.items() if a.server == si]

    # -- planned migration (see module docstring: steal policy lives in the
    # engine; this is the intent/commit protocol the router honors) --------
    def request_migration(self, stream: str, dst: int) -> bool:
        """Record the intent to move ``stream`` to server ``dst``.  The
        stream's own generating thread performs the actual block copy at
        its next decode-step boundary and then calls
        ``complete_migration``.  Returns False (no-op) when the move is
        not currently legal: unknown stream, dead/draining destination, or
        the stream is already there."""
        with self._assign_lock:
            a = self._streams.get(stream)
            if (a is None or not (0 <= dst < len(self.servers))
                    or not self._alive[dst] or dst in self._draining
                    or a.server == dst):
                return False
            self._migrations[stream] = dst
            return True

    def pending_migration(self, stream: str) -> int | None:
        with self._assign_lock:
            return self._migrations.get(stream)

    def cancel_migration(self, stream: str) -> None:
        with self._assign_lock:
            self._migrations.pop(stream, None)

    def complete_migration(self, stream: str) -> None:
        """Flip the binding AFTER the blocks landed on the destination —
        from here on the router sends the stream's requests there."""
        with self._assign_lock:
            dst = self._migrations.pop(stream, None)
            a = self._streams.get(stream)
            if dst is not None and a is not None and self._alive[dst]:
                a.server = dst

    # -- elastic membership ------------------------------------------------
    def draining(self) -> set[int]:
        with self._assign_lock:
            return set(self._draining)

    def begin_drain(self, si: int) -> None:
        """Take server ``si`` out of routing: no new assignments, never a
        migration destination.  Existing streams keep running until moved
        away; ``retire_server`` completes the removal."""
        if not (0 <= si < len(self.servers)) or not self._alive[si]:
            raise ValueError(f"server {si} is not alive")
        with self._assign_lock:
            self._draining.add(si)

    def retire_server(self, si: int) -> None:
        """Remove an empty drained server from the pool: it must hold no
        stream bindings (migrate or remove them first).  The server thread
        drains its queue and joins; the slot stays in ``servers`` (dead)
        so indices of other servers never shift."""
        with self._assign_lock:
            left = [n for n, a in self._streams.items() if a.server == si]
            if left:
                raise RuntimeError(
                    f"server {si} still owns streams {left}; migrate or "
                    "remove them before retiring")
            if not self._alive[si]:
                return
            self._alive[si] = False
            self._draining.discard(si)
            self._migrations = {s: d for s, d in self._migrations.items()
                                if d != si}
        if self._monitor is not None:
            self._monitor.unregister(self.servers[si].name)
        self.servers[si].shutdown(drain=True)

    def add_server(self) -> int:
        """Grow the pool by one server mid-traffic; returns its index.  The
        new server is wired into the heartbeat monitor when detection is
        enabled, and immediately eligible for routing and as a migration
        destination."""
        with self._assign_lock:
            si = len(self.servers)
            if self.batching:
                server: AcceleratorServer = BatchingServer(
                    ordering=self._ordering, max_batch=self._max_batch,
                    name=f"{self._name}-{si}")
            else:
                server = AcceleratorServer(ordering=self._ordering,
                                           name=f"{self._name}-{si}")
            self.servers.append(server)
            self._alive.append(True)
        if self._monitor is not None:
            self._wire_server(si)
        return si

    # -- fault tolerance ---------------------------------------------------
    def alive_servers(self) -> list[int]:
        return [i for i in range(len(self.servers)) if self._alive[i]]

    def evict_server(self, si: int, *, cause: BaseException | None = None,
                     reroute: bool = True) -> dict[str, int | None] | None:
        """Declare server ``si`` dead and displace its streams.

        Idempotent and safe to call from any thread — the heartbeat monitor
        calls it on stall, the server's own thread on fatal device error,
        the engine's recovery path when a client wakes with
        ``ServerFailedError``; whichever races first wins and the rest see
        ``None`` (already evicted — nothing displaced by *this* call).  The
        server is failed (all its suspended clients wake), and every stream
        assigned to it is displaced in decreasing priority: with
        ``reroute=True`` each is re-bound worst-fit among survivors
        (returned as ``{stream: new_server}``); with ``reroute=False`` the
        bindings are dropped and returned as ``{stream: None}`` so the
        caller (degraded-mode admission) decides placement — or shedding —
        itself.
        """
        if not (0 <= si < len(self.servers)):
            raise ValueError(f"server {si} outside pool of {len(self.servers)}")
        with self._assign_lock:
            if not self._alive[si]:
                return None
            self._alive[si] = False
            self._draining.discard(si)
            displaced = sorted(
                (name for name, a in self._streams.items() if a.server == si),
                key=lambda n: -self._streams[n].priority)
            # pending migrations to or from the dead server are moot: the
            # destination is gone, or the stream is being displaced anyway
            self._migrations = {
                s: d for s, d in self._migrations.items()
                if d != si and s not in displaced}
            if not any(self._alive):
                reroute = False  # nowhere left to put them
            moved: dict[str, int | None] = {}
            for name in displaced:
                a = self._streams.pop(name)
                if reroute:
                    new = self._route(a.utilization, a.priority)
                    self._streams[name] = StreamAssignment(
                        new, a.utilization, a.priority)
                    moved[name] = new
                else:
                    moved[name] = None
        if self._monitor is not None:
            self._monitor.unregister(self.servers[si].name)
        self.servers[si].fail(cause)  # reentrant-safe: _alive already False
        return moved

    def reassign(self, stream: str, server: int, *, utilization: float = 0.0,
                 priority: int = 0) -> None:
        """Re-bind a (possibly displaced) stream to an explicit live server
        — the degraded-admission path after ``evict_server(reroute=False)``."""
        with self._assign_lock:
            if not (0 <= server < len(self.servers)) or not self._alive[server]:
                raise ValueError(f"server {server} is not alive")
            if server in self._draining:
                raise ValueError(f"server {server} is draining")
            self._streams[stream] = StreamAssignment(
                server, utilization, priority)

    def enable_failure_detection(
        self, *, timeout: float = 1.0, poll: float = 0.05,
        on_death: Callable[[int, dict], None] | None = None,
    ) -> "Any":
        """Wire a ``HeartbeatMonitor`` across the pool: every server thread
        beats between device calls (and each ``poll``-ish interval while
        idle), so a single device call outlasting ``timeout`` is a stall
        and the monitor thread evicts that server from outside — the
        per-device-call timeout.  Detection covers every death path: stall
        (monitor thread) and fatal device error / retry exhaustion (the
        server's own thread, via ``fail`` -> ``on_failure``).

        With ``on_death`` set, eviction uses ``reroute=False`` and
        ``on_death(si, displaced)`` receives the dropped bindings — the
        serving engine hangs degraded-mode admission here.  Whichever path
        evicts first is the only one that fires ``on_death``.  Returns the
        monitor (owned by the pool; ``shutdown`` closes it)."""
        from repro.runtime.fault_tolerance import HeartbeatMonitor

        self._detection = (timeout, poll, on_death)

        def _stalled(worker: str) -> None:
            si = next(i for i, s in enumerate(self.servers)
                      if s.name == worker)
            self._report_death(si, TimeoutError(
                f"no heartbeat from {worker!r} for {timeout}s"))

        monitor = HeartbeatMonitor(timeout=timeout, poll=poll,
                                   on_failure=_stalled)
        self._monitor = monitor
        for i in range(len(self.servers)):
            self._wire_server(i)
        return monitor

    def _report_death(self, si: int, cause: BaseException) -> None:
        on_death = self._detection[2] if self._detection else None
        displaced = self.evict_server(si, cause=cause,
                                      reroute=on_death is None)
        if displaced is not None and on_death is not None:
            on_death(si, displaced)

    def _wire_server(self, i: int) -> None:
        """Hook server ``i`` into the active HeartbeatMonitor — shared by
        ``enable_failure_detection`` (all servers) and ``add_server``
        (elastic join after detection is already on)."""
        _timeout, poll, _on_death = self._detection
        monitor, s = self._monitor, self.servers[i]
        monitor.register(s.name)
        s.beat = (lambda name=s.name: monitor.beat(name))
        s.beat_interval_s = min(s.beat_interval_s, max(poll, 1e-3))
        s.on_failure = (lambda server, si=i:
                        self._report_death(si, server.fail_cause))

    def attach_fault_injector(self, injector: "Any") -> None:
        """Install a ``runtime.faultinject.FaultInjector``'s per-server
        hooks into every server's device-call path."""
        injector.attach(self)

    # -- dispatch ----------------------------------------------------------
    def submit(self, stream: str, fn: Callable[[], Any], *, priority: int = 0,
               deadline: float | None = None, name: str = "") -> Request:
        return self.server_for(stream).submit(
            fn, priority=priority, deadline=deadline, name=name)

    def submit_batch(self, stream: str, payload: Any, *,
                     run_batch: Callable[[list[Any]], list[Any]],
                     batch_key: Hashable, priority: int = 0,
                     deadline: float | None = None,
                     name: str = "") -> BatchRequest:
        server = self.server_for(stream)
        if not isinstance(server, BatchingServer):
            raise TypeError("pool was built with batching=False")
        return server.submit_batch(payload, run_batch=run_batch,
                                   batch_key=batch_key, priority=priority,
                                   deadline=deadline, name=name)

    # -- measurement export ------------------------------------------------
    def cell_stats(self) -> dict:
        """Per-cell device-call aggregates merged across every server in the
        pool — one measurement table for the whole device fleet, in the
        shape ``analysis.cost_model.StepCostModel.ingest`` consumes.  The
        servers share jitted step functions (one engine), so same-cell calls
        on different devices price identically and pooling them is sound."""
        merged: dict = {}
        for s in self.servers:
            for key, cell in s.stats.cell_stats.items():
                if key in merged:
                    merged[key].merge(cell)
                else:
                    acc = CellStats()
                    acc.merge(cell)
                    merged[key] = acc
        return merged

    # -- lifecycle ---------------------------------------------------------
    def shutdown(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Close the pool.  The monitor goes down FIRST — servers stop
        beating the moment they are told to stop, and a monitor left
        running would race eviction callbacks into a half-torn-down pool.
        With ``drain=True`` every server then finishes its queued and
        in-flight work before joining; with ``drain=False`` pending
        requests are failed (clients wake) and only in-flight work runs
        out."""
        if self._monitor is not None:
            self._monitor.close()
            self._monitor = None
        for s in self.servers:
            s.shutdown(drain=drain, timeout=timeout)

    def __len__(self) -> int:
        return len(self.servers)

    def __enter__(self) -> "ServerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
