"""Multi-accelerator dispatch subsystem.

The paper's GPU server (§5.1) arbitrates ONE accelerator.  This package
grows that spine into a multi-server dispatch layer, the two pieces the
paper's §7 generalization note calls for:

  * :mod:`repro.core.dispatch.policy` — the queue-ordering policy
    (priority / FIFO / EDF keys) extracted out of ``AcceleratorServer`` so
    the executable runtime and the discrete-event simulator share one
    definition of "who goes first".
  * :mod:`repro.core.dispatch.pool` — ``ServerPool``: one
    ``AcceleratorServer`` per device / mesh slice, with a priority-aware
    router that *partitions* streams across servers (like the paper's
    per-core task partitioning, so each server's queue can be analyzed in
    isolation by ``server_analysis.analyze_pool``).  Partitions are
    SEMI-partitioned, not frozen: a two-phase migration protocol
    (``request_migration``/``complete_migration``) re-homes a live stream
    between decode steps — the engine's work stealer drains deep queues
    onto idle devices, ``consolidate()`` packs mostly-idle devices so
    they can retire, and ``add_server``/``retire_server`` grow and shrink
    the pool mid-traffic.  Each move is priced by the StepCostModel and
    re-proved by ``PoolAdmissionController``, and the analysis side
    charges it via ``server_analysis.analyze_pool_under_migrations``'s
    per-phase migration-delay term.
  * :mod:`repro.core.dispatch.batching` — ``BatchingServer``: coalesces
    same-shape requests (one ``batch_key``) from multiple admitted streams
    into one device call, amortizing the paper's 2*eps-per-request server
    overhead (Lemma 1) to 2*eps-per-batch.

Imports are lazy to keep ``policy`` importable from
``core.server_runtime`` without a cycle (pool/batching import the runtime).
"""

_EXPORTS = {
    "request_key": "repro.core.dispatch.policy",
    "ORDERINGS": "repro.core.dispatch.policy",
    "BatchRequest": "repro.core.dispatch.batching",
    "BatchingServer": "repro.core.dispatch.batching",
    "ServerPool": "repro.core.dispatch.pool",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
