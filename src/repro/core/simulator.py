"""Discrete-event simulator for partitioned fixed-priority multicore + one
or more non-preemptive accelerators, in the access-control modes the paper
evaluates (plus the batched extension):

  * ``server`` — the paper's GPU-server approach (§5.1): clients submit a
    request and suspend; the server (highest priority on its core) dequeues
    by task priority, pays eps CPU to dispatch, busy-waits only for the
    misc (G^m) portion, suspends during the pure-GPU (G^e) portion, pays eps
    CPU to notify.  Consecutive queued requests are separated by a single
    eps, matching Figure 4.
  * ``server_fifo`` — same server, FIFO-ordered queue (the paper's §7 /
    Fig. 15 future-work variant).
  * ``server_edf`` — beyond-paper: the server dequeues by earliest absolute
    job deadline (the ``dispatch.policy`` 'edf' ordering); analyzed by the
    order-agnostic job-driven bound (``server_analysis.analyze_edf_server``).
  * ``server_batched`` — beyond-paper: the server coalesces queued
    same-shape requests (identical (G^e, G^m)) into one accelerator call of
    up to ``batch_max`` requests: G^e and G^m are paid once per batch, the
    completion eps once per batch, and one receive eps drains all arrivals
    since the server last checked its mailbox — amortizing Lemma 1's 2*eps
    per request toward 2*eps per batch.  Batching only lets requests JOIN
    the head of the queue, never delays it, so the per-request (unbatched)
    analysis bound still dominates.
  * ``mpcp``  — synchronization-based, priority-ordered mutex queue; the
    whole GPU segment busy-waits on the client's CPU at the boosted global
    priority ceiling (§4).
  * ``fmlp``  — same, FIFO-ordered mutex queue (FMLP+).

Multi-accelerator systems (``System.server_cores`` with one core per
device) run one GPU server (or one mutex) per device; each task's
``device`` attribute routes its segments, matching the partitioned
``dispatch.ServerPool`` runtime.

The simulator executes exact protocol semantics and is the ground truth the
analyses are property-tested against (analysis bound >= simulated response
time).  Time is integer nanoseconds internally; the public API is float ms.

Job structure: a task's C is split into eta+1 equal normal chunks interleaved
with its GPU segments (an explicit per-task split can be supplied for case
studies).  Within a GPU segment, misc time is split half before / half after
the pure-GPU span, matching Figure 4's depiction.

The scenario engine (``repro.scenarios``) plugs in through two hooks, both
defaulting to the legacy behavior bit-for-bit:

  * ``releases`` — explicit per-task release instants (arrival models:
    sporadic slack, bursts, diurnal modulation, recorded traces) instead of
    the built-in strictly periodic release loop;
  * ``etm`` — per-job actual execution times (execution-time models: table,
    random, measured step costs) instead of every job running at its
    declared worst case.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace

from .dispatch.policy import request_key
from .faults import DeviceFault
from .migration import StreamMigration
from .task_model import GpuSegment, System, Task

__all__ = ["simulate", "SimResult", "TraceSlice"]

NS_PER_MS = 1_000_000
_BOOST = 10**9  # global priority ceiling offset (pi_B)
_SERVER_PRIO = 10**12


def _ns(ms: float) -> int:
    return int(round(ms * NS_PER_MS))


@dataclass(frozen=True)
class TraceSlice:
    core: int
    name: str  # task name or "__gpu_server__"
    start_ms: float
    end_ms: float
    kind: str  # "cpu" | "gcs" (busy-wait critical section) | "server"


@dataclass
class SimResult:
    response_times: dict[str, list[float]] = field(default_factory=dict)
    deadline_misses: dict[str, int] = field(default_factory=dict)
    trace: list[TraceSlice] = field(default_factory=list)

    def wcrt(self, name: str) -> float:
        rts = self.response_times.get(name, [])
        return max(rts) if rts else 0.0

    @property
    def any_miss(self) -> bool:
        return any(v > 0 for v in self.deadline_misses.values())


# --------------------------------------------------------------------------
# threads & cores
# --------------------------------------------------------------------------


class _Thread:
    """A schedulable entity on one core (a job in a CPU phase, or the server)."""

    __slots__ = ("name", "core", "base_prio", "prio", "remaining", "kind", "on_done")

    def __init__(self, name: str, core: int, prio: int):
        self.name = name
        self.core = core
        self.base_prio = prio
        self.prio = prio
        self.remaining = 0  # ns of the current CPU burst
        self.kind = "cpu"
        self.on_done = None  # callback when current burst finishes


class _Core:
    __slots__ = ("idx", "ready", "running", "run_start", "token")

    def __init__(self, idx: int):
        self.idx = idx
        self.ready: list[_Thread] = []
        self.running: _Thread | None = None
        self.run_start = 0
        self.token = 0


class _Engine:
    def __init__(self, num_cores: int, trace: bool):
        self.now = 0
        self.events: list[tuple[int, int, object]] = []  # (time, seq, fn)
        self.seq = 0
        self.cores = [_Core(i) for i in range(num_cores)]
        self.trace_on = trace
        self.trace: list[TraceSlice] = []

    def post(self, t: int, fn) -> None:
        self.seq += 1
        heapq.heappush(self.events, (t, self.seq, fn))

    # -- CPU scheduling ----------------------------------------------------
    def _record(self, core: _Core, upto: int) -> None:
        if self.trace_on and core.running is not None and upto > core.run_start:
            th = core.running
            self.trace.append(
                TraceSlice(core.idx, th.name, core.run_start / NS_PER_MS, upto / NS_PER_MS, th.kind)
            )

    def reschedule(self, core: _Core) -> None:
        top = max(core.ready, key=lambda th: th.prio, default=None)
        cur = core.running
        if cur is top:
            return
        if cur is not None:
            cur.remaining -= self.now - core.run_start
            self._record(core, self.now)
        core.running = top
        core.run_start = self.now
        core.token += 1
        if top is not None:
            tok = core.token
            self.post(self.now + top.remaining, lambda: self._burst_end(core, tok))

    def _burst_end(self, core: _Core, tok: int) -> None:
        if core.token != tok or core.running is None:
            return  # stale event (thread was preempted or finished earlier)
        th = core.running
        self._record(core, self.now)
        th.remaining = 0
        core.ready.remove(th)
        core.running = None
        core.token += 1
        cb = th.on_done
        th.on_done = None
        if cb is not None:
            cb()
        self.reschedule(core)

    def run_burst(self, th: _Thread, dur: int, kind: str, on_done) -> None:
        """Make ``th`` ready with a CPU burst of ``dur`` ns."""
        core = self.cores[th.core]
        th.kind = kind
        th.on_done = on_done
        if dur <= 0:
            # zero-length burst: complete immediately without scheduling
            self.post(self.now, on_done)
            return
        th.remaining = dur
        core.ready.append(th)
        self.reschedule(core)

    def set_prio(self, th: _Thread, prio: int) -> None:
        th.prio = prio
        core = self.cores[th.core]
        if th in core.ready or core.running is th:
            self.reschedule(core)

    def run(self, until: int) -> None:
        while self.events and self.events[0][0] <= until:
            t, _, fn = heapq.heappop(self.events)
            self.now = t
            fn()
        self.now = until
        for core in self.cores:
            if core.running is not None:
                core.running.remaining -= self.now - core.run_start
                self._record(core, self.now)


# --------------------------------------------------------------------------
# accelerator arbitration
# --------------------------------------------------------------------------


class _GpuServer:
    """The paper's GPU server (mode='server').

    CPU accounting (reconstructed from Lemma 1 + the Figure-4 timeline):
      * every submit costs eps of server CPU (receive/wake-up) — this is what
        delays tau_h by eps at time 3 in the example;
      * every completion costs eps (notify + dequeue-next), and a chained
        next segment starts right after that single eps (Lemma 3: "the GPU
        server needs to be invoked only once between two consecutive GPU
        requests");
      * the misc portion G^m of a segment is server-core CPU, split half
        before / half after the pure-GPU span (the example's "two
        sub-segments of miscellaneous operations");
      * so extra CPU per request = receive + notify = 2*eps (Lemma 1).

    All server CPU activities are serialized through a small work queue
    (the server is one thread); segment-progress work (m1/m2/notify) takes
    precedence over receive work so an in-flight segment is never stretched
    by unrelated arrivals.

    ``batch_max > 1`` enables batched dispatch (mode='server_batched'):
    when a segment starts, every queued request with the SAME (G^e, G^m)
    signature — the simulator's proxy for "same shape" — joins the batch
    (up to batch_max); the batch runs G^e/G^m once and pays one completion
    eps.  Receive work is also coalesced: a single eps drains all requests
    that arrived since the last mailbox check, so a steady batch of b pays
    ~2*eps instead of 2*b*eps of server CPU.
    """

    def __init__(self, eng: _Engine, core: int, eps: int, *,
                 ordering: str = "priority", batch_max: int = 1,
                 name: str = "__gpu_server__"):
        self.eng = eng
        self.eps = eps
        self.ordering = ordering  # dispatch.policy key: priority | fifo | edf
        self.batch_max = batch_max
        self.queue: list[tuple[float, int, object]] = []  # (key, seq, req)
        self.seq = 0
        self.gpu_busy = False
        self.notify_pending = False  # a completion eps not yet finished
        self.recv_pending = False  # a coalesced receive eps not yet finished
        self.thread = _Thread(name, core, _SERVER_PRIO)
        self.work: list[tuple[int, int, object]] = []  # (class, seq, (dur, then))
        self.cpu_busy = False
        self.dead = False  # device died (fault injection): nothing completes
        self.inflight: list | None = None  # requests inside the current call

    # -- fault injection ---------------------------------------------------
    def kill(self) -> None:
        """The device dies mid-work: the in-flight call never completes, the
        queue freezes, and every continuation below turns into a no-op.  The
        orphaned requests stay parked until ``drain_orphans`` (the detection
        instant) hands them to the failover target."""
        self.dead = True

    def drain_orphans(self) -> list:
        """All parked requests — in-flight first (they waited longest), then
        the frozen queue in policy order — as (prio, seg_e, seg_m, cb,
        deadline)."""
        orphans = list(self.inflight or [])
        self.inflight = None
        for item in sorted(self.queue):
            _, _, req = item
            orphans.append(req)
        self.queue = []
        return orphans

    # -- serialized server CPU --------------------------------------------
    def _cpu(self, dur: int, then, *, segment_work: bool) -> None:
        if self.dead:
            return
        self.seq += 1
        heapq.heappush(self.work, (0 if segment_work else 1, self.seq, (dur, then)))
        if not self.cpu_busy:
            self._next_work()

    def _next_work(self) -> None:
        if self.dead or not self.work:
            self.cpu_busy = False
            return
        self.cpu_busy = True
        _, _, (dur, then) = heapq.heappop(self.work)

        def done():
            if self.dead:
                self.cpu_busy = False
                return
            then()
            self._next_work()

        if dur <= 0:
            self.eng.post(self.eng.now, done)
        else:
            self.eng.run_burst(self.thread, dur, "server", done)

    # -- protocol -----------------------------------------------------------
    def submit(self, prio: int, seg_e: int, seg_m: int, on_complete,
               deadline: float | None = None) -> None:
        self.seq += 1
        key = request_key(self.ordering, priority=prio, deadline=deadline)
        heapq.heappush(self.queue,
                       (key, self.seq, (prio, seg_e, seg_m, on_complete,
                                        deadline)))
        if self.dead:
            return  # parked: recovered at the detection instant
        if self.batch_max > 1:
            # coalesced receive: one eps drains every arrival since the
            # server last checked its mailbox
            if self.recv_pending:
                return
            self.recv_pending = True

            def received():
                self.recv_pending = False
                self._maybe_start()

            self._cpu(self.eps, received, segment_work=False)
        else:
            # receive/wake-up: eps of server CPU per request (Lemma 1)
            self._cpu(self.eps, self._maybe_start, segment_work=False)

    def _pop_batch(self) -> tuple[int, int, list]:
        """Pop the head request plus every same-shape request (identical
        (G^e, G^m)) up to batch_max; returns (seg_e, seg_m, batch) with
        batch entries (prio, seg_e, seg_m, on_complete, deadline)."""
        _, _, head = heapq.heappop(self.queue)
        seg_e, seg_m = head[1], head[2]
        batch = [head]
        if self.batch_max > 1 and self.queue:
            keep = []
            for item in sorted(self.queue):  # queue-policy order
                req = item[2]
                if (len(batch) < self.batch_max and req[1] == seg_e
                        and req[2] == seg_m):
                    batch.append(req)
                else:
                    keep.append(item)
            self.queue = keep
            heapq.heapify(self.queue)
        return seg_e, seg_m, batch

    def _maybe_start(self) -> None:
        if self.dead or self.gpu_busy or self.notify_pending or not self.queue:
            return
        self.gpu_busy = True
        seg_e, seg_m, batch = self._pop_batch()
        self.inflight = batch
        callbacks = [req[3] for req in batch]
        m1 = seg_m // 2
        m2 = seg_m - m1

        def after_m1():
            if self.dead:
                return
            # pure-GPU span: server suspends (no CPU demand)
            self.eng.post(self.eng.now + seg_e, after_e)

        def after_e():
            if self.dead:
                return
            self._cpu(m2, after_m2, segment_work=True)

        def after_m2():
            if self.dead:
                return
            # completion: eps of server CPU (notify client(s) + dequeue next)
            self.gpu_busy = False
            self.notify_pending = True
            self._cpu(self.eps, complete, segment_work=True)

        def complete():
            if self.dead:
                return
            self.inflight = None
            self.notify_pending = False
            for cb in callbacks:
                cb()
            self._maybe_start()  # chained segment: single eps paid (Fig. 4)

        self._cpu(m1, after_m1, segment_work=True)


class _GpuLock:
    """Synchronization-based mutex (mode='mpcp' priority queue, 'fmlp' FIFO)."""

    def __init__(self, fifo: bool):
        self.fifo = fifo
        self.holder = None
        self.queue: list[tuple[int, int, object]] = []
        self.seq = 0

    def acquire(self, prio: int, grant) -> bool:
        """Returns True if granted immediately, else queues ``grant``."""
        if self.holder is None:
            self.holder = grant
            return True
        self.seq += 1
        key = self.seq if self.fifo else -prio
        heapq.heappush(self.queue, (key, self.seq, grant))
        return False

    def release(self) -> None:
        self.holder = None
        if self.queue:
            _, _, grant = heapq.heappop(self.queue)
            self.holder = grant
            grant()


# --------------------------------------------------------------------------
# jobs
# --------------------------------------------------------------------------


class _Job:
    def __init__(self, sim: "_Sim", task: Task, release: int, index: int = 0,
                 fold: GpuSegment | None = None):
        self.sim = sim
        self.task = task
        self.release = release
        # per-job actual costs: the execution-time model prices this job
        # (declared worst case when no model is plugged in)
        if sim.etm is None:
            C_ms, self.segs = task.C, task.segments
        else:
            C_ms, self.segs = sim.etm(task, index)
        # one-time migration cost: the first job released after a planned
        # migration carries the block-copy cost folded into its first GPU
        # segment (one request, no extra server invocation — weaker than
        # the analysis, which appends a standalone segment)
        if fold is not None and fold.total > 0:
            if self.segs:
                s0 = self.segs[0]
                self.segs = (GpuSegment(s0.e + fold.e, s0.m + fold.m),
                             *tuple(self.segs)[1:])
            else:
                self.segs = (fold,)
        eta = len(self.segs)
        # normal chunks: explicit split if provided, else eta+1 equal chunks
        split = sim.splits.get(task.name)
        if split is None:
            chunk = _ns(C_ms) // (eta + 1)
            last = _ns(C_ms) - chunk * eta
            self.chunks = [chunk] * eta + [last]
        else:
            self.chunks = [_ns(c) for c in split]
        self.deadline_ms = (release + _ns(task.D)) / NS_PER_MS
        self.phase = 0  # 0..eta: index of next normal chunk
        self.thread = _Thread(task.name, task.core, task.priority)

    def start(self) -> None:
        self._run_chunk()

    def _run_chunk(self) -> None:
        self.sim.eng.run_burst(self.thread, self.chunks[self.phase], "cpu", self._chunk_done)

    def _chunk_done(self) -> None:
        if self.phase < len(self.segs):
            seg = self.segs[self.phase]
            self.phase += 1
            self.sim.gpu_access(self, seg)
        else:
            self._finish()

    def gpu_done(self) -> None:
        self._run_chunk()

    def _finish(self) -> None:
        rt = (self.sim.eng.now - self.release) / NS_PER_MS
        self.sim.result.response_times.setdefault(self.task.name, []).append(rt)
        if rt > self.task.D + 1e-9:
            self.sim.result.deadline_misses[self.task.name] = (
                self.sim.result.deadline_misses.get(self.task.name, 0) + 1
            )


class _Sim:
    def __init__(
        self,
        system: System,
        mode: str,
        horizon_ms: float,
        trace: bool,
        splits: dict[str, list[float]] | None,
        offsets: dict[str, float] | None,
        batch_max: int = 1,
        faults: list[DeviceFault] | None = None,
        releases: dict[str, list[float]] | None = None,
        etm=None,
        migrations: list[StreamMigration] | None = None,
    ):
        self.system = system
        self.mode = mode
        self.eng = _Engine(system.num_cores, trace)
        self.result = SimResult()
        self.splits = splits or {}
        self.offsets = offsets or {}
        self.releases = releases
        self.etm = etm
        self.horizon = _ns(horizon_ms)
        self.faults = sorted(faults or [], key=lambda f: f.at_ms)
        server_modes = ("server", "server_fifo", "server_edf",
                        "server_batched")
        if self.faults and mode not in server_modes:
            raise ValueError("fault injection requires a server mode")
        self.device_map = list(range(max(system.num_gpus, 1)))
        for f in self.faults:
            if not (0 <= f.device < len(self.device_map)
                    and 0 <= f.to < len(self.device_map)):
                raise ValueError(f"fault device outside pool: {f}")
        self.migrations = sorted(migrations or [], key=lambda m: m.at_ms)
        if self.migrations and mode not in server_modes:
            raise ValueError("migration replay requires a server mode")
        names = {t.name for t in system.tasks}
        self._migs_by_task: dict[str, list[StreamMigration]] = {}
        for m in self.migrations:
            if m.task not in names:
                raise ValueError(f"migration names unknown task: {m}")
            if not 0 <= m.to < len(self.device_map):
                raise ValueError(f"migration device outside pool: {m}")
            if m.core >= system.num_cores:
                raise ValueError(f"migration core outside system: {m}")
            self._migs_by_task.setdefault(m.task, []).append(m)
        if mode in server_modes:
            cores = system.server_cores
            if not cores:
                raise ValueError("server mode needs system.server_core(s) set")
            ordering = {"server_fifo": "fifo", "server_edf": "edf"}.get(
                mode, "priority")
            bmax = batch_max if mode == "server_batched" else 1
            self.servers = [
                _GpuServer(self.eng, core, _ns(system.epsilon),
                           ordering=ordering, batch_max=bmax,
                           name=f"__gpu_server_{d}__" if len(cores) > 1
                           else "__gpu_server__")
                for d, core in enumerate(cores)
            ]
            self.mode = "server"
        elif mode in ("mpcp", "fmlp"):
            self.locks = [_GpuLock(fifo=(mode == "fmlp"))
                          for _ in range(system.num_gpus)]
        else:
            raise ValueError(mode)

    def _route(self, device: int) -> int:
        """Resolve failovers transitively (a double failure chains maps)."""
        d = device
        while self.device_map[d] != d:
            d = self.device_map[d]
        return d

    def _recover(self, f: DeviceFault) -> None:
        """Detection instant of fault ``f``: re-route the dead device's
        traffic and re-submit its orphaned requests to the failover target
        with the recovery (re-prefill) cost FOLDED into each segment — one
        re-issued request, not an extra one.  That is deliberately weaker
        than the analysis (which appends a whole extra segment, paying its
        own 2*eps server handling), keeping bound >= sim."""
        self.device_map[f.device] = f.to
        target = self.servers[self._route(f.to)]
        rec_e, rec_m = _ns(f.recovery.e), _ns(f.recovery.m)
        for prio, e, m, cb, deadline in self.servers[f.device].drain_orphans():
            target.submit(prio, e + rec_e, m + rec_m, cb, deadline)

    def gpu_access(self, job: _Job, seg) -> None:
        e_ns, m_ns = _ns(seg.e), _ns(seg.m)
        if self.mode == "server":
            # client suspends; its device's server handles the segment
            server = self.servers[self._route(job.task.device)]
            server.submit(job.task.priority, e_ns, m_ns, job.gpu_done,
                          job.deadline_ms)
        else:
            th = job.thread
            lock = self.locks[job.task.device]

            def granted():
                # boosted global ceiling; whole segment busy-waits on CPU
                self.eng.set_prio(th, _BOOST + th.base_prio)
                th.kind = "gcs"
                self.eng.run_burst(th, e_ns + m_ns, "gcs", release)

            def release():
                self.eng.set_prio(th, th.base_prio)
                lock.release()
                job.gpu_done()

            if lock.acquire(job.task.priority, granted):
                granted()

    def run(self) -> SimResult:
        for f in self.faults:
            self.eng.post(_ns(f.at_ms),
                          lambda f=f: self.servers[f.device].kill())
            self.eng.post(_ns(f.at_ms + f.detect_ms),
                          lambda f=f: self._recover(f))
        for task in self.system.tasks:
            rel_list = (self.releases.get(task.name)
                        if self.releases is not None else None)
            if rel_list is None:
                # legacy strictly periodic release loop (ns accumulation)
                off = _ns(self.offsets.get(task.name, 0.0))
                rel_ns = []
                t = off
                while t < self.horizon:
                    rel_ns.append(t)
                    t += _ns(task.T)
            else:
                rel_ns = [_ns(r) for r in rel_list if _ns(r) < self.horizon]
            migs = self._migs_by_task.get(task.name, [])
            charged = [False] * len(migs)
            for idx, rel in enumerate(rel_ns):
                # job-granularity placement: jobs released at/after a
                # migration run on its destination; each migration's cost
                # is folded ONCE into the first such job's first segment
                dev, core = task.device, task.core
                fold_e = fold_m = 0.0
                for j, m in enumerate(migs):
                    if _ns(m.at_ms) <= rel:
                        dev = m.to
                        if m.core >= 0:
                            core = m.core
                        if not charged[j]:
                            charged[j] = True
                            fold_e += m.cost.e
                            fold_m += m.cost.m
                eff = (task if (dev, core) == (task.device, task.core)
                       else replace(task, device=dev, core=core))
                fold = (GpuSegment(fold_e, fold_m)
                        if fold_e or fold_m else None)
                self.eng.post(
                    rel,
                    lambda task=eff, rel=rel, idx=idx, fold=fold:
                        _Job(self, task, rel, idx, fold=fold).start())
        self.eng.run(self.horizon)
        self.result.trace = self.eng.trace
        return self.result


def simulate(
    system: System,
    *,
    mode: str,
    horizon_ms: float,
    trace: bool = False,
    splits: dict[str, list[float]] | None = None,
    offsets: dict[str, float] | None = None,
    batch_max: int = 4,
    faults: list[DeviceFault] | None = None,
    releases: dict[str, list[float]] | None = None,
    etm=None,
    migrations: list[StreamMigration] | None = None,
) -> SimResult:
    """Simulate ``system`` for ``horizon_ms`` under ``mode`` in
    {'server','server_fifo','server_edf','server_batched','mpcp','fmlp'}.
    Jobs are released periodically (synchronous release at t=0 unless
    per-task ``offsets`` are given).  ``splits`` may supply an explicit
    normal-chunk split (list of ms, length eta+1) per task name.
    ``batch_max`` caps the coalesced batch size in 'server_batched' mode
    (ignored otherwise).  Multi-accelerator systems (``System.server_cores``)
    run one server (or mutex) per device, routed by each task's ``device``.

    ``faults`` (server modes only) injects ``core.faults.DeviceFault``
    device deaths: at ``at_ms`` the device stops mid-work; at
    ``at_ms + detect_ms`` its orphaned requests re-submit to device ``to``
    with the recovery cost folded in, and its tasks re-route there for the
    rest of the run.  ``server_analysis.analyze_pool_under_faults`` prices
    the same schedule analytically; bound >= sim is property-tested.

    ``migrations`` (server modes only) replays a planned
    ``core.migration.StreamMigration`` schedule: every job of the named
    task released at or after ``at_ms`` runs on device ``to`` / core
    ``core``, and the one-time migration ``cost`` is folded into the first
    such job's first GPU segment.  Jobs in flight at the boundary keep the
    old placement — deliberately weaker than
    ``server_analysis.analyze_pool_under_migrations`` (which appends the
    cost segment to every later phase), keeping bound >= sim.

    Scenario-engine hooks (``repro.scenarios`` wires both; each defaults to
    the legacy behavior exactly):

    * ``releases`` maps task name -> sorted absolute release instants (ms);
      tasks absent from the mapping release periodically.  Generators must
      respect each task's minimum inter-arrival time T for the analyses to
      stay sound.
    * ``etm(task, job_index) -> (C_ms, segments)`` prices each job's actual
      execution; costs must stay within the declared worst case, with the
      declared segment count."""
    return _Sim(system, mode, horizon_ms, trace, splits, offsets,
                batch_max=batch_max, faults=faults, releases=releases,
                etm=etm, migrations=migrations).run()
