"""Pure-jnp oracles for every Pallas kernel in this package.

These are THE reference semantics: kernel tests sweep shapes/dtypes and
assert allclose against these functions (interpret=True on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q (B,Nq,S,H); k/v (B,Nkv,S,H) -> (B,Nq,S,H).  Grouped (GQA) heads."""
    b, nq, s, h = q.shape
    nkv = k.shape[1]
    g = nq // nkv
    scale = scale if scale is not None else h ** -0.5
    qg = q.reshape(b, nkv, g, s, h)
    logits = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        idx = jnp.arange(s)
        mask = idx[None, :] <= idx[:, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v.astype(jnp.float32))
    return out.reshape(b, nq, s, h).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale: float | None = None):
    """q (B,Nq,H); k/v (B,Nkv,S,H); lengths (B,) -> (B,Nq,H).

    Attends to positions < lengths[b] (a KV cache of logical length
    lengths[b] inside a max_seq buffer)."""
    b, nq, h = q.shape
    nkv, s = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else h ** -0.5
    qg = q.reshape(b, nkv, g, h)
    logits = jnp.einsum("bkgh,bkth->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] < lengths[:, None]  # (B,S)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,bkth->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, nq, h).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               scale: float | None = None):
    """q (B,Nq,H); k/v pools (NB,BS,Nkv,H); block_tables (B,W) int32;
    lengths (B,) -> (B,Nq,H).

    Gathers each row's blocks into a logically contiguous (W*BS) cache view
    and defers to :func:`decode_attention_ref` — the paged kernel must be
    exactly 'dense decode attention over the gathered view'."""
    bs = k_pool.shape[1]
    b, w = block_tables.shape

    def gather(pool):
        # (B, W, BS, Nkv, H) -> (B, Nkv, W*BS, H)
        seq = pool[block_tables].reshape(b, w * bs, *pool.shape[2:])
        return jnp.swapaxes(seq, 1, 2)

    return decode_attention_ref(q, gather(k_pool), gather(v_pool), lengths,
                                scale=scale)


def paged_mla_decode_attention_ref(q_lat, q_rope, ckv_pool, krope_pool,
                                   block_tables, lengths, *,
                                   scale: float | None = None):
    """q_lat (B,Nq,R); q_rope (B,Nq,PR); pools ckv (NB,BS,R) /
    k_rope (NB,BS,PR); block_tables (B,W); lengths (B,) -> o_lat (B,Nq,R).

    Absorbed MLA over the gathered latent view: key = concat(c_kv, k_rope),
    value = c_kv itself."""
    f32 = jnp.float32
    bs = ckv_pool.shape[1]
    b, w = block_tables.shape
    r, pr = q_lat.shape[-1], q_rope.shape[-1]
    scale = scale if scale is not None else (r + pr) ** -0.5
    ckv = ckv_pool[block_tables].reshape(b, w * bs, r).astype(f32)
    krope = krope_pool[block_tables].reshape(b, w * bs, pr).astype(f32)
    logits = (jnp.einsum("bnr,btr->bnt", q_lat.astype(f32), ckv)
              + jnp.einsum("bnp,btp->bnt", q_rope.astype(f32), krope)) * scale
    valid = jnp.arange(w * bs)[None, :] < lengths[:, None]  # (B, W*BS)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bnt,btr->bnr", probs, ckv).astype(q_lat.dtype)


def ssd_slab_decode_ref(state_pool, slab_ids, x, dt, A, B, C):
    """state_pool (NS,H,P,N) fp32; slab_ids (B,); x (B,H,P); dt (B,H);
    A (H,); B/C (B,G,N) -> (y (B,H,P), states (B,H,P,N) fp32).

    One SSD recurrent step over each row's gathered slab (same math as
    models.ssm.ssd_decode_step, state addressed through the pool)."""
    f32 = jnp.float32
    h = x.shape[1]
    hg = h // B.shape[1]
    state = state_pool[slab_ids].astype(f32)
    dtf = dt.astype(f32)
    dec = jnp.exp(dtf * A)  # (B,H)
    Bh = jnp.repeat(B, hg, axis=1).astype(f32)  # (B,H,N)
    Ch = jnp.repeat(C, hg, axis=1).astype(f32)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtf, x.astype(f32), Bh)
    state = dec[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x.dtype), state


def ssd_intra_ref(x, dt, dA, B, C):
    """Intra-chunk SSD + chunk-state summary (one chunk per leading index).

    x  (M, H, Q, P)   inputs (M = batch*num_chunks)
    dt (M, H, Q)      positive step sizes
    dA (M, H, Q)      dt * A  (negative)
    B  (M, Q, N)      input projection (shared across heads; G=1)
    C  (M, Q, N)      output projection
    returns y (M, H, Q, P) = intra-chunk output,
            s (M, H, N, P) = end-of-chunk state contribution
    """
    f32 = jnp.float32
    seg = jnp.cumsum(dA.astype(f32), axis=-1)  # (M,H,Q)
    q = x.shape[2]
    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]
    L = jnp.exp(jnp.where(causal[None, None], seg[..., :, None] - seg[..., None, :],
                          -1e30))
    cb = jnp.einsum("min,mjn->mij", C.astype(f32), B.astype(f32))  # (M,Q,Q)
    w = cb[:, None] * L  # (M,H,Q,Q)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]
    y = jnp.einsum("mhij,mhjp->mhip", w, xdt)
    dte = jnp.exp(seg[..., -1:] - seg) * dt.astype(f32)  # (M,H,Q)
    s = jnp.einsum("mhq,mqn,mhqp->mhnp", dte, B.astype(f32), x.astype(f32))
    return y.astype(x.dtype), s.astype(f32)
