"""Pure-jnp oracles for every Pallas kernel in this package.

These are THE reference semantics: kernel tests sweep shapes/dtypes and
assert allclose against these functions (interpret=True on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q (B,Nq,S,H); k/v (B,Nkv,S,H) -> (B,Nq,S,H).  Grouped (GQA) heads."""
    b, nq, s, h = q.shape
    nkv = k.shape[1]
    g = nq // nkv
    scale = scale if scale is not None else h ** -0.5
    qg = q.reshape(b, nkv, g, s, h)
    logits = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        idx = jnp.arange(s)
        mask = idx[None, :] <= idx[:, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v.astype(jnp.float32))
    return out.reshape(b, nq, s, h).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale: float | None = None):
    """q (B,Nq,H); k/v (B,Nkv,S,H); lengths (B,) -> (B,Nq,H).

    Attends to positions < lengths[b] (a KV cache of logical length
    lengths[b] inside a max_seq buffer)."""
    b, nq, h = q.shape
    nkv, s = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else h ** -0.5
    qg = q.reshape(b, nkv, g, h)
    logits = jnp.einsum("bkgh,bkth->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] < lengths[:, None]  # (B,S)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,bkth->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, nq, h).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               scale: float | None = None):
    """q (B,Nq,H); k/v pools (NB,BS,Nkv,H); block_tables (B,W) int32;
    lengths (B,) -> (B,Nq,H).

    Gathers each row's blocks into a logically contiguous (W*BS) cache view
    and defers to :func:`decode_attention_ref` — the paged kernel must be
    exactly 'dense decode attention over the gathered view'."""
    bs = k_pool.shape[1]
    b, w = block_tables.shape

    def gather(pool):
        # (B, W, BS, Nkv, H) -> (B, Nkv, W*BS, H)
        seq = pool[block_tables].reshape(b, w * bs, *pool.shape[2:])
        return jnp.swapaxes(seq, 1, 2)

    return decode_attention_ref(q, gather(k_pool), gather(v_pool), lengths,
                                scale=scale)


def ssd_intra_ref(x, dt, dA, B, C):
    """Intra-chunk SSD + chunk-state summary (one chunk per leading index).

    x  (M, H, Q, P)   inputs (M = batch*num_chunks)
    dt (M, H, Q)      positive step sizes
    dA (M, H, Q)      dt * A  (negative)
    B  (M, Q, N)      input projection (shared across heads; G=1)
    C  (M, Q, N)      output projection
    returns y (M, H, Q, P) = intra-chunk output,
            s (M, H, N, P) = end-of-chunk state contribution
    """
    f32 = jnp.float32
    seg = jnp.cumsum(dA.astype(f32), axis=-1)  # (M,H,Q)
    q = x.shape[2]
    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]
    L = jnp.exp(jnp.where(causal[None, None], seg[..., :, None] - seg[..., None, :],
                          -1e30))
    cb = jnp.einsum("min,mjn->mij", C.astype(f32), B.astype(f32))  # (M,Q,Q)
    w = cb[:, None] * L  # (M,H,Q,Q)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]
    y = jnp.einsum("mhij,mhjp->mhip", w, xdt)
    dte = jnp.exp(seg[..., -1:] - seg) * dt.astype(f32)  # (M,H,Q)
    s = jnp.einsum("mhq,mqn,mhqp->mhnp", dte, B.astype(f32), x.astype(f32))
    return y.astype(x.dtype), s.astype(f32)
