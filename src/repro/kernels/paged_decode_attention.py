"""Pallas TPU paged decode attention: one query token against a BLOCK-POOL
KV cache addressed through a block table (vLLM-style paging, TPU-shaped).

The cache is a dense pool k/v (num_blocks, block_size, Nkv, H); each batch
row owns an ordered list of pool blocks given by ``block_tables`` (B, W)
int32, and ``lengths`` (B,) gives the logical token count.  Block j of row b
holds cache positions [j*block_size, (j+1)*block_size).

Grid: (B, Nq, W), the block-table dimension sequential.  The block table and
lengths ride as scalar-prefetch operands (``PrefetchScalarGridSpec``): the
index map reads ``tables[b, j]`` to DMA exactly the tile the row needs —
the gather IS the addressing, no materialized contiguous copy.  Tiles wholly
past ``lengths[b]`` are skipped, so the sweep cost tracks each row's true
cache length (the server's central knowledge of per-stream lengths, pushed
down into the device loop).

The online-softmax recurrence is shared with the masked-dense kernel
(``decode_attention.online_softmax_*``) — the two paths differ only in tile
addressing, so they stay numerically interchangeable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params
from repro.kernels.decode_attention import (online_softmax_block,
                                            online_softmax_finalize,
                                            online_softmax_init)


def _paged_kernel(tables_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, bs: int):
    j = pl.program_id(2)
    nb = pl.num_programs(2)
    length = len_ref[pl.program_id(0)]

    @pl.when(j == 0)
    def _init():
        online_softmax_init(m_ref, l_ref, acc_ref)

    @pl.when(j * bs < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)     # (1, H)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, H): one pool block
        v = v_ref[0, :, 0].astype(jnp.float32)
        cols = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        online_softmax_block(q, k, v, cols, length, scale, m_ref, l_ref,
                             acc_ref)

    @pl.when(j == nb - 1)
    def _finalize():
        o_ref[0, 0] = online_softmax_finalize(l_ref, acc_ref).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """q (B,Nq,H); k/v pools (NB,BS,Nkv,H); block_tables (B,W) int32;
    lengths (B,) -> (B,Nq,H).

    ``W * BS`` must cover ``max(lengths)``; table entries past a row's live
    blocks may point anywhere (their tiles are skipped or fully masked).
    """
    b, nq, h = q.shape
    bs, nkv = k_pool.shape[1], k_pool.shape[2]
    g = nq // nkv
    w = block_tables.shape[1]
    scale = scale if scale is not None else h ** -0.5

    kernel = functools.partial(_paged_kernel, scale=scale, bs=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=(b, nq, w),
        in_specs=[
            pl.BlockSpec((1, 1, 1, h), lambda b_, n, j, t, l: (b_, n, 0, 0)),
            pl.BlockSpec((1, bs, 1, h),
                         lambda b_, n, j, t, l: (t[b_, j], 0, n // g, 0)),
            pl.BlockSpec((1, bs, 1, h),
                         lambda b_, n, j, t, l: (t[b_, j], 0, n // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, h),
                               lambda b_, n, j, t, l: (b_, n, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, h), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nq, 1, h), q.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q[:, :, None, :], k_pool, v_pool)
    return out[:, :, 0, :]


# --------------------------------------------------------------------------
# MLA variant: absorbed decode over latent block pools
# --------------------------------------------------------------------------


def _paged_mla_kernel(tables_ref, len_ref, q_ref, ckv_ref, krope_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, scale: float, bs: int,
                      r: int):
    j = pl.program_id(2)
    nb = pl.num_programs(2)
    length = len_ref[pl.program_id(0)]

    @pl.when(j == 0)
    def _init():
        online_softmax_init(m_ref, l_ref, acc_ref)

    @pl.when(j * bs < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)        # (1, R+PR) absorbed query
        ckv = ckv_ref[0].astype(jnp.float32)       # (bs, R): one pool block
        krope = krope_ref[0].astype(jnp.float32)   # (bs, PR)
        # MLA's key IS (latent ‖ rope-key) and its value IS the latent:
        # the shared online-softmax core handles k/v of different widths
        # (acc is sized by v), so the only MLA-specific work is the concat
        k = jnp.concatenate([ckv, krope], axis=-1)  # (bs, R+PR)
        cols = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        online_softmax_block(q, k, ckv, cols, length, scale, m_ref, l_ref,
                             acc_ref)

    @pl.when(j == nb - 1)
    def _finalize():
        o_ref[0, 0] = online_softmax_finalize(l_ref, acc_ref).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_mla_decode_attention(q_lat, q_rope, ckv_pool, krope_pool,
                               block_tables, lengths, *,
                               scale: float | None = None,
                               interpret: bool = False):
    """Absorbed-MLA paged decode: q_lat (B,Nq,R) latent-projected queries,
    q_rope (B,Nq,PR); pools ckv (NB,BS,R), k_rope (NB,BS,PR);
    block_tables (B,W) int32; lengths (B,) -> o_lat (B,Nq,R).

    Per position the key is concat(c_kv, k_rope) and the VALUE is c_kv
    itself, so the kernel is the GQA paged sweep with a different tile
    addressing — the caller applies w_uv to the returned latent output.
    ``scale`` should be 1/sqrt(qk_nope + qk_rope); NOTE the pools carry no
    head axis (the latent is shared by every head — MLA's memory win), so
    each of the Nq sweeps re-reads the same blocks.
    """
    b, nq, r = q_lat.shape
    pr = q_rope.shape[-1]
    bs = ckv_pool.shape[1]
    w = block_tables.shape[1]
    scale = scale if scale is not None else (r + pr) ** -0.5
    q = jnp.concatenate([q_lat, q_rope], axis=-1)[:, :, None, :]

    kernel = functools.partial(_paged_mla_kernel, scale=scale, bs=bs, r=r)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=(b, nq, w),
        in_specs=[
            pl.BlockSpec((1, 1, 1, r + pr),
                         lambda b_, n, j, t, l: (b_, n, 0, 0)),
            pl.BlockSpec((1, bs, r), lambda b_, n, j, t, l: (t[b_, j], 0, 0)),
            pl.BlockSpec((1, bs, pr), lambda b_, n, j, t, l: (t[b_, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, r),
                               lambda b_, n, j, t, l: (b_, n, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, r), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nq, 1, r), q_lat.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, q, ckv_pool, krope_pool)
    return out[:, :, 0, :]
