"""Pallas TPU decode attention: one query token against a KV cache.

q (B, Nq, H); k/v caches (B, Nkv, Smax, H); lengths (B,) gives the logical
cache length per sequence (positions >= lengths[b] are masked).

Grid: (B, Nq, Smax/bk), KV dimension sequential, online softmax in VMEM
scratch (same recurrence as the prefill kernel, with a single query row).
Blocks wholly beyond lengths[b] are skipped — for ragged batches the sweep
cost tracks the true cache length, not the buffer size.

The query row is tiny (1, H); we keep it in VMEM and rely on the (bk, H)
cache tile reads being the bandwidth term — decode attention is memory-bound
and the point of the kernel is to stream the cache exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


# -- online-softmax core ----------------------------------------------------
# Shared by the masked-dense kernel below and the paged kernel in
# paged_decode_attention.py: both sweep KV one (bk, H) tile at a time and
# differ only in how the tile is addressed (contiguous slab vs block-table
# indirection).  The recurrence state lives in VMEM scratch:
#   m (1,)  running max,  l (1,)  running denominator,  acc (1, H) numerator.


def online_softmax_init(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def online_softmax_block(q, k, v, cols, length, scale, m_ref, l_ref, acc_ref):
    """Fold one KV tile into the recurrence.  q (1,H); k/v (bk,H) fp32;
    ``cols`` (1,bk) are the tile's global cache positions — positions >=
    ``length`` are masked, so callers only need tile-granular early exit."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(cols < length, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def online_softmax_finalize(l_ref, acc_ref):
    denom = jnp.maximum(l_ref[...], 1e-30)
    return acc_ref[...] / denom[:, None]


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, bk: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)
    length = len_ref[pl.program_id(0)]

    @pl.when(j == 0)
    def _init():
        online_softmax_init(m_ref, l_ref, acc_ref)

    @pl.when(j * bk < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, H)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, H)
        v = v_ref[0, 0].astype(jnp.float32)
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        online_softmax_block(q, k, v, cols, length, scale, m_ref, l_ref,
                             acc_ref)

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = online_softmax_finalize(l_ref, acc_ref).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = False):
    """q (B,Nq,H); k/v (B,Nkv,Smax,H); lengths (B,) -> (B,Nq,H)."""
    b, nq, h = q.shape
    nkv, smax = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else h ** -0.5
    bk = min(block_k, smax)
    assert smax % bk == 0, (smax, bk)

    grid = (b, nq, smax // bk)
    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths, scalar-prefetch style
            pl.BlockSpec((1, 1, 1, h), lambda b_, n, j: (b_, n, 0, 0)),
            pl.BlockSpec((1, 1, bk, h), lambda b_, n, j: (b_, n // g, j, 0)),
            pl.BlockSpec((1, 1, bk, h), lambda b_, n, j: (b_, n // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, h), lambda b_, n, j: (b_, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, 1, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, h), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q[:, :, None, :], k, v)
    return out[:, :, 0, :]
