"""Jitted public wrappers over the Pallas kernels with automatic fallback.

On TPU backends the Pallas kernels run natively; elsewhere (CPU container,
tests) ``interpret=True`` executes the kernel body in Python for
correctness, and callers that want speed on CPU use the jnp references
directly (the model code defaults to the XLA path; kernels are opt-in via
TrainSettings.use_pallas_kernels).
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_intra as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    force_pallas: bool = False, interpret: bool | None = None):
    """q (B,Nq,S,H); k/v (B,Nkv,S,H) -> (B,Nq,S,H)."""
    if _on_tpu() or force_pallas:
        itp = interpret if interpret is not None else not _on_tpu()
        block = 256 if q.shape[2] % 256 == 0 else q.shape[2]
        return _flash_pallas(q, k, v, causal=causal, scale=scale,
                             block_q=block, block_k=block, interpret=itp)
    return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


def decode_attention(q, k, v, lengths, *, scale: float | None = None,
                     force_pallas: bool = False, interpret: bool | None = None):
    """q (B,Nq,H); k/v (B,Nkv,Smax,H); lengths (B,) -> (B,Nq,H)."""
    if _on_tpu() or force_pallas:
        itp = interpret if interpret is not None else not _on_tpu()
        block = 512 if k.shape[2] % 512 == 0 else k.shape[2]
        return _decode_pallas(q, k, v, lengths, scale=scale, block_k=block,
                              interpret=itp)
    return ref.decode_attention_ref(q, k, v, lengths, scale=scale)


def ssd_intra(x, dt, dA, B, C, *, force_pallas: bool = False,
              interpret: bool | None = None):
    """x (M,H,Q,P); dt/dA (M,H,Q); B/C (M,Q,N) -> (y, s)."""
    if _on_tpu() or force_pallas:
        itp = interpret if interpret is not None else not _on_tpu()
        return _ssd_pallas(x, dt, dA, B, C, interpret=itp)
    return ref.ssd_intra_ref(x, dt, dA, B, C)
