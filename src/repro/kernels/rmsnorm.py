"""Pallas TPU fused RMSNorm.

Why a kernel: the XLA path (models/layers.rms_norm) upcasts the (B,S,D)
activation to f32, reduces, rescales, and casts back — on the dry-run
profile this f32 round-trip of the residual stream is a top-5 HBM
contributor on every train cell (EXPERIMENTS.md §Perf diagnosis).  The
fused kernel reads the bf16 row once, keeps the f32 math in VMEM, writes
the bf16 row once: 2 x D bytes per row instead of ~6 x.

Grid: one program per row block (rows = flattened batch*seq).  D stays
whole per block (d_model <= 16k -> a (block_rows, D) bf16 tile plus f32
scratch fits VMEM comfortably: 256 x 16384 x 2B = 8 MiB at the largest).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x (..., D); w (D,) -> same shape/dtype as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    br = max(br, 1)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
