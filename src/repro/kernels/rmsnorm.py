"""Pallas TPU fused RMSNorm.

Why a kernel: the XLA path (models/layers.rms_norm) upcasts the (B,S,D)
activation to f32, reduces, rescales, and casts back — on the dry-run
profile this f32 round-trip of the residual stream is a top-5 HBM
contributor on every train cell (EXPERIMENTS.md §Perf diagnosis).  The
fused kernel reads the bf16 row once, keeps the f32 math in VMEM, writes
the bf16 row once: 2 x D bytes per row instead of ~6 x.

Grid: one program per row block (rows = flattened batch*seq).  D stays
whole per block (d_model <= 16k -> a (block_rows, D) bf16 tile plus f32
scratch fits VMEM comfortably: 256 x 16384 x 2B = 8 MiB at the largest).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tpu_compiler_params


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x (..., D); w (D,) -> same shape/dtype as x.

    Row counts that are not a multiple of ``block_rows`` are zero-padded up
    to the next block boundary (each row normalizes independently, so the
    pad rows are dead work, discarded on the way out) — keeping the block
    size large instead of shrinking it to a divisor of the row count.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        x2 = jnp.pad(x2, ((0, rows_p - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows_p // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        compiler_params=tpu_compiler_params(("parallel",)),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
