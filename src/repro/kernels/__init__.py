# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(dimension_semantics):
    """CompilerParams across the pallas-TPU rename: jax 0.4.x calls it
    TPUCompilerParams, newer releases CompilerParams."""
    cls = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams
    return cls(dimension_semantics=tuple(dimension_semantics))
