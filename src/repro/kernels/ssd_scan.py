"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

The chunked SSD algorithm (models/ssm.py) splits the sequence into chunks;
the O(Q^2) intra-chunk part and the (N x P) chunk-state summary are the
compute hot-spot and live here.  The O(num_chunks) inter-chunk recurrence is
tiny and stays in jnp (lax.scan).

Per grid step (m = batch*chunk index, h = head):
    seg   = cumsum(dA_h)                      (Q,)
    L     = exp(seg_i - seg_j) . causal       (Q, Q)
    w     = (C B^T) * L                       (Q, Q)   <- MXU matmul
    y     = w (x * dt)                        (Q, P)   <- MXU matmul
    s_c   = B^T diag(exp(seg_Q - seg) dt) x   (N, P)   <- MXU matmul
B and C are shared across heads (ngroups=1), so their tiles are fetched
once per (m, *) sweep and reused across the head dimension, which is the
innermost ("arbitrary") grid axis.

VMEM per step (Q=256, N=128, P=64, fp32): L+w 2*256KiB, B/C 2*128KiB,
x 64KiB, outputs <96KiB -> ~1MiB, comfortably inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, s_ref):
    f32 = jnp.float32
    q = x_ref.shape[2]
    x = x_ref[0, 0].astype(f32)      # (Q, P)
    dt = dt_ref[0, 0].astype(f32)    # (Q,)
    da = da_ref[0, 0].astype(f32)    # (Q,)
    bb = b_ref[0].astype(f32)        # (Q, N)
    cc = c_ref[0].astype(f32)        # (Q, N)

    seg = jnp.cumsum(da)             # (Q,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    diff = seg[:, None] - seg[None, :]
    L = jnp.exp(jnp.where(rows >= cols, diff, NEG_INF))

    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)  # (Q, Q)
    w = cb * L
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)   # (Q, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    dte = jnp.exp(seg[-1] - seg) * dt                     # (Q,)
    s = jax.lax.dot_general(bb * dte[:, None], x, (((0,), (0,)), ((), ())),
                            preferred_element_type=f32)   # (N, P)
    s_ref[0, 0] = s


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra(x, dt, dA, B, C, *, interpret: bool = False):
    """x (M,H,Q,P); dt/dA (M,H,Q); B/C (M,Q,N) ->
    y (M,H,Q,P), s (M,H,N,P) fp32."""
    m, h, q, p = x.shape
    n = B.shape[-1]
    grid = (m, h)
    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, h, q, p), x.dtype),
            jax.ShapeDtypeStruct((m, h, n, p), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, dA, B, C)
    return y, s


# --------------------------------------------------------------------------
# slab-indexed decode step: per-row SSM state gathered from a SLAB POOL
# --------------------------------------------------------------------------


def _slab_decode_kernel(slab_ref, x_ref, dt_ref, a_ref, b_ref, c_ref,
                        st_ref, y_ref, out_ref):
    f32 = jnp.float32
    st = st_ref[0].astype(f32)    # (H, P, N): this row's slab
    x = x_ref[0].astype(f32)      # (H, P)
    dt = dt_ref[0].astype(f32)    # (H,)
    a = a_ref[...].astype(f32)    # (H,)
    bb = b_ref[0].astype(f32)     # (H, N) head-expanded
    cc = c_ref[0].astype(f32)     # (H, N)

    dec = jnp.exp(dt * a)
    st = dec[:, None, None] * st + (dt[:, None] * x)[..., None] * bb[:, None, :]
    out_ref[0] = st
    y_ref[0] = jnp.sum(st * cc[:, None, :], axis=-1).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_slab_decode(state_pool, slab_ids, x, dt, A, B, C, *,
                    interpret: bool = False):
    """One recurrent SSD step with per-row state addressed THROUGH a slab
    pool: state_pool (NS,H,P,N) fp32, slab_ids (B,) int32, x (B,H,P),
    dt (B,H), A (H,), B/C (B,G,N) -> (y (B,H,P), states (B,H,P,N) fp32).

    ``slab_ids`` rides as a scalar-prefetch operand and the state's index
    map reads ``s[i]`` — the slab gather IS the addressing, mirroring how
    paged_decode_attention addresses KV blocks.  The updated per-row states
    come back gathered; the caller scatters them with
    ``state_pool.at[slab_ids].set(states)`` (slabs are unshared, so the
    scatter cannot race between live rows)."""
    bsz, h, p = x.shape
    n = B.shape[-1]
    hg = h // B.shape[1]
    Bh = jnp.repeat(B, hg, axis=1)  # (B,H,N)
    Ch = jnp.repeat(C, hg, axis=1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # slab_ids
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, h, p), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i, s: (i, 0)),
            pl.BlockSpec((h,), lambda i, s: (0,)),
            pl.BlockSpec((1, h, n), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, h, n), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i, s: (s[i], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, p), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i, s: (i, 0, 0, 0)),
        ],
    )
    y, states = pl.pallas_call(
        _slab_decode_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(slab_ids, x, dt, A, Bh, Ch, state_pool)
    return y, states
