"""Pallas TPU flash attention (prefill, causal, GQA).

Layout: q (B, Nq, S, H); k/v (B, Nkv, S, H) — heads-major so the (S, H)
tile is contiguous and MXU-aligned (H and the block sizes are multiples of
128 at production scale; the wrapper pads smaller test shapes).

Grid: (B, Nq, S/bq, S/bk) with the last (KV) dimension sequential
("arbitrary") — the online-softmax running max/denominator/accumulator live
in VMEM scratch across the KV sweep and the output block is written once on
the final visited KV block.  Causal blocks with j > i are skipped entirely
(their iterations early-out), halving the work versus a dense sweep.

VMEM budget per step (bq=bk=256, H=128, fp32 scratch):
  q/k/v tiles 3*256*128*2B = 192KiB, logits 256*256*4B = 256KiB,
  acc 256*128*4B = 128KiB  -> well under the ~16MiB VMEM/core.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int):
    i = pl.program_id(2)  # query block
    j = pl.program_id(3)  # kv block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: block is relevant iff any query row can see any kv column
    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, H)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, H)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q (B,Nq,S,H); k/v (B,Nkv,S,H) -> (B,Nq,S,H)."""
    b, nq, s, h = q.shape
    nkv = k.shape[1]
    g = nq // nkv
    scale = scale if scale is not None else h ** -0.5
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)

    grid = (b, nq, s // bq, s // bk)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, h), lambda b_, n, i, j: (b_, n, i, 0)),
            pl.BlockSpec((1, 1, bk, h), lambda b_, n, i, j: (b_, n // g, j, 0)),
            pl.BlockSpec((1, 1, bk, h), lambda b_, n, i, j: (b_, n // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, h), lambda b_, n, i, j: (b_, n, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, h), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
