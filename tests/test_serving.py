"""Serving engine tests: admission-gated streams, prefill+decode generation
through the accelerator server, priority arbitration."""

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine, StreamSpec


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, max_seq=32, batch_size=1)
    yield eng
    eng.close()


def _spec(name, prio=1, period=1000.0):
    return StreamSpec(name=name, priority=prio, period_ms=period,
                      deadline_ms=period, prefill_ms=50.0, decode_ms=10.0,
                      decode_steps=4)


class TestAdmission:
    def test_admit_then_reject_on_saturation(self, engine):
        assert engine.admit(_spec("s_ok", prio=5)).admitted
        # a stream whose declared device demand saturates the accelerator
        hog = StreamSpec(name="s_hog", priority=4, period_ms=100,
                         deadline_ms=100, prefill_ms=95.0, decode_ms=10.0,
                         decode_steps=4)
        assert not engine.admit(hog).admitted
        engine.remove("s_ok")

    def test_generation_roundtrip(self, engine):
        assert engine.admit(_spec("gen", prio=3)).admitted
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        res = engine.generate("gen", prompt, steps=4)
        assert len(res.tokens) == 4
        assert res.prefill_latency_s > 0
        assert len(res.decode_latencies_s) == 4
        cfg = engine.cfg
        assert all(0 <= t < cfg.vocab_size for t in res.tokens)
        engine.remove("gen")

    def test_greedy_is_deterministic(self, engine):
        assert engine.admit(_spec("det", prio=2)).admitted
        prompt = np.array([[5, 6, 7]], np.int32)
        r1 = engine.generate("det", prompt, steps=3)
        r2 = engine.generate("det", prompt, steps=3)
        assert r1.tokens == r2.tokens
        engine.remove("det")

    def test_two_streams_share_engine(self, engine):
        assert engine.admit(_spec("a", prio=9)).admitted
        assert engine.admit(_spec("b", prio=1)).admitted
        pa = np.array([[1, 2]], np.int32)
        ra = engine.generate("a", pa, steps=2)
        rb = engine.generate("b", pa, steps=2)
        assert len(ra.tokens) == 2 and len(rb.tokens) == 2
        # server saw all requests in priority order without deadlock
        assert engine.server.stats.completed >= 6
        engine.remove("a")
        engine.remove("b")


class TestPagedKVIntegration:
    def test_blocks_reserved_and_freed(self):
        from repro.serving.kvcache import OutOfBlocksError

        cfg = get_config("internlm2_1_8b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(5))
        eng = ServeEngine(cfg, params, max_seq=32, kv_blocks=4, kv_block_size=8)
        try:
            assert eng.admit(_spec("pg", prio=1)).admitted
            prompt = np.arange(8, dtype=np.int32)[None, :]
            res = eng.generate("pg", prompt, steps=4)
            assert len(res.tokens) == 4
            # all blocks returned after the sequence completes
            assert eng.kv.blocks_in_use == 0
            # a request that cannot fit is rejected before any device work
            big = np.zeros((1, 30), np.int32)
            with pytest.raises(OutOfBlocksError):
                eng.generate("pg", big, steps=16)
            assert eng.kv.blocks_in_use == 0  # rejection leaks nothing
        finally:
            eng.close()
