"""Chaos matrix: deterministic fault injection against the live serving
pool.

Every scenario asserts the two recovery invariants end to end:
  * BIT-IDENTICAL tokens — a stream that survives a failure produces
    exactly the failure-free greedy tokens (the recovery re-prefill of the
    retained prefix reconstructs the dead server's cache state);
  * ZERO LEAKS — after all streams drain, every paged-KV block is back in
    the free list (``kv_blocks_in_use() == 0``) and every decode slot is
    back in its server's free list.

Matrix: kill 1 of N mid-decode, kill during prefill, double failure,
transient-error storm (below and above the retry budget), stall detected
by the heartbeat monitor, and degraded-mode shedding on an overloaded
survivor."""

import threading

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.core.faults import StreamShedError
from repro.models import model as M
from repro.runtime.faultinject import FaultInjector, ServerFault
from repro.serving.engine import ServeEngine, StreamSpec

STEPS = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _spec(name, prio, steps=STEPS, deadline_ms=8000.0):
    return StreamSpec(name=name, priority=prio, period_ms=8000.0,
                      deadline_ms=deadline_ms, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _reference_tokens(cfg, params, prompt, steps=STEPS):
    eng = ServeEngine(cfg, params, max_seq=32)
    try:
        assert eng.admit(_spec("ref", 1, steps=steps)).admitted
        return eng.generate("ref", prompt, steps=steps).tokens
    finally:
        eng.close()


def _engine(cfg, params, *, num_servers=2, paged=True, max_batch=4,
            heartbeat_timeout_s=30.0):
    eng = ServeEngine(cfg, params, max_seq=32, num_servers=num_servers,
                      batching=True, max_batch=max_batch, paged=paged,
                      kv_block_size=8)
    eng.enable_fault_tolerance(heartbeat_timeout_s=heartbeat_timeout_s)
    return eng


def _run_streams(eng, prompts, steps=STEPS):
    """Generate all streams concurrently; returns ({name: result-or-error},
    nothing raised out of the workers)."""
    out = {}

    def worker(n):
        try:
            out[n] = eng.generate(n, prompts[n], steps=steps)
        except Exception as e:  # noqa: BLE001 - recorded for assertions
            out[n] = e

    threads = [threading.Thread(target=worker, args=(n,)) for n in prompts]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return out


def _assert_no_leaks(eng):
    assert eng.kv_blocks_in_use() == 0
    for si in eng.pool.alive_servers():
        assert len(eng._slots[si].free) == eng.max_batch


class TestChaosMatrix:
    def test_kill_one_of_two_mid_decode(self, setup):
        """A server dies while its streams are decoding: both migrate to
        the survivor, re-prefill their retained prefix, and finish with
        exactly the failure-free tokens."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = _engine(cfg, params)
        try:
            names = [f"s{i}" for i in range(4)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 4 - i)).admitted
            assert {eng.pool.server_of(n) for n in names} == {0, 1}
            victim = eng.pool.server_of(names[0])
            on_victim = {n for n in names if eng.pool.server_of(n) == victim}
            inj = FaultInjector([ServerFault(server=victim, at_call=6,
                                             kind="die")])
            eng.pool.attach_fault_injector(inj)

            out = _run_streams(eng, {n: prompt for n in names})
            assert inj.events and inj.events[0].kind == "die"
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            # the victim's streams actually went through recovery
            assert any(out[n].recoveries > 0 for n in on_victim)
            assert len(eng.degraded_reports) == 1
            rep = eng.degraded_reports[0]
            assert rep.device == victim and not rep.shed
            assert set(rep.moved) == on_victim  # everyone re-placed
            assert all(rep.recovery_ms[n] > 0 for n in on_victim)
            assert eng.pool.alive_servers() == [1 - victim]
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_kill_during_prefill(self, setup):
        """The victim dies on its very first device call — the prefill
        itself — so recovery re-runs from an empty retained prefix."""
        cfg, params = setup
        prompt = np.array([[5, 6, 7]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = _engine(cfg, params)
        try:
            names = ["p0", "p1"]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 2 - i)).admitted
            victim = eng.pool.server_of("p0")
            inj = FaultInjector([ServerFault(server=victim, at_call=0,
                                             kind="die")])
            eng.pool.attach_fault_injector(inj)

            out = _run_streams(eng, {n: prompt for n in names})
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            assert any(out[n].recoveries > 0 for n in names)
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_double_failure(self, setup):
        """Two of three servers die at different times; every stream ends
        on the last survivor with bit-identical tokens."""
        cfg, params = setup
        prompt = np.array([[2, 4, 6]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = _engine(cfg, params, num_servers=3)
        try:
            names = [f"d{i}" for i in range(3)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 3 - i)).admitted
            servers = {eng.pool.server_of(n) for n in names}
            assert len(servers) == 3
            dead = sorted(servers)[:2]
            inj = FaultInjector([
                ServerFault(server=dead[0], at_call=3, kind="die"),
                ServerFault(server=dead[1], at_call=5, kind="die"),
            ])
            eng.pool.attach_fault_injector(inj)

            out = _run_streams(eng, {n: prompt for n in names})
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            assert len(eng.degraded_reports) == 2
            assert len(eng.pool.alive_servers()) == 1
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_transient_storm_within_retry_budget(self, setup):
        """Transient device errors under the retry budget are absorbed by
        backoff-retry: no recovery, no eviction, identical tokens."""
        cfg, params = setup
        prompt = np.array([[3, 1, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = _engine(cfg, params, num_servers=1)
        try:
            assert eng.admit(_spec("t0", 1)).admitted
            inj = FaultInjector([ServerFault(server=0, at_call=2,
                                             kind="transient", count=2)])
            eng.pool.attach_fault_injector(inj)

            res = eng.generate("t0", prompt, steps=STEPS)
            assert res.tokens == want
            assert res.recoveries == 0
            assert not eng.degraded_reports
            assert eng.pool.alive_servers() == [0]
            assert len(inj.events) == 2  # both transient hits logged
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_transient_storm_exhausts_retries_and_recovers(self, setup):
        """A storm longer than the retry budget escalates to device loss;
        the stream recovers on the survivor."""
        cfg, params = setup
        prompt = np.array([[9, 8]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = _engine(cfg, params)
        try:
            names = ["x0", "x1"]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 2 - i)).admitted
            victim = eng.pool.server_of("x0")
            inj = FaultInjector([ServerFault(server=victim, at_call=4,
                                             kind="transient", count=10)])
            eng.pool.attach_fault_injector(inj)

            out = _run_streams(eng, {n: prompt for n in names})
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            assert len(eng.degraded_reports) == 1
            assert victim not in eng.pool.alive_servers()
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_stall_detected_by_heartbeat(self, setup):
        """A wedged device call never raises on its own; the heartbeat
        monitor declares the server dead from OUTSIDE (per-device-call
        timeout) and the streams recover on the survivor."""
        cfg, params = setup
        prompt = np.array([[1, 1, 2]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        # warm every cell FIRST (including the longer recovery re-prefill
        # buckets), then arm the short heartbeat: a cold XLA compile inside
        # a device call would otherwise look exactly like a stall
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=2,
                          batching=True, max_batch=4, paged=True,
                          kv_block_size=8)
        try:
            eng.precompile((4, 8, 16))
            eng.enable_fault_tolerance(heartbeat_timeout_s=1.0)
            names = ["h0", "h1"]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 2 - i)).admitted
            victim = eng.pool.server_of("h0")
            inj = FaultInjector([ServerFault(server=victim, at_call=4,
                                             kind="stall", delay_s=3.0)])
            eng.pool.attach_fault_injector(inj)

            out = _run_streams(eng, {n: prompt for n in names})
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            assert victim not in eng.pool.alive_servers()
            assert len(eng.degraded_reports) == 1
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_degraded_admission_sheds_lowest_priority(self, setup):
        """When the survivor cannot host everyone, degraded-mode admission
        sheds in reverse priority order: the shed stream's generator raises
        StreamShedError, the survivors' tokens stay bit-identical, and the
        shed stream's blocks are all released."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = _engine(cfg, params)
        try:
            # deadline 500ms fits exactly two of these streams per device
            # (verified against the admission analysis); after eviction the
            # survivor cannot hold all four, so shedding MUST happen
            names = [f"g{i}" for i in range(4)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 4 - i, deadline_ms=500.0)).admitted
            assert {eng.pool.server_of(n) for n in names} == {0, 1}
            victim = eng.pool.server_of("g0")  # holds g0 (prio 4), g2 (2)
            inj = FaultInjector([ServerFault(server=victim, at_call=6,
                                             kind="die")])
            eng.pool.attach_fault_injector(inj)

            out = _run_streams(eng, {n: prompt for n in names})
            assert len(eng.degraded_reports) == 1
            rep = eng.degraded_reports[0]
            # reverse-priority shedding, deterministic given the placement:
            # g0 (highest) displaces g3 (globally lowest) and is re-admitted
            # with its recovery segment; g2 finds no lower victim -> shed
            assert rep.moved == {"g0": 1 - victim}
            assert rep.shed == ["g3", "g2"]
            assert rep.recovery_ms["g0"] > 0
            for n in ("g0", "g1"):  # the survivors: bit-identical tokens
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            for s in rep.shed:
                # a shed stream either observed the shed (StreamShedError)
                # or had already finished — then its tokens must be right
                if isinstance(out[s], Exception):
                    assert isinstance(out[s], StreamShedError), out[s]
                else:
                    assert out[s].tokens == want, s
                eng.remove(s)
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_remove_releases_leaked_blocks(self, setup):
        """engine.remove(stream) frees paged-KV blocks still held for the
        stream (a failure can orphan a reservation if the generating thread
        is gone)."""
        cfg, params = setup
        eng = _engine(cfg, params, num_servers=1)
        try:
            assert eng.admit(_spec("leaky", 1)).admitted
            si = eng.pool.server_of("leaky")
            eng._paged_reserve(si, "leaky", 4, STEPS, 4)
            assert eng.kv_blocks_in_use() > 0
            eng.remove("leaky")
            assert eng.kv_blocks_in_use() == 0
        finally:
            eng.close()

    def test_shutdown_drains_inflight_work(self, setup):
        """shutdown(drain=True) finishes queued work before joining; with
        drain=False pending requests fail fast instead of hanging."""
        cfg, params = setup
        eng = _engine(cfg, params, num_servers=1)
        try:
            assert eng.admit(_spec("d0", 1)).admitted
            res = eng.generate("d0", np.array([[4, 2]], np.int32),
                               steps=STEPS)
            assert len(res.tokens) == STEPS
        finally:
            eng.close()  # drains: must not raise or hang
        assert all(not s._thread.is_alive() for s in eng.pool.servers)
