"""Tests for the HLO cost model (analysis/hlo_cost.py) and roofline terms —
the measurement instrument behind EXPERIMENTS.md §Roofline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost, roofline


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestHloCost:
    def test_scan_flops_multiply_by_trip_count(self):
        """cost_analysis() counts a while body once; our walker must multiply
        by known_trip_count."""

        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        # XLA's own analysis undercounts (body counted once):
        xla = hlo_cost.xla_cost_analysis(compiled)
        assert xla["flops"] == pytest.approx(2 * 256**3)
        cost = hlo_cost.analyze_text(compiled.as_text())
        assert cost.flops == pytest.approx(12 * 2 * 256**3)

    def test_dot_flops_exact(self):
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        cost = hlo_cost.analyze_text(_compile_text(f, a, b))
        assert cost.flops == pytest.approx(2 * 64 * 128 * 32)

    def test_batched_dot_flops(self):
        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
        cost = hlo_cost.analyze_text(_compile_text(f, a, b))
        assert cost.flops == pytest.approx(2 * 4 * 32 * 64 * 16)

    def test_memory_counts_weights_once_per_iteration(self):
        def f(x, ws):
            def body(c, w):
                return c @ w, None
            return jax.lax.scan(body, x, ws)[0]

        n, L = 128, 6
        x = jax.ShapeDtypeStruct((n, n), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
        cost = hlo_cost.analyze_text(_compile_text(f, x, ws))
        w_bytes = n * n * 4
        # per iteration: weight slice read (2x in the cost model: slice
        # in+out) + dot operands/result (3x) + carry copies.  Must be
        # O(L * w_bytes), far from L * full-stack reads.
        assert cost.hbm_bytes < 16 * L * w_bytes
        assert cost.hbm_bytes > 2 * L * w_bytes

    def test_parse_handles_index_comments(self):
        """Big tuple types carry /*index=N*/ comments that must not break
        instruction parsing (regression: while loops were silently skipped)."""
        txt = """
HloModule m, entry_computation_layout={()->f32[2]{0}}

%body (p: (s32[], f32[2])) -> (s32[], f32[2]) {
  %p = (s32[], /*index=1*/f32[2]{0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[2]{0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %a = s32[] add(%g0, %c1)
  %m = f32[2]{0} multiply(%g1, %g1)
  ROOT %t = (s32[], /*index=1*/f32[2]{0}) tuple(%a, %m)
}

%cond (p2: (s32[], f32[2])) -> pred[] {
  %p2 = (s32[], /*index=1*/f32[2]{0}) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%g, %c), direction=LT
}

ENTRY %main () -> f32[2] {
  %z = f32[2]{0} constant({1, 2})
  %zi = s32[] constant(0)
  %t0 = (s32[], /*index=1*/f32[2]{0}) tuple(%zi, %z)
  %w = (s32[], /*index=1*/f32[2]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[2]{0} get-tuple-element(%w), index=1
}
"""
        comps, entry = hlo_cost.parse_module(txt)
        assert "body" in comps and "cond" in comps
        cost = hlo_cost.analyze_text(txt)
        # 5 iterations x [multiply f32[2]: 3*8 B, counter add s32: 3*4 B,
        # cond compare: 4+4+1 B] = 5 * (24 + 12 + 9) = 225
        assert cost.hbm_bytes == pytest.approx(5 * (24 + 12 + 9))


class TestCollectiveParsing:
    def test_psum_bytes(self):
        """all-reduce result bytes x trips, via shard_map on 1 device."""
        txt = """
HloModule m

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%x), replica_groups={}, to_apply=%add
}
"""
        cost = hlo_cost.analyze_text(txt)
        assert cost.collective_bytes["all-reduce"] == pytest.approx(4096)
        assert cost.collective_counts["all-reduce"] == 1

    def test_async_start_done_counted_once(self):
        txt = """
HloModule m

ENTRY %main (x: f32[256]) -> f32[512] {
  %x = f32[256]{0} parameter(0)
  %ags = (f32[256]{0}, f32[512]{0}) all-gather-start(%x), dimensions={0}
  ROOT %agd = f32[512]{0} all-gather-done(%ags)
}
"""
        cost = hlo_cost.analyze_text(txt)
        assert cost.collective_counts["all-gather"] == 1
        # result tuple of -start includes in+out buffers; we charge its bytes
        assert cost.collective_bytes["all-gather"] > 0


class TestPagedDecodeGatherShapes:
    """Regression pin for the cost-model calibration path: the roofline
    features the StepCostModel fits against come from pricing the paged-
    decode KV gather, so the gather byte rule and the xla_cost_analysis
    normalization must stay stable on exactly these shapes."""

    def test_gather_bytes_rule_pinned(self):
        """gather charges 2*out + indices: the block-table gather of a
        (n=2, w=4) cell over (17, 8, 4) block pools."""
        txt = """
HloModule m

ENTRY %main (pool: f32[17,8,4], tables: s32[2,4]) -> f32[2,4,8,4] {
  %pool = f32[17,8,4]{2,1,0} parameter(0)
  %tables = s32[2,4]{1,0} parameter(1)
  ROOT %g = f32[2,4,8,4]{3,2,1,0} gather(%pool, %tables), offset_dims={2,3}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=2, slice_sizes={1,8,4}
}
"""
        cost = hlo_cost.analyze_text(txt)
        out_b = 2 * 4 * 8 * 4 * 4      # f32[2,4,8,4]
        idx_b = 2 * 4 * 4              # s32[2,4]
        assert cost.hbm_bytes == pytest.approx(2.0 * out_b + idx_b)
        assert cost.flops == 0.0

    def test_compiled_gather_is_memory_bound(self):
        """End to end on a real trace: jit the block-pool gather at a
        paged-decode cell shape; the walker must price it, the
        xla_cost_analysis dict must normalize to a flat mapping, and the
        roofline terms must call it memory-bound (zero-FLOP data movement
        is the regime the rows*width cost-model feature covers)."""

        def f(pool, tables):
            return pool[tables]  # (n, w, bs, hd) block gather

        pool = jax.ShapeDtypeStruct((33, 16, 8), jnp.float32)
        tables = jax.ShapeDtypeStruct((4, 2), jnp.int32)
        compiled = jax.jit(f).lower(pool, tables).compile()
        xla = hlo_cost.xla_cost_analysis(compiled)
        assert isinstance(xla, dict) and "bytes accessed" in xla
        out_b = 4 * 2 * 16 * 8 * 4
        assert xla["bytes accessed"] >= out_b
        cost = hlo_cost.analyze_text(compiled.as_text())
        assert cost.hbm_bytes >= 2.0 * out_b
        terms = roofline.analyze(
            {"flops": xla.get("flops", 0.0),
             "bytes accessed": xla["bytes accessed"]},
            compiled.as_text(), chips=1, model_flops=0.0)
        assert terms.bottleneck == "memory"
        assert terms.memory_ms > 0.0
        assert terms.compute_ms == pytest.approx(0.0, abs=1e-9)


class TestRooflineTerms:
    def test_bottleneck_and_fraction(self):
        cost = {"flops": 0.0, "bytes accessed": 0.0}
        txt = """
HloModule m

ENTRY %main (a: bf16[4096,4096], b: bf16[4096,4096]) -> bf16[4096,4096] {
  %a = bf16[4096,4096]{1,0} parameter(0)
  %b = bf16[4096,4096]{1,0} parameter(1)
  ROOT %d = bf16[4096,4096]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
        terms = roofline.analyze(cost, txt, chips=1, model_flops=2 * 4096**3)
        assert terms.compute_ms == pytest.approx(
            2 * 4096**3 / roofline.PEAK_FLOPS * 1e3)
        assert terms.memory_ms == pytest.approx(
            3 * 4096 * 4096 * 2 / roofline.HBM_BW * 1e3)
        assert terms.collective_ms == 0.0
        assert terms.bottleneck == "compute"  # AI = 683 >> 240 ridge point
        assert terms.roofline_fraction == 1.0
        assert terms.model_flops_ratio == pytest.approx(1.0)
