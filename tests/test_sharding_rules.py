"""Sharding-rule invariants, checked against the FULL configs (via
eval_shape — no allocation): every sharded dimension must divide the mesh
axis it is mapped to, for params, batches, and decode caches.  These are
the invariants that make the 512-device dry-run compile."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.distributed import sharding as shd
from repro.launch.steps import cache_pspecs
from repro.models import model as M
from repro.training.train_step import batch_specs


class _FakeMesh:
    """Stands in for the 256-chip mesh (shape lookups only)."""

    shape = {"data": 16, "model": 16}


RULES = shd.ShardingRules(mesh=_FakeMesh(), batch_axes=("data",), fsdp=True)


def _axis_size(name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= _FakeMesh.shape[n]
        return out
    return _FakeMesh.shape[name]


def _check_tree(shapes_tree, specs_tree, what: str):
    leaves_s, _ = jax.tree_util.tree_flatten(shapes_tree)
    leaves_p = jax.tree_util.tree_flatten(
        specs_tree, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(leaves_s) == len(leaves_p), what
    for arr, spec in zip(leaves_s, leaves_p):
        assert isinstance(spec, P), (what, spec)
        for i, name in enumerate(spec):
            size = _axis_size(name)
            assert arr.shape[i] % size == 0, (
                f"{what}: dim {i} of {arr.shape} not divisible by "
                f"{name} ({size})")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, RULES)
    _check_tree(shapes, specs, f"{arch} params")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_divisible(arch):
    cfg = get_config(arch)
    for shape in shapes_for(cfg):
        batch = M.input_specs(cfg, shape)
        specs = batch_specs(cfg, batch, RULES)
        _check_tree(batch, specs, f"{arch} {shape.name} batch")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    for shape in shapes_for(cfg):
        if shape.kind != "decode":
            continue
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
        specs = cache_pspecs(cache, RULES, batch=shape.global_batch,
                             seq=shape.seq_len)
        _check_tree(cache, specs, f"{arch} {shape.name} cache")


@pytest.mark.parametrize("arch", ["llama3_405b", "qwen3_moe_235b_a22b"])
def test_expert_and_serve2d_layouts(arch):
    """The §Perf layouts must keep divisibility too."""
    import dataclasses

    cfg = get_config(arch)
    rules = dataclasses.replace(RULES, expert_ff_fsdp=True, shard_batch=False,
                                seq_axes=("data", "model"))
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, rules)
    _check_tree(shapes, specs, f"{arch} serve2d params")
    shape = SHAPES["decode_32k"]
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    specs = cache_pspecs(cache, rules, batch=shape.global_batch,
                         seq=shape.seq_len)
    _check_tree(cache, specs, f"{arch} serve2d cache")
