"""Distributed MoE numerics: the shard_map EP paths must match the dense
reference.  Runs in a subprocess so we can force 8 host devices without
polluting the main test process (jax locks the device count at first init).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.configs.registry import get_config
    from repro.distributed import sharding as shd
    from repro.models import moe as MOE

    cfg = get_config("qwen3_moe_235b_a22b").reduced()
    # high capacity factor => no drops => exact match with dense
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0,
                              num_experts=8, num_experts_per_tok=2)
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(cfg, key, jnp.float32)
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))

    # --- EP all_to_all path (train/prefill: S divisible by model axis) ----
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
    want, aux_want = MOE.moe_dense(cfg, p, x)
    rules = shd.ShardingRules(mesh=mesh, batch_axes=("data",), fsdp=False)
    with shd.use_rules(rules):
        got, aux = jax.jit(lambda pp, xx: MOE.moe_layer(cfg, pp, xx))(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # aux is computed per shard and pmean'd (GShard convention): close to
    # but not identical with the global-batch aux
    np.testing.assert_allclose(float(aux), float(aux_want), rtol=0.25)
    print("A2A-PATH-OK")

    # --- replicated path (decode: S == 1) ---------------------------------
    x1 = jax.random.normal(jax.random.PRNGKey(2), (4, 1, cfg.d_model), jnp.float32)
    want1, _ = MOE.moe_dense(cfg, p, x1)
    with shd.use_rules(rules):
        got1, _ = jax.jit(lambda pp, xx: MOE.moe_layer(cfg, pp, xx))(p, x1)
    np.testing.assert_allclose(np.asarray(got1), np.asarray(want1),
                               rtol=2e-5, atol=2e-5)
    print("REPLICATED-PATH-OK")

    # --- gradients flow through the a2a dispatch --------------------------
    def loss(pp):
        with shd.use_rules(rules):
            out, aux = MOE.moe_layer(cfg, pp, x)
        return jnp.sum(out ** 2) + 0.01 * aux
    g = jax.grad(loss)(p)
    leaves = jax.tree_util.tree_leaves(g)
    assert leaves and all(np.isfinite(np.asarray(l)).all() for l in leaves)
    assert any(np.abs(np.asarray(l)).max() > 0 for l in leaves)
    print("GRADS-OK")
""")


@pytest.mark.slow
def test_moe_shard_map_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "A2A-PATH-OK" in res.stdout
    assert "REPLICATED-PATH-OK" in res.stdout
    assert "GRADS-OK" in res.stdout
