"""Per-architecture smoke tests: instantiate the REDUCED config of each
family, run forward/train/prefill/decode on CPU, assert shapes + finiteness.
The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model as M

jax.config.update("jax_platform_name", "cpu")


def _batch_for(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        del batch["tokens"]
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.float32).astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, b, s))
        batch["mrope_positions"] = pos
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32).astype(cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def built():
    """Init each reduced arch once per test session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = M.init_params(cfg, jax.random.PRNGKey(42))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_forward(arch, built):
    cfg, params = built(arch)
    batch = _batch_for(cfg)
    logits, _, aux = M.apply(cfg, params, batch, mode="train")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_gradients(arch, built):
    cfg, params = built(arch)
    batch = _batch_for(cfg)

    def loss(p):
        return M.loss_fn(cfg, p, batch, remat=True)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, built):
    cfg, params = built(arch)
    b, s, max_seq = 2, 8, 16
    batch = _batch_for(cfg, b, s)
    batch["max_seq"] = max_seq
    logits, cache, _ = M.apply(cfg, params, batch, mode="prefill")
    assert logits.shape == (b, s, cfg.vocab_size)
    assert cache is not None
    assert int(cache["pos"][0]) == s

    step = {"tokens": jnp.array([[1], [2]], jnp.int32)}
    if cfg.family == "vlm":
        del step["tokens"]
        step["embeds"] = jnp.ones((b, 1, cfg.d_model), cfg.dtype)
        step["mrope_positions"] = jnp.full((3, b, 1), s, jnp.int32)
    logits2, cache2, _ = M.apply(cfg, params, step, mode="decode", cache=cache)
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["pos"][0]) == s + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch, built):
    """Teacher-forced decode must reproduce the full-sequence forward
    logits (the KV/state caches are exact, not approximations).  Run in
    fp32: the property is cache exactness — in bf16 the absorbed-MLA and
    SSD decode paths reorder reductions and differ by ~1e-2, which is
    precision, not logic (verified fp32 max diff <= 4e-6)."""
    import dataclasses

    cfg, _ = built(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(42))
    b, s = 1, 8
    batch = _batch_for(cfg, b, s)
    full_logits, _, _ = M.apply(cfg, params, batch, mode="train")

    pre = {k: (v[:, :4] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    if cfg.family == "vlm":
        pre["embeds"] = batch["embeds"][:, :4]
        pre["mrope_positions"] = batch["mrope_positions"][:, :, :4]
    pre["max_seq"] = s
    _, cache, _ = M.apply(cfg, params, pre, mode="prefill")

    outs = []
    for t in range(4, s):
        step = {"tokens": batch["tokens"][:, t:t + 1]} if cfg.family != "vlm" else {}
        if cfg.family == "vlm":
            step["embeds"] = batch["embeds"][:, t:t + 1]
            step["mrope_positions"] = batch["mrope_positions"][:, :, t:t + 1]
        if cfg.family == "encdec":
            step["tokens"] = batch["tokens"][:, t:t + 1]
        lg, cache, _ = M.apply(cfg, params, step, mode="decode", cache=cache)
        outs.append(np.asarray(lg[:, 0], np.float32))

    want = np.asarray(full_logits[:, 4:s], np.float32)
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_family_resolves(arch):
    """Every registry entry must resolve to a paged cache family — either
    declared (cfg.cache_family) or derived (plain GQA stacks only).  A
    None here would mean the arch silently loses the paged serving path
    and falls back to dense, which the serving layer forbids."""
    from repro.serving.kvcache import FAMILIES

    cfg = get_config(arch).reduced()
    fam = M.cache_family(cfg)
    assert fam is not None, f"{arch}: no cache family (silent dense fallback)"
    assert fam in FAMILIES, f"{arch}: unknown family {fam!r}"
    assert M.supports_paged(cfg), arch
    # the declaration (when present) is what resolution honors
    if cfg.cache_family:
        assert fam == cfg.cache_family


@pytest.mark.parametrize("arch", ["llama3_405b", "qwen3_moe_235b_a22b",
                                  "mamba2_780m", "zamba2_7b", "whisper_medium"])
def test_param_count_matches_init(arch):
    """Analytical param_count (used for roofline MODEL_FLOPS) must match the
    actual initialized tree of the reduced config."""
    cfg = get_config(arch).reduced()
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    expected = M.param_count(cfg)
    assert actual == expected, f"{arch}: init {actual} vs analytical {expected}"


def test_full_config_param_counts():
    """Sanity-check the FULL configs' analytical sizes (billions)."""
    expect = {
        "llama3_405b": (390e9, 420e9),
        "granite_34b": (32e9, 38e9),
        "internlm2_20b": (17e9, 22e9),
        "internlm2_1_8b": (1.6e9, 2.1e9),
        "qwen3_moe_235b_a22b": (225e9, 245e9),
        "deepseek_v2_lite_16b": (13e9, 17e9),
        "mamba2_780m": (0.6e9, 0.9e9),
        "qwen2_vl_2b": (1.2e9, 2.3e9),
        # whisper-medium is 769M (enc+dec, tied unembedding)
        "whisper_medium": (0.70e9, 0.85e9),
        # zamba2-7b minus the per-use LoRA deltas on the shared block
        # (omitted; DESIGN.md §5) lands at ~5.7B
        "zamba2_7b": (5e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = M.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
