"""Scenario-engine tests: registries, generator invariants, golden replay
against the legacy simulator paths, bit-identical seeded replay, the
bound-dominance property over the full arrival-model x protocol matrix,
the server-vs-sync admission cross-check, and the LP allocation baseline.

``hypothesis`` is optional, as in test_simulator_property.py: the property
tests parametrize over a fixed seed list, so the tier-1 command collects
and runs everywhere.
"""

import math
import random

import pytest

from repro.core import fmlp_analysis, mpcp_analysis, server_analysis, simulator
from repro.core.allocation import allocate, allocate_pool
from repro.core.faults import seeded_device_faults
from repro.core.task_model import GpuSegment, Task
from repro.core.taskset_gen import GenParams, _split_random, generate_taskset
from repro.scenarios import (
    ARRIVALS,
    CI_MATRIX,
    ETM,
    OVERHEADS,
    PROTOCOLS,
    SCENARIOS,
    SCHEDULERS,
    Registry,
    RegistryError,
    Scenario,
    build,
    default_cost_model,
    rng_stream,
    run,
)
from repro.scenarios.arrivals import check_min_separation
from repro.scenarios.etm import check_within_declared
from repro.scenarios.lp_alloc import HAVE_SCIPY, allocate_lp, lp_pack

NS_TOL = 1e-3  # ms; the simulator's integer-ns quantization slack

_SEEDS = [0, 1, 2, 7, 19]


def _params(**kw) -> GenParams:
    base = dict(num_cores=2, num_tasks=(3, 6), epsilon_ms=0.05,
                pct_gpu_tasks=(0.3, 0.6))
    base.update(kw)
    return GenParams(**base)


def _gpu_task(seed: int = 0) -> Task:
    tasks = generate_taskset(_params(), random.Random(seed))
    gpu = [t for t in tasks if t.uses_gpu]
    assert gpu, "canonical params always produce a GPU task"
    return gpu[0]


# -------------------------------------------------------------------------
# registries
# -------------------------------------------------------------------------


class TestRegistry:
    def test_unknown_key_lists_alternatives(self):
        with pytest.raises(RegistryError) as e:
            ARRIVALS.create("nope")
        msg = str(e.value)
        assert "unknown arrival model 'nope'" in msg
        assert "periodic" in msg and "bursty" in msg

    def test_duplicate_registration_rejected(self):
        r = Registry("thing")
        r.register("a", lambda: 1)
        with pytest.raises(ValueError, match="duplicate"):
            r.register("a", lambda: 2)

    def test_builtin_keys_present(self):
        assert {"periodic", "sporadic", "bursty", "diurnal", "trace"} <= set(ARRIVALS)
        assert {"constant", "table", "uniform", "measured"} <= set(ETM)
        assert {"constant", "zero", "scaled", "measured"} <= set(OVERHEADS)
        assert {"server", "server_fifo", "server_edf", "server_batched",
                "mpcp", "fmlp"} <= set(PROTOCOLS)
        assert {"rm", "dm", "given"} <= set(SCHEDULERS)
        assert set(CI_MATRIX) <= set(SCENARIOS)

    def test_scenario_rejects_unknown_keys_at_construction(self):
        with pytest.raises(RegistryError, match="unknown protocol"):
            Scenario(name="x", protocol="token_ring")
        with pytest.raises(RegistryError, match="unknown arrival model"):
            Scenario(name="x", arrivals="poisson")

    def test_scenario_config_is_json_able(self):
        import json

        scn = SCENARIOS.create("flash_crowd", seed=5)
        echo = json.loads(json.dumps(scn.config()))
        assert echo["name"] == "flash_crowd" and echo["seed"] == 5


# -------------------------------------------------------------------------
# arrival models: the sporadic minimum-gap contract
# -------------------------------------------------------------------------

_ARRIVAL_SPECS = [
    ("periodic", {}),
    ("periodic", {"offset_ms": 3.0}),
    ("sporadic", {"slack": (0.0, 0.4)}),
    ("bursty", {"p_enter": 0.2, "p_exit": 0.3, "idle_factor": 3.0}),
    ("bursty", {"p_enter": 0.05, "p_exit": 0.1, "idle_factor": 6.0,
                "start_bursting": True}),
    ("diurnal", {"cycles": 3.0, "amplitude": 2.5}),
]


class TestArrivals:
    @pytest.mark.parametrize("key,params", _ARRIVAL_SPECS)
    @pytest.mark.parametrize("seed", _SEEDS)
    def test_min_separation_and_horizon(self, key, params, seed):
        task = _gpu_task(seed)
        horizon = 10.0 * task.T
        rel = ARRIVALS.create(key, **params).releases(
            task, horizon, rng_stream(seed, f"t/{key}"))
        assert rel == sorted(rel)
        assert all(0.0 <= r < horizon for r in rel)
        check_min_separation(task, rel)  # raises on violation

    def test_periodic_matches_legacy_release_loop(self):
        task = _gpu_task(0)
        horizon = 7.3 * task.T
        rel = ARRIVALS.create("periodic").releases(task, horizon, None)
        # the legacy simulate() loop: integer-ns accumulation from 0
        t, step, ns_h, legacy = 0, int(round(task.T * 1e6)), int(round(horizon * 1e6)), []
        while t < ns_h:
            legacy.append(t / 1e6)
            t += step
        assert rel == legacy

    def test_trace_validates_min_gap(self):
        task = _gpu_task(0)
        bad = {task.name: [0.0, task.T * 0.5]}
        with pytest.raises(ValueError, match="inter-arrival"):
            ARRIVALS.create("trace", releases_ms=bad).releases(
                task, 10 * task.T, None)

    def test_trace_absent_task_falls_back_to_periodic(self):
        task = _gpu_task(0)
        rel = ARRIVALS.create("trace", releases_ms={}).releases(
            task, 5 * task.T, None)
        assert rel == ARRIVALS.create("periodic").releases(task, 5 * task.T, None)


# -------------------------------------------------------------------------
# execution-time models: never above the declared worst case
# -------------------------------------------------------------------------

class TestEtm:
    @pytest.mark.parametrize("key,params", [
        ("constant", {}),
        ("table", {"scales": {}, "default": 0.8}),
        ("uniform", {"frac": (0.5, 1.0)}),
    ])
    @pytest.mark.parametrize("seed", _SEEDS)
    def test_within_declared(self, key, params, seed):
        model = ETM.create(key, **params)
        rng = rng_stream(seed, f"etm/{key}")
        for task in generate_taskset(_params(), random.Random(seed)):
            for j in range(5):
                C, segs = model.costs(task, j, rng)
                check_within_declared(task, C, segs)  # raises on violation

    def test_constant_is_exactly_declared(self):
        task = _gpu_task(0)
        C, segs = ETM.create("constant").costs(task, 0, None)
        assert C == task.C and segs == task.segments

    def test_measured_within_declared_and_needs_model(self):
        with pytest.raises(ValueError, match="StepCostModel"):
            ETM.create("measured")
        model = ETM.create("measured", cost_model=default_cost_model(),
                           cell=("decode", 4, 64))
        for task in generate_taskset(_params(), random.Random(3)):
            C, segs = model.costs(task, 0, None)
            check_within_declared(task, C, segs)

    def test_check_rejects_inflated_costs(self):
        task = _gpu_task(0)
        with pytest.raises(ValueError, match="> declared"):
            check_within_declared(task, task.C * 1.5, task.segments)
        fat = tuple(GpuSegment(e=s.e * 2, m=s.m) for s in task.segments)
        with pytest.raises(ValueError, match="exceeds"):
            check_within_declared(task, task.C, fat)


# -------------------------------------------------------------------------
# taskset generation: int seeds, heavy-tailed segment splits
# -------------------------------------------------------------------------

class TestTasksetGen:
    def test_int_seed_replays(self):
        p = _params()
        assert generate_taskset(p, 42) == generate_taskset(p, 42)
        assert generate_taskset(p, 42) != generate_taskset(p, 43)

    @pytest.mark.parametrize("mode", ["uniform", "heavy"])
    def test_split_preserves_total(self, mode):
        rng = random.Random(9)
        for n in (1, 2, 5):
            parts = _split_random(10.0, n, rng, mode)
            assert len(parts) == n
            assert all(p > 0 for p in parts)
            assert math.isclose(sum(parts), 10.0, rel_tol=1e-12)

    def test_unknown_split_mode_rejected(self):
        with pytest.raises(ValueError, match="seg_split"):
            _split_random(1.0, 2, random.Random(0), "zipf")
        with pytest.raises(ValueError, match="seg_split"):
            generate_taskset(_params(seg_split="zipf"), 0)


# -------------------------------------------------------------------------
# golden replay: the registry-driven engine vs the legacy simulator paths
# -------------------------------------------------------------------------

def _legacy_system(seed: int, *, pool: bool = False):
    tasks = generate_taskset(_params(), random.Random(seed))
    if pool:
        return allocate_pool(tasks, 2, 2, epsilon=0.05)
    return allocate(tasks, 2, approach="server", epsilon=0.05)


class TestGoldenReplay:
    """The refactored simulate() with explicit periodic releases and the
    constant ETM must replay the legacy hard-coded paths bit-for-bit."""

    @pytest.mark.parametrize("mode", ["server", "server_batched"])
    @pytest.mark.parametrize("seed", _SEEDS)
    def test_modes_identical(self, mode, seed):
        system = _legacy_system(seed)
        horizon = 3.0 * max(t.T for t in system.tasks)
        legacy = simulator.simulate(system, mode=mode, horizon_ms=horizon,
                                    trace=True)
        periodic = ARRIVALS.create("periodic")
        releases = {t.name: periodic.releases(t, horizon, None)
                    for t in system.tasks}
        constant = ETM.create("constant")
        replayed = simulator.simulate(
            system, mode=mode, horizon_ms=horizon, trace=True,
            releases=releases, etm=lambda t, j: constant.costs(t, j, None))
        assert replayed == legacy

    @pytest.mark.parametrize("seed", _SEEDS[:3])
    def test_fault_path_identical(self, seed):
        system = _legacy_system(seed, pool=True)
        horizon = 3.0 * max(t.T for t in system.tasks)
        faults = seeded_device_faults(system, seed, num_faults=1,
                                      horizon_ms=horizon)
        legacy = simulator.simulate(system, mode="server", horizon_ms=horizon,
                                    faults=faults, trace=True)
        periodic = ARRIVALS.create("periodic")
        releases = {t.name: periodic.releases(t, horizon, None)
                    for t in system.tasks}
        replayed = simulator.simulate(
            system, mode="server", horizon_ms=horizon, faults=faults,
            trace=True, releases=releases,
            etm=lambda t, j: (t.C, t.segments))
        assert replayed == legacy

    @pytest.mark.parametrize("name", CI_MATRIX)
    def test_same_seed_scenario_bit_identical(self, name):
        cm = default_cost_model()
        a = run(SCENARIOS.create(name, seed=11), cost_model=cm)
        b = run(SCENARIOS.create(name, seed=11), cost_model=cm)
        assert a.sim == b.sim  # full SimResult: every response time + trace
        assert a.bounds == b.bounds
        assert [t for t in a.system.tasks] == [t for t in b.system.tasks]


# -------------------------------------------------------------------------
# the matrix property: bound >= simulated WCRT on every covered cell
# -------------------------------------------------------------------------

_MATRIX_ARRIVALS = [
    ("periodic", {}),
    ("sporadic", {"slack": (0.0, 0.3)}),
    ("bursty", {"p_enter": 0.15, "p_exit": 0.3, "idle_factor": 3.0}),
    ("diurnal", {"cycles": 2.0, "amplitude": 2.0}),
]
_MATRIX_PROTOCOLS = ["server", "server_fifo", "server_edf", "server_batched",
                     "mpcp", "fmlp"]


class TestMatrixBoundDominance:
    @pytest.mark.parametrize("protocol", _MATRIX_PROTOCOLS)
    @pytest.mark.parametrize("arr", _MATRIX_ARRIVALS,
                             ids=[a[0] for a in _MATRIX_ARRIVALS])
    @pytest.mark.parametrize("seed", _SEEDS[:3])
    def test_bound_dominates_sim(self, protocol, arr, seed):
        scn = Scenario(name=f"cell_{protocol}_{arr[0]}", seed=seed,
                       taskset=dict(num_cores=2, num_tasks=(3, 6),
                                    epsilon_ms=0.05,
                                    pct_gpu_tasks=(0.3, 0.6)),
                       arrivals=arr, protocol=protocol)
        res = run(scn)
        for t in res.system.tasks:
            bound, wcrt = res.bounds[t.name], res.wcrt[t.name]
            if math.isfinite(bound):
                assert wcrt <= bound + NS_TOL, (
                    f"{scn.name}: {t.name} sim WCRT {wcrt} > bound {bound}")

    @pytest.mark.parametrize("name", CI_MATRIX)
    @pytest.mark.parametrize("seed", [0, 3])
    def test_ci_presets_bound_dominates(self, name, seed):
        res = run(SCENARIOS.create(name, seed=seed),
                  cost_model=default_cost_model())
        for t in res.system.tasks:
            bound, wcrt = res.bounds[t.name], res.wcrt[t.name]
            if math.isfinite(bound):
                assert wcrt <= bound + NS_TOL, (
                    f"{name}/{seed}: {t.name} sim WCRT {wcrt} > bound {bound}")

    def test_variable_etm_dominated_by_declared_bound(self):
        # Eqs (1)-(6) are monotone in costs: running jobs BELOW declared
        # WCET must stay below the declared-cost bound.
        scn = Scenario(name="etm_cell", seed=2,
                       taskset=dict(num_cores=2, num_tasks=(4, 7),
                                    epsilon_ms=0.05,
                                    pct_gpu_tasks=(0.3, 0.6)),
                       etm=("uniform", {"frac": (0.4, 1.0)}))
        res = run(scn)
        for t in res.system.tasks:
            if math.isfinite(res.bounds[t.name]):
                assert res.wcrt[t.name] <= res.bounds[t.name] + NS_TOL


# -------------------------------------------------------------------------
# server vs sync baselines: the admission cross-check (canonical sweep)
# -------------------------------------------------------------------------

class TestServerVsSyncCrossCheck:
    def test_server_admits_superset_on_canonical_sweep(self):
        """Paper claim, checked through the protocol registry: on the §6.3
        canonical parameters the server-based bound admits every taskset
        the sync baselines admit (up to rare allocation artifacts — the
        approaches pack different demand shapes, so we pin aggregate
        dominance plus a tight cap on per-taskset exceptions)."""
        params = GenParams(num_cores=4)
        server_p = PROTOCOLS.create("server")
        mpcp_p = PROTOCOLS.create("mpcp")
        fmlp_p = PROTOCOLS.create("fmlp")
        n = 150
        admitted = {"server": 0, "mpcp": 0, "fmlp": 0}
        exceptions = 0
        for seed in range(n):
            tasks = generate_taskset(params, random.Random(seed))
            sync_sys = allocate(tasks, 4, approach="sync")
            m = mpcp_p.analyze(sync_sys).schedulable
            f = fmlp_p.analyze(sync_sys).schedulable
            srv_sys = allocate(tasks, 4, approach="server",
                               epsilon=params.epsilon_ms)
            s = server_p.analyze(srv_sys).schedulable
            admitted["server"] += s
            admitted["mpcp"] += m
            admitted["fmlp"] += f
            if (m or f) and not s:
                exceptions += 1
        assert admitted["server"] >= admitted["mpcp"]
        assert admitted["server"] >= admitted["fmlp"]
        assert exceptions <= 0.02 * n, (
            f"server failed {exceptions}/{n} tasksets a sync baseline "
            f"admitted — superset claim broken beyond allocation noise")


# -------------------------------------------------------------------------
# LP allocation baseline
# -------------------------------------------------------------------------

class TestLpAllocation:
    @pytest.mark.parametrize("seed", _SEEDS)
    def test_lp_pack_valid_and_lower_bounded(self, seed):
        rng = random.Random(seed)
        items = [(f"i{k}", rng.uniform(0.05, 0.5)) for k in range(9)]
        pack = lp_pack(items, 3)
        assert set(pack.assignment) == {n for n, _ in items}
        assert all(0 <= b < 3 for b in pack.assignment.values())
        # z* is a true lower bound; the rounded packing sits at/above it
        assert pack.lp_bound <= pack.max_load + 1e-9
        total = sum(u for _, u in items)
        assert pack.lp_bound >= max(total / 3, max(u for _, u in items)) - 1e-6
        if HAVE_SCIPY:
            assert pack.used_lp

    def test_lp_pack_empty_and_single_bin(self):
        assert lp_pack([], 2).assignment == {}
        pack = lp_pack([("a", 0.3), ("b", 0.2)], 1)
        assert pack.assignment == {"a": 0, "b": 0}
        assert math.isclose(pack.max_load, 0.5)

    @pytest.mark.parametrize("seed", _SEEDS[:3])
    def test_allocate_lp_system_shape(self, seed):
        tasks = generate_taskset(_params(num_tasks=(6, 10)),
                                 random.Random(seed))
        system = allocate_lp(tasks, 2, 2, epsilon=0.05)
        assert system.num_cores == 4
        assert len(system.server_cores) == 2
        assert {t.device for t in system.tasks if t.uses_gpu} <= {0, 1}
        # partitions must stay core-disjoint: subsystem() raises otherwise
        for d in range(2):
            system.subsystem(d)
        # the LP system is analyzable and simulable end to end
        res = server_analysis.analyze_pool(system)
        horizon = 2.0 * max(t.T for t in system.tasks)
        sim = simulator.simulate(system, mode="server", horizon_ms=horizon)
        for t in system.tasks:
            if math.isfinite(res.wcrt(t.name)):
                assert sim.wcrt(t.name) <= res.wcrt(t.name) + NS_TOL

    @pytest.mark.parametrize("seed", _SEEDS[:3])
    def test_lp_bound_bounds_wfd_too(self, seed):
        """z* lower-bounds ANY packing, including the greedy heuristic's."""
        tasks = generate_taskset(_params(num_tasks=(8, 12)),
                                 random.Random(seed))
        gpu_items = [(t.name, t.G / t.T) for t in tasks if t.uses_gpu]
        if len(gpu_items) < 2:
            pytest.skip("degenerate draw: <2 GPU tasks")
        pack = lp_pack(gpu_items, 2)
        wfd = allocate_pool(tasks, 2, 2, epsilon=0.05)
        load = [0.0, 0.0]
        for t in wfd.tasks:
            if t.uses_gpu:
                load[t.device] += t.G / t.T
        assert pack.lp_bound <= max(load) + 1e-9


# -------------------------------------------------------------------------
# scenario-level config validation
# -------------------------------------------------------------------------

class TestScenarioValidation:
    def test_sync_protocol_rejects_pools(self):
        with pytest.raises(ValueError, match="num_devices"):
            build(Scenario(name="x", protocol="mpcp", num_devices=2,
                           taskset=dict(num_cores=2, num_tasks=(3, 5))))

    def test_fault_replay_needs_server_protocol(self):
        with pytest.raises(ValueError, match="cannot kill"):
            Scenario(name="x", num_faults=1)  # 1 fault on 1 device

    def test_measured_etm_requires_cost_model(self):
        scn = Scenario(name="x", etm="measured",
                       taskset=dict(num_cores=2, num_tasks=(3, 5)))
        with pytest.raises(ValueError, match="StepCostModel"):
            build(scn)
