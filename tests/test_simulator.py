"""Simulator semantics tests, including the paper's Figure 2 / Figure 4
worked example reproduced event-for-event."""

import pytest

from repro.core import simulator
from repro.core.task_model import GpuSegment, System, Task


def _example_system(eps: float) -> System:
    tau_h = Task("tau_h", C=2, T=100, D=100, priority=3, core=1,
                 segments=(GpuSegment(e=1.0, m=2.0),))
    tau_m = Task("tau_m", C=2, T=100, D=100, priority=2, core=1,
                 segments=(GpuSegment(e=1.0, m=2.0),))
    tau_l = Task("tau_l", C=2, T=100, D=100, priority=1, core=2,
                 segments=(GpuSegment(e=2.0, m=2.0),))
    return System(tasks=[tau_h, tau_m, tau_l], num_cores=3, epsilon=eps, server_core=1)


OFFSETS = {"tau_l": 0.0, "tau_m": 2.0, "tau_h": 3.0}
SPLITS = {t: [1.0, 1.0] for t in OFFSETS}


class TestFigure2_MPCP:
    def test_response_times(self):
        """Figure 2: tau_h's response time is exactly 9 under MPCP."""
        sys_ = _example_system(0.0)
        res = simulator.simulate(sys_, mode="mpcp", horizon_ms=50,
                                 splits=SPLITS, offsets=OFFSETS)
        assert res.wcrt("tau_h") == pytest.approx(9.0, abs=1e-6)
        # tau_l holds the GPU first (requests at t=1, free): gcs [1,5],
        # finishes chunk2 [5,6] -> RT 6
        assert res.wcrt("tau_l") == pytest.approx(6.0, abs=1e-6)
        # tau_m: acquires at 8, gcs [8,11], chunk2 [12,13] after tau_h's
        # chunk2 [11,12] (tau_h has higher priority) -> RT 11
        assert res.wcrt("tau_m") == pytest.approx(11.0, abs=1e-6)

    def test_fifo_changes_grant_order(self):
        """Under FMLP+ (FIFO), tau_m requested before tau_h, so tau_m is
        granted first."""
        sys_ = _example_system(0.0)
        res = simulator.simulate(sys_, mode="fmlp", horizon_ms=50,
                                 splits=SPLITS, offsets=OFFSETS)
        # tau_m: gcs [5,8]; tau_h: gcs [8,11] (boosted, preempts tau_m's
        # chunk2), tau_h chunk2 [11,12], tau_m chunk2 [12,13] -> RT 11
        assert res.wcrt("tau_h") == pytest.approx(9.0, abs=1e-6)
        assert res.wcrt("tau_m") == pytest.approx(11.0, abs=1e-6)


class TestFigure4_Server:
    def test_response_time_6_plus_4eps(self):
        """Figure 4: tau_h's response time is exactly 6 + 4*eps under the
        server approach.  The example's GPU segments carry two misc
        sub-segments of ~eps each (m = 2*eps), so the 4 eps delays to tau_h
        are: receive of tau_m's request at t=3; notify-tau_l before tau_h's
        segment start (5+2eps); notify-tau_h (8+3eps); and the first misc
        sub-segment of tau_m's chained segment (8+4eps)."""
        eps = 0.05
        m = 2 * eps
        tau_h = Task("tau_h", C=2, T=100, D=100, priority=3, core=1,
                     segments=(GpuSegment(e=3.0 - m, m=m),))
        tau_m = Task("tau_m", C=2, T=100, D=100, priority=2, core=1,
                     segments=(GpuSegment(e=3.0 - m, m=m),))
        tau_l = Task("tau_l", C=2, T=100, D=100, priority=1, core=2,
                     segments=(GpuSegment(e=4.0 - m, m=m),))
        sys_ = System(tasks=[tau_h, tau_m, tau_l], num_cores=3,
                      epsilon=eps, server_core=1)
        res = simulator.simulate(sys_, mode="server", horizon_ms=60,
                                 splits=SPLITS, offsets=OFFSETS)
        assert res.wcrt("tau_h") == pytest.approx(6 + 4 * eps, abs=1e-6)

    def test_small_eps_beats_mpcp(self):
        """The paper's conclusion for this taskset: server beats sync if
        eps < 3/4."""
        eps = 0.05
        sys_ = _example_system(eps)
        r_server = simulator.simulate(sys_, mode="server", horizon_ms=60,
                                      splits=SPLITS, offsets=OFFSETS)
        sys0 = _example_system(0.0)
        r_mpcp = simulator.simulate(sys0, mode="mpcp", horizon_ms=60,
                                    splits=SPLITS, offsets=OFFSETS)
        assert r_server.wcrt("tau_h") < r_mpcp.wcrt("tau_h")

    def test_client_does_not_consume_cpu_during_gpu(self):
        """Server mode: while tau_l's segment runs on the GPU, core 2 must be
        free (tau_l suspended) — verified via the execution trace."""
        eps = 0.05
        sys_ = _example_system(eps)
        res = simulator.simulate(sys_, mode="server", horizon_ms=60, trace=True,
                                 splits=SPLITS, offsets=OFFSETS)
        core2_busy = sum(s.end_ms - s.start_ms for s in res.trace if s.core == 2)
        assert core2_busy == pytest.approx(2.0, abs=1e-6)  # just tau_l's C

    def test_mpcp_busy_waits(self):
        sys_ = _example_system(0.0)
        res = simulator.simulate(sys_, mode="mpcp", horizon_ms=60, trace=True,
                                 splits=SPLITS, offsets=OFFSETS)
        core2_busy = sum(s.end_ms - s.start_ms for s in res.trace if s.core == 2)
        assert core2_busy == pytest.approx(2.0 + 4.0, abs=1e-6)  # C + busy-wait G


class TestPeriodicReleases:
    def test_multiple_jobs(self):
        t = Task("t", C=1, T=10, D=10, priority=1, core=0,
                 segments=(GpuSegment(e=1.0, m=0.2),))
        sys_ = System(tasks=[t], num_cores=2, epsilon=0.05, server_core=1)
        res = simulator.simulate(sys_, mode="server", horizon_ms=100)
        assert len(res.response_times["t"]) == 10
        assert not res.any_miss
