"""Live KV-block migration: manager corners, engine end-to-end (steal /
consolidate / elastic scale), the remove()-vs-migration race, and the
static-pricing feed.

Every end-to-end case holds the tentpole's two invariants: greedy tokens
are BIT-IDENTICAL to a never-migrated run, and nothing leaks — after the
streams drain, ``kv_blocks_in_use()`` is 0 and every surviving server has
all its slots free.
"""

import threading
import time

import numpy as np
import pytest

import jax

from repro.analysis.cost_model import StepCostModel, hlo_cell_features
from repro.configs.registry import get_config
from repro.core.faults import StreamShedError
from repro.models import model as M
from repro.runtime.elastic import ElasticPoolController, LoadTrajectory
from repro.serving.engine import ServeEngine, StreamSpec
from repro.serving.kvcache import (OutOfBlocksError, PagedKVCacheManager,
                                   SeqExport)

STEPS = 6


# -------------------------------------------------------------------------
# manager-level corners (no device work)
# -------------------------------------------------------------------------


class TestManagerMigration:
    def test_export_import_roundtrip_across_pools(self):
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        b = PagedKVCacheManager(num_blocks=8, block_size=4)
        a.allocate("s#0", 6)  # 2 blocks
        a.extend("s#0", 3)  # 3rd block
        exp = a.export_seq("s#0")
        assert exp.blocks == tuple(a.seqs["s#0"].blocks)
        new = b.import_seq(exp)
        assert len(new) == len(exp.blocks)
        assert b.length("s#0") == a.length("s#0") == 9
        # export is a pure read: source untouched until the engine commits
        assert a.blocks_in_use == 3 and b.blocks_in_use == 3
        a.free_seq("s#0")
        b.free_seq("s#0")
        assert a.blocks_in_use == 0 and b.blocks_in_use == 0

    def test_import_preserves_reservation_padding(self):
        """A mid-generation move keeps blocks the source reserved beyond
        the current length — the destination table must not shrink."""
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        b = PagedKVCacheManager(num_blocks=8, block_size=4)
        a.allocate("s#0", 3)
        a.extend("s#0", 8)  # reserve ahead: 3 blocks for 11 tokens
        n_src = len(a.seqs["s#0"].blocks)
        new = b.import_seq(a.export_seq("s#0"))
        assert len(new) == n_src

    def test_cow_forked_stream_migrates_privately(self):
        """Migrating one side of a COW fork: the mover gets PRIVATE blocks
        on the destination; the stay-behind sibling and the shared
        refcounts on the source are untouched."""
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        b = PagedKVCacheManager(num_blocks=8, block_size=4)
        a.allocate("base#0", 8)  # 2 blocks
        a.fork("base#0", "fork#0")
        shared = list(a.seqs["base#0"].blocks)
        assert all(a.refcount[blk] == 2 for blk in shared)
        new = b.import_seq(a.export_seq("fork#0"))
        assert set(new).isdisjoint(shared) or True  # different pools anyway
        assert all(b.refcount[blk] == 1 for blk in new)
        # commit: free the source side of the fork only
        a.free_seq("fork#0")
        assert all(a.refcount[blk] == 1 for blk in shared)
        assert a.seqs["base#0"].blocks == shared
        # destination extend never touches the source's sibling
        b.extend("fork#0", 4)
        assert a.length("base#0") == 8

    def test_import_exhaustion_is_all_or_nothing(self):
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        b = PagedKVCacheManager(num_blocks=2, block_size=4)
        a.allocate("s#0", 12)  # 3 blocks > b's pool
        free_before = list(b.free)
        with pytest.raises(OutOfBlocksError):
            b.import_seq(a.export_seq("s#0"))
        assert b.free == free_before and "s#0" not in b.seqs
        assert b.blocks_in_use == 0

    def test_import_duplicate_id_rejected(self):
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        b = PagedKVCacheManager(num_blocks=8, block_size=4)
        a.allocate("s#0", 4)
        b.allocate("s#0", 4)
        with pytest.raises(ValueError, match="already allocated"):
            b.import_seq(a.export_seq("s#0"))

    def test_mid_extend_exhaustion_after_migration_leaks_nothing(self):
        """The imported sequence keeps extending on the destination; when
        THAT pool runs dry mid-extend, freeing the sequence returns every
        block — including any appended before the exhaustion raised."""
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        b = PagedKVCacheManager(num_blocks=3, block_size=4)
        a.allocate("s#0", 8)  # 2 blocks
        b.import_seq(a.export_seq("s#0"))
        with pytest.raises(OutOfBlocksError):
            b.extend("s#0", 4 * 4)  # needs 4 more blocks, only 1 free
        b.free_seq("s#0")
        assert b.blocks_in_use == 0

    def test_export_unknown_seq_raises(self):
        a = PagedKVCacheManager(num_blocks=4, block_size=4)
        with pytest.raises(KeyError):
            a.export_seq("nope#0")

    def test_exported_snapshot_is_immutable(self):
        a = PagedKVCacheManager(num_blocks=8, block_size=4)
        a.allocate("s#0", 4)
        exp = a.export_seq("s#0")
        assert isinstance(exp, SeqExport)
        a.extend("s#0", 8)
        assert len(exp.blocks) == 1  # snapshot taken before the extend


FAMILY_POOLS = {  # family -> (num_blocks, num_slabs, num_segments)
    "gqa": (8, 0, 0),
    "mla": (8, 0, 0),
    "ssm": (0, 4, 0),
    "hybrid": (8, 4, 0),
    "encdec": (8, 0, 3),
}


def _mgr(family, *, blocks=None, slabs=None, segments=None):
    nb, ns, ng = FAMILY_POOLS[family]
    return PagedKVCacheManager(
        num_blocks=blocks if blocks is not None else nb, block_size=4,
        num_slabs=slabs if slabs is not None else ns,
        num_segments=segments if segments is not None else ng,
        family=family)


class TestManagerMigrationAllFamilies:
    """Satellite: export/import round-trip properties for EVERY cache
    family — reservation pads preserved, COW siblings untouched, imports
    all-or-nothing across every pool kind."""

    @pytest.mark.parametrize("family", list(FAMILY_POOLS))
    def test_roundtrip_preserves_shape_and_drains_clean(self, family):
        a, b = _mgr(family), _mgr(family)
        a.allocate("s#0", 6, segment_key="frames")
        a.extend("s#0", 5)  # reservation padding rides along for block kinds
        exp = a.export_seq("s#0")
        new = b.import_seq(exp)
        assert len(new) == len(exp.blocks)
        assert b.length("s#0") == a.length("s#0") == 11
        assert (b.slab("s#0") is not None) == a.family.uses_slab
        assert (b.segment("s#0") is not None) == a.family.uses_segment
        if a.family.uses_segment:
            assert b.seqs["s#0"].segment_key == "frames"
        a.free_seq("s#0")
        b.free_seq("s#0")
        for mgr in (a, b):
            assert mgr.usage() == {"blocks": 0, "slabs": 0, "segments": 0}

    @pytest.mark.parametrize("family", ["gqa", "mla", "hybrid", "encdec"])
    def test_cow_sibling_untouched_by_migration(self, family):
        a, b = _mgr(family), _mgr(family)
        a.allocate("base#0", 8, segment_key="frames")
        a.fork("base#0", "fork#0")
        shared = list(a.seqs["base#0"].blocks)
        assert all(a.refcount[blk] == 2 for blk in shared)
        b.import_seq(a.export_seq("fork#0"))
        a.free_seq("fork#0")  # commit: source side of the fork only
        assert all(a.refcount[blk] == 1 for blk in shared)
        assert a.seqs["base#0"].blocks == shared
        b.extend("fork#0", 4)
        assert a.length("base#0") == 8

    def test_slab_import_gets_fresh_slab(self):
        a, b = _mgr("ssm"), _mgr("ssm")
        a.allocate("s#0", 6)
        b.allocate("other#0", 3)  # occupies a slab on the destination
        taken = b.slab("other#0")
        b.import_seq(a.export_seq("s#0"))
        assert b.slab("s#0") is not None and b.slab("s#0") != taken
        # the source slab stays live until the engine's commit free
        assert a.slab("s#0") is not None

    def test_segment_import_joins_resident_key(self):
        a, b = _mgr("encdec"), _mgr("encdec")
        a.allocate("s#0", 4, segment_key="frames")
        b.allocate("t#0", 4, segment_key="frames")
        seg = b.segment("t#0")
        b.import_seq(a.export_seq("s#0"))
        assert b.segment("s#0") == seg  # joined, not re-allocated
        assert b.segment_refcount[seg] == 2
        b.free_seq("t#0")
        assert b.segment_refcount[seg] == 1  # mover still holds it
        b.free_seq("s#0")
        assert b.segments_in_use == 0

    @pytest.mark.parametrize("family,short", [
        ("gqa", dict(blocks=2)),
        ("ssm", dict(slabs=0)),
        ("hybrid", dict(slabs=0)),
        ("encdec", dict(segments=0)),
    ])
    def test_import_exhaustion_all_or_nothing_per_kind(self, family, short):
        a, b = _mgr(family), _mgr(family, **short)
        a.allocate("s#0", 12, segment_key="frames")
        before = b.usage()
        with pytest.raises(OutOfBlocksError):
            b.import_seq(a.export_seq("s#0"))
        assert b.usage() == before and "s#0" not in b.seqs


# -------------------------------------------------------------------------
# engine end-to-end
# -------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _spec(name, prio, steps=STEPS):
    return StreamSpec(name=name, priority=prio, period_ms=8000.0,
                      deadline_ms=8000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _reference_tokens(cfg, params, prompt, steps=STEPS):
    eng = ServeEngine(cfg, params, max_seq=32)
    try:
        assert eng.admit(_spec("ref", 1, steps=steps)).admitted
        return eng.generate("ref", prompt, steps=steps).tokens
    finally:
        eng.close()


def _engine(cfg, params, *, num_servers=2, max_batch=4):
    return ServeEngine(cfg, params, max_seq=32, num_servers=num_servers,
                       batching=True, max_batch=max_batch, paged=True,
                       kv_block_size=8)


def _run_streams(eng, prompts, steps=STEPS):
    out = {}

    def worker(n):
        try:
            out[n] = eng.generate(n, prompts[n], steps=steps)
        except Exception as e:  # noqa: BLE001 - recorded for assertions
            out[n] = e

    threads = [threading.Thread(target=worker, args=(n,)) for n in prompts]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return out


def _assert_no_leaks(eng):
    assert eng.kv_blocks_in_use() == 0
    for si in eng.pool.alive_servers():
        assert len(eng._slots[si].free) == eng.max_batch


class TestEngineMigration:
    def test_manual_migration_bit_identical(self, setup):
        """A migration intent placed before the run moves the stream's
        blocks mid-decode; tokens match the never-migrated reference and
        nothing leaks on either server."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4, 5, 6, 7, 8]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        eng = _engine(cfg, params)
        try:
            assert eng.admit(_spec("s0", 1)).admitted
            src = eng.pool.server_of("s0")
            dst = 1 - src
            decision, d = eng.admission.migrate("s0", dst)
            assert decision.admitted and d == dst
            assert eng.pool.request_migration("s0", dst)
            res = eng.generate("s0", prompt, steps=STEPS)
            assert res.tokens == want
            assert eng.migrations_completed == 1
            assert eng.pool.server_of("s0") == dst
            assert eng.admission.device_of("s0") == dst
            _assert_no_leaks(eng)
            # the moved stream keeps serving from the destination
            assert eng.generate("s0", prompt, steps=STEPS).tokens == want
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_work_stealing_rebalances_live(self, setup):
        """All streams pinned on one server, the other idle: a rebalance
        pass steals at least one mid-flight stream, tokens stay exact, and
        the ledger drains to zero."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        steps = 12
        want = _reference_tokens(cfg, params, prompt, steps=steps)
        eng = _engine(cfg, params)
        try:
            names = [f"s{i}" for i in range(3)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, i + 1, steps=steps)).admitted
            for n in names:  # pin everything onto server 0
                if eng.admission.device_of(n) != 0:
                    assert eng.admission.migrate(n, 0)[1] == 0
                eng.pool.reassign(n, 0, priority=eng._streams[n].priority)
            out = {}

            def worker(n):
                out[n] = eng.generate(n, prompt, steps=steps)

            threads = [threading.Thread(target=worker, args=(n,))
                       for n in names]
            for t in threads:
                t.start()
            deadline = time.monotonic() + 10
            while (len(eng._active_jobs) < len(names)
                   and time.monotonic() < deadline):
                time.sleep(0.001)
            stolen = eng.rebalance_once()
            for t in threads:
                t.join()
            assert stolen >= 1
            assert eng.migrations_completed >= 1
            for n in names:
                assert out[n].tokens == want, n
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_steal_loop_under_fault_tolerance_tick(self, setup):
        """enable_work_stealing piggybacks on the heartbeat tick when fault
        tolerance is on; a full concurrent run stays bit-identical and
        leak-free."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        eng = _engine(cfg, params)
        eng.enable_fault_tolerance(heartbeat_timeout_s=30.0, poll_s=0.005)
        eng.enable_work_stealing()
        assert eng.pool._monitor.on_tick is not None
        try:
            names = [f"s{i}" for i in range(4)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, i + 1)).admitted
            out = _run_streams(eng, {n: prompt for n in names})
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_remove_race_frees_both_sides_once(self, setup):
        """Deterministic replay of the remove()-during-migration race: the
        stream is removed while the gather is in flight.  remove() frees
        BOTH ledger sides; the migration's commit must observe the empty
        ledger and raise instead of double-freeing."""
        cfg, params = setup
        eng = _engine(cfg, params)
        try:
            assert eng.admit(_spec("s0", 1)).admitted
            seq_id, _, _, _ = eng._paged_reserve(0, "s0", 4, STEPS, 8)
            assert eng.kv_blocks_in_use() > 0
            src = eng._paged[0]
            src.pools = M.init_paged_cache(cfg, src.mgr.num_blocks,
                                           src.mgr.block_size)
            real_export = eng._export_kv
            fired = []

            def export_and_remove(pools, table, slab, seg):
                packed = real_export(pools, table, slab, seg)
                if not fired:
                    fired.append(True)
                    eng.remove("s0")  # lands mid-copy, before commit
                return packed

            eng._export_kv = export_and_remove
            with pytest.raises(StreamShedError, match="removed"):
                eng._execute_migration("s0", seq_id, 0, 1, 0)
            assert fired
            assert eng.kv_blocks_in_use() == 0  # freed once, by remove()
            assert eng.migrations_completed == 0
        finally:
            eng._export_kv = real_export
            eng.close()

    def test_migration_to_full_destination_aborts_clean(self, setup):
        """Destination pool exhaustion aborts the move all-or-nothing: the
        stream keeps its source blocks and the destination stays empty."""
        cfg, params = setup
        eng = _engine(cfg, params)
        try:
            assert eng.admit(_spec("s0", 1)).admitted
            seq_id, _, _, _ = eng._paged_reserve(0, "s0", 4, STEPS, 8)
            src = eng._paged[0]
            src.pools = M.init_paged_cache(cfg, src.mgr.num_blocks,
                                           src.mgr.block_size)
            src_used = src.mgr.blocks_in_use
            dst = eng._paged[1]
            hog = dst.mgr.allocate("hog#0", dst.mgr.num_blocks
                                   * dst.mgr.block_size - dst.mgr.block_size)
            assert hog
            with pytest.raises(OutOfBlocksError):
                eng._execute_migration("s0", seq_id, 0, 1, 0)
            assert eng._paged[0].mgr.blocks_in_use == src_used
            assert seq_id not in dst.mgr.seqs
            dst.mgr.free_seq("hog#0")
            eng._paged_release(0, seq_id)
            eng.remove("s0")
            assert eng.kv_blocks_in_use() == 0
        finally:
            eng.close()


class TestElastic:
    def test_consolidate_then_remove_server(self, setup):
        """Scale-down end-to-end: grow to 3 servers, consolidate server 0,
        retire it, and keep serving bit-identically from the survivors."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4, 5, 6, 7, 8]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        eng = _engine(cfg, params)
        try:
            names = [f"s{i}" for i in range(3)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, i + 1)).admitted
            si = eng.add_server()
            assert si == 2
            assert set(eng.pool.alive_servers()) == {0, 1, 2}
            on0 = eng.pool.streams_on(0)
            moved = eng.consolidate(0)
            assert set(moved) == set(on0)
            assert all(d != 0 for d in moved.values())
            eng.remove_server(0, timeout_s=10.0)
            assert 0 not in eng.pool.alive_servers()
            assert len(eng.degraded_reports) == 1
            assert not eng.degraded_reports[0].shed  # idle pool: all moved
            out = _run_streams(eng, {n: prompt for n in names})
            for n in names:
                assert not isinstance(out[n], Exception), out[n]
                assert out[n].tokens == want, n
            _assert_no_leaks(eng)
        finally:
            eng.close()

    def test_elastic_controller_ramp(self, setup):
        """LoadTrajectory drives scale_to up and down; streams admitted at
        any pool size keep generating the reference tokens throughout."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        eng = _engine(cfg, params)
        try:
            assert eng.admit(_spec("s0", 1)).admitted
            ctl = ElasticPoolController(eng, min_servers=1, max_servers=4)
            traj = LoadTrajectory(((0.0, 2), (1.0, 4), (2.0, 2)))
            assert traj.target_at(0.0) == 2
            assert traj.target_at(1.5) == 4
            assert traj.target_at(99.0) == 2
            assert len(ctl.live()) == 2
            ctl.scale_to(traj.target_at(1.5))
            assert len(ctl.live()) == 4
            assert eng.generate("s0", prompt, steps=STEPS).tokens == want
            ctl.scale_to(traj.target_at(2.0))
            assert len(ctl.live()) == 2
            assert eng.generate("s0", prompt, steps=STEPS).tokens == want
            _assert_no_leaks(eng)
            assert [e[0] for e in ctl.events].count("add") == 2
            assert [e[0] for e in ctl.events].count("remove") == 2
        finally:
            eng.close()

    def test_added_server_participates_in_admission(self, setup):
        """add_server grows the admission partition in lockstep: a stream
        that no longer fits the old pool is provable on the new device."""
        cfg, params = setup
        eng = _engine(cfg, params, num_servers=1, max_batch=2)
        try:
            # saturate the single device
            admitted = []
            for i in range(64):
                spec = StreamSpec(name=f"s{i}", priority=1, period_ms=100.0,
                                  deadline_ms=100.0, prefill_ms=20.0,
                                  decode_ms=5.0, decode_steps=4)
                if not eng.admit(spec).admitted:
                    break
                admitted.append(spec.name)
            else:
                pytest.fail("single device never saturated")
            reject = StreamSpec(name="late", priority=1, period_ms=100.0,
                                deadline_ms=100.0, prefill_ms=20.0,
                                decode_ms=5.0, decode_steps=4)
            assert not eng.admit(reject).admitted
            eng.add_server()
            d = eng.admit(reject)
            assert d.admitted
            assert eng.admission.device_of("late") == 1
            assert eng.pool.server_of("late") == 1
        finally:
            eng.close()


class TestStaticPricing:
    def test_static_costs_feed_unseen_migrate_cells(self, setup):
        """hlo_cost static pricing lets the cost model price a migration
        width it never measured: observe ONE migrate cell, predict another
        — finite, positive, and monotone in width."""
        cfg, params = setup
        eng = _engine(cfg, params, num_servers=1)
        try:
            costs = eng.static_cell_costs()
            assert costs  # one entry per width bucket
            assert all(k[0] == "migrate" for k in costs)
            assert all(f >= 0 and b > 0 for f, b in costs.values())
            widths = sorted(k[1] for k in costs)
            by_w = {k[1]: v for k, v in costs.items()}
            for lo, hi in zip(widths, widths[1:]):
                assert by_w[hi][1] >= by_w[lo][1]  # bytes grow with width
            model = StepCostModel(work=hlo_cell_features(costs))
            w_seen, w_unseen = widths[-1], widths[0]
            model.observe(("migrate", w_seen, eng.kv_block_size), 4e-3)
            pred = model.predict("migrate", w_unseen, eng.kv_block_size)
            import math
            assert math.isfinite(pred) and pred > 0
        finally:
            eng.close()

    def test_static_costs_price_decode_and_prefill_cells(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, num_servers=1)
        try:
            costs = eng.static_cell_costs(
                [("decode", 2, 4), ("prefill", 1, 8)])
            assert costs[("decode", 2, 4)][0] > 0  # decode does real math
            assert costs[("prefill", 1, 8)][0] > 0
        finally:
            eng.close()
