"""Property-based soundness tests: for randomly generated tasksets, the
analysis bound must dominate the simulated response time, under all three
protocols.  This is the validation strategy DESIGN.md §4 commits to."""

import math
import random

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import fmlp_analysis, mpcp_analysis, server_analysis, simulator
from repro.core.allocation import allocate
from repro.core.taskset_gen import GenParams, generate_taskset

_SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _make_system(seed: int, approach: str):
    rng = random.Random(seed)
    params = GenParams(num_cores=2, num_tasks=(3, 6), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    return allocate(tasks, params.num_cores, approach=approach, epsilon=params.epsilon_ms)


def _horizon(system) -> float:
    return 3.0 * max(t.T for t in system.tasks)


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_server_analysis_dominates_simulation(seed):
    system = _make_system(seed, "server")
    res = server_analysis.analyze(system)
    sim = simulator.simulate(system, mode="server", horizon_ms=_horizon(system))
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (  # ns quantization in the simulator
                f"{t.name}: simulated {observed} > analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_mpcp_analysis_dominates_simulation(seed):
    system = _make_system(seed, "sync")
    res = mpcp_analysis.analyze(system)
    sim = simulator.simulate(system, mode="mpcp", horizon_ms=_horizon(system))
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (  # ns quantization in the simulator
                f"{t.name}: simulated {observed} > analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_fmlp_analysis_dominates_simulation(seed):
    system = _make_system(seed, "sync")
    res = fmlp_analysis.analyze(system)
    sim = simulator.simulate(system, mode="fmlp", horizon_ms=_horizon(system))
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (  # ns quantization in the simulator
                f"{t.name}: simulated {observed} > analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_schedulable_means_no_misses_in_simulation(seed):
    """If the server-based analysis says schedulable, the simulation must not
    miss a deadline (necessary condition for analysis soundness)."""
    system = _make_system(seed, "server")
    res = server_analysis.analyze(system)
    if not res.schedulable:
        return
    sim = simulator.simulate(system, mode="server", horizon_ms=_horizon(system))
    assert not sim.any_miss


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_double_bound_never_exceeds_request_driven(seed):
    """Eq (2): min(B^rd, B^jd) <= B^rd — the improved analysis can only
    tighten the original (conference-version) request-driven-only bound."""
    system = _make_system(seed, "server")
    for t in system.tasks:
        if not t.uses_gpu:
            continue
        rd = server_analysis.request_driven_bound(system, t, horizon=t.D)
        total_rd = t.eta * rd if not math.isinf(rd) else math.inf
        w = server_analysis.waiting_bound(system, t, t.D, horizon=t.D)
        assert w <= total_rd + 1e-9


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_epsilon_monotonicity(seed):
    """Response-time bounds are monotonically non-decreasing in eps."""
    rng = random.Random(seed)
    params = GenParams(num_cores=2, num_tasks=(3, 6))
    tasks = generate_taskset(params, rng)
    prev = None
    for eps in (0.0, 0.05, 0.5):
        system = allocate(tasks, 2, approach="server", epsilon=eps, heuristic="wfd")
        res = server_analysis.analyze(system)
        total = sum(
            min(res.wcrt(t.name), 10 * t.D) for t in system.tasks
        )
        if prev is not None:
            # allocation may shift with eps; compare only when placement agrees
            if [t.core for t in system.tasks] == prev[1]:
                assert total >= prev[0] - 1e-6
        prev = (total, [t.core for t in system.tasks])
