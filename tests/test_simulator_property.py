"""Property-based soundness tests: for randomly generated tasksets, the
analysis bound must dominate the simulated response time, under all three
protocols.  This is the validation strategy DESIGN.md §4 commits to.

``hypothesis`` is optional: when it is not installed, ``given(seed=...)``
degrades to a deterministic sweep over a fixed seed list (same property,
fixed sampling), so the tier-1 command collects and runs everywhere.
"""

import math
import random

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    _SETTINGS = dict(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
except ImportError:  # deterministic fallback sampler
    _FALLBACK_SEEDS = list(range(0, 10_000, 401))  # 25 seeds, like max_examples

    def given(**kwargs):
        names = sorted(kwargs)
        if names != ["seed"]:
            raise NotImplementedError(f"fallback only supports seed=, got {names}")
        return pytest.mark.parametrize("seed", _FALLBACK_SEEDS)

    def settings(**_kwargs):
        return lambda f: f

    class _IntRange:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

    class st:  # noqa: N801 - mimics hypothesis.strategies
        integers = staticmethod(_IntRange)

    _SETTINGS = {}

from repro.core import fmlp_analysis, mpcp_analysis, server_analysis, simulator
from repro.core.allocation import allocate, allocate_pool
from repro.core.faults import seeded_device_faults
from repro.core.migration import seeded_stream_migrations
from repro.core.taskset_gen import GenParams, generate_taskset


def _make_system(seed: int, approach: str):
    rng = random.Random(seed)
    params = GenParams(num_cores=2, num_tasks=(3, 6), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    return allocate(tasks, params.num_cores, approach=approach, epsilon=params.epsilon_ms)


def _horizon(system) -> float:
    return 3.0 * max(t.T for t in system.tasks)


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_server_analysis_dominates_simulation(seed):
    system = _make_system(seed, "server")
    res = server_analysis.analyze(system)
    sim = simulator.simulate(system, mode="server", horizon_ms=_horizon(system))
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (  # ns quantization in the simulator
                f"{t.name}: simulated {observed} > analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_mpcp_analysis_dominates_simulation(seed):
    system = _make_system(seed, "sync")
    res = mpcp_analysis.analyze(system)
    sim = simulator.simulate(system, mode="mpcp", horizon_ms=_horizon(system))
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (  # ns quantization in the simulator
                f"{t.name}: simulated {observed} > analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_fmlp_analysis_dominates_simulation(seed):
    system = _make_system(seed, "sync")
    res = fmlp_analysis.analyze(system)
    sim = simulator.simulate(system, mode="fmlp", horizon_ms=_horizon(system))
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (  # ns quantization in the simulator
                f"{t.name}: simulated {observed} > analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_pool_analysis_dominates_batched_simulation(seed):
    """Per-server analysis (Eqs (1)-(6) within each device partition) must
    dominate the simulated WCRT under the batched multi-accelerator
    dispatcher: batching only coalesces same-shape requests into the head's
    device call, so the per-request bound stays sound."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 10), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate_pool(tasks, 2, 2, epsilon=params.epsilon_ms)
    res = server_analysis.analyze_pool(system)
    sim = simulator.simulate(system, mode="server_batched",
                             horizon_ms=_horizon(system), batch_max=4)
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (
                f"{t.name} (device {t.device}): simulated {observed} > "
                f"pool analysis bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_pool_analysis_dominates_under_bucketed_coalescing(seed):
    """Length-bucketed prefill keys and slot compaction only NARROW which
    requests may coalesce (the simulator's exact-signature rule is already
    the strictest bucketing; smaller batch_max models fewer same-bucket
    peers).  The per-request analysis bound never credits coalescing, so it
    must dominate at EVERY coalescing width, down to none at all."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 10), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate_pool(tasks, 2, 2, epsilon=params.epsilon_ms)
    res = server_analysis.analyze_pool(system)
    for batch_max in (1, 2, 4):
        sim = simulator.simulate(system, mode="server_batched",
                                 horizon_ms=_horizon(system),
                                 batch_max=batch_max)
        for t in system.tasks:
            bound = res.wcrt(t.name)
            if not math.isinf(bound):
                assert sim.wcrt(t.name) <= bound + 1e-3, (
                    f"{t.name} (batch_max={batch_max}): simulated "
                    f"{sim.wcrt(t.name)} > pool analysis bound {bound}"
                )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_faulted_analysis_dominates_simulation_under_failures(seed):
    """Recovery-augmented bound soundness: under a seeded device-fault
    schedule (device dies mid-traffic, tasks migrate to the failover target
    after the detection gap, each re-submitting with its recovery segment
    folded in), the per-task bound of ``analyze_pool_under_faults`` —
    sum of per-phase Eqs (1)-(6) bounds plus detection gaps — must dominate
    the simulated WCRT of the batched dispatcher replaying the SAME
    schedule.  The simulator deliberately under-approximates the analysis's
    failure model (recovery folded into the re-submitted segment, no extra
    server invocation), so domination is required, not lucky."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 10), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate_pool(tasks, 3, 2, epsilon=params.epsilon_ms)
    horizon = _horizon(system)
    faults = seeded_device_faults(system, seed, num_faults=1,
                                  horizon_ms=horizon, detect_ms=1.0)
    res = server_analysis.analyze_pool_under_faults(system, faults)
    sim = simulator.simulate(system, mode="server_batched",
                             horizon_ms=horizon, batch_max=4, faults=faults)
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (
                f"{t.name} (device {t.device}, faults {faults}): simulated "
                f"{observed} > recovery-augmented bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_faulted_bound_dominates_fault_free_phase(seed):
    """The recovery-augmented bound can only grow: for every task it is >=
    the fault-free phase-0 bound, and the excess is exactly the reported
    per-task recovery delay."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 10), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate_pool(tasks, 3, 2, epsilon=params.epsilon_ms)
    faults = seeded_device_faults(system, seed, num_faults=2,
                                  horizon_ms=_horizon(system), detect_ms=2.0)
    res = server_analysis.analyze_pool_under_faults(system, faults)
    base = server_analysis.analyze_pool(system)
    for t in system.tasks:
        b0, bf = base.wcrt(t.name), res.wcrt(t.name)
        if math.isinf(b0) or math.isinf(bf):
            continue
        assert bf >= b0 - 1e-9
        assert abs((bf - b0) - res.recovery_delay[t.name]) <= 1e-6


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_migrated_analysis_dominates_simulation_under_migrations(seed):
    """Migration-delay-augmented bound soundness: under a seeded planned-
    migration schedule (work stealing / consolidation — tasks move to
    other devices mid-traffic, each paying a one-time block-copy segment),
    the per-task bound of ``analyze_pool_under_migrations`` — sum of
    per-phase Eqs (1)-(6) bounds, NO detection gap — must dominate the
    simulated WCRT replaying the SAME schedule.  The simulator charges the
    copy cost once on the first post-move job while the analysis keeps the
    segment in every later phase, so domination is structural, not lucky."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 10), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate_pool(tasks, 3, 2, epsilon=params.epsilon_ms)
    horizon = _horizon(system)
    migrations = seeded_stream_migrations(system, seed, num_migrations=2,
                                          horizon_ms=horizon)
    res = server_analysis.analyze_pool_under_migrations(system, migrations)
    sim = simulator.simulate(system, mode="server_batched",
                             horizon_ms=horizon, batch_max=4,
                             migrations=migrations)
    for t in system.tasks:
        bound = res.wcrt(t.name)
        observed = sim.wcrt(t.name)
        if not math.isinf(bound):
            assert observed <= bound + 1e-3, (
                f"{t.name} (device {t.device}, migrations {migrations}): "
                f"simulated {observed} > migration-augmented bound {bound}"
            )


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_migrated_bound_dominates_migration_free_phase(seed):
    """The migration-delay-augmented bound can only grow: for every task it
    is >= the migration-free phase-0 bound, and the excess is exactly the
    reported per-task migration delay."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 10), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate_pool(tasks, 3, 2, epsilon=params.epsilon_ms)
    migrations = seeded_stream_migrations(system, seed, num_migrations=3,
                                          horizon_ms=_horizon(system))
    res = server_analysis.analyze_pool_under_migrations(system, migrations)
    base = server_analysis.analyze_pool(system)
    for t in system.tasks:
        b0, bm = base.wcrt(t.name), res.wcrt(t.name)
        if math.isinf(b0) or math.isinf(bm):
            continue
        assert bm >= b0 - 1e-9
        assert abs((bm - b0) - res.migration_delay[t.name]) <= 1e-6


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_batching_never_delays_any_task(seed):
    """Coalescing only lets requests JOIN the head's device call: for the
    same system, every task's batched WCRT is <= its unbatched WCRT."""
    rng = random.Random(seed)
    params = GenParams(num_cores=2, num_tasks=(3, 6), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    system = allocate(tasks, 2, approach="server", epsilon=params.epsilon_ms)
    horizon = _horizon(system)
    unb = simulator.simulate(system, mode="server", horizon_ms=horizon)
    bat = simulator.simulate(system, mode="server_batched",
                             horizon_ms=horizon, batch_max=4)
    for t in system.tasks:
        assert bat.wcrt(t.name) <= unb.wcrt(t.name) + 1e-3


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_schedulable_means_no_misses_in_simulation(seed):
    """If the server-based analysis says schedulable, the simulation must not
    miss a deadline (necessary condition for analysis soundness)."""
    system = _make_system(seed, "server")
    res = server_analysis.analyze(system)
    if not res.schedulable:
        return
    sim = simulator.simulate(system, mode="server", horizon_ms=_horizon(system))
    assert not sim.any_miss


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_double_bound_never_exceeds_request_driven(seed):
    """Eq (2): min(B^rd, B^jd) <= B^rd — the improved analysis can only
    tighten the original (conference-version) request-driven-only bound."""
    system = _make_system(seed, "server")
    for t in system.tasks:
        if not t.uses_gpu:
            continue
        rd = server_analysis.request_driven_bound(system, t, horizon=t.D)
        total_rd = t.eta * rd if not math.isinf(rd) else math.inf
        w = server_analysis.waiting_bound(system, t, t.D, horizon=t.D)
        assert w <= total_rd + 1e-9


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_epsilon_monotonicity(seed):
    """Response-time bounds are monotonically non-decreasing in eps."""
    rng = random.Random(seed)
    params = GenParams(num_cores=2, num_tasks=(3, 6))
    tasks = generate_taskset(params, rng)
    prev = None
    for eps in (0.0, 0.05, 0.5):
        system = allocate(tasks, 2, approach="server", epsilon=eps, heuristic="wfd")
        res = server_analysis.analyze(system)
        total = sum(
            min(res.wcrt(t.name), 10 * t.D) for t in system.tasks
        )
        if prev is not None:
            # allocation may shift with eps; compare only when placement agrees
            if [t.core for t in system.tasks] == prev[1]:
                assert total >= prev[0] - 1e-6
        prev = (total, [t.core for t in system.tasks])
