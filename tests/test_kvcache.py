"""Paged KV-cache manager: allocation, growth, copy-on-write prefix
sharing, exhaustion, and the device-side gather semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kvcache import OutOfBlocksError, PagedKVCacheManager


class TestAllocation:
    def test_blocks_for_lengths(self):
        m = PagedKVCacheManager(num_blocks=16, block_size=4)
        assert len(m.allocate("a", 1)) == 1
        assert len(m.allocate("b", 4)) == 1
        assert len(m.allocate("c", 5)) == 2
        assert m.blocks_in_use == 4

    def test_unique_blocks(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=2)
        blocks = m.allocate("a", 8) + m.allocate("b", 8)
        assert len(set(blocks)) == 8

    def test_exhaustion_raises(self):
        m = PagedKVCacheManager(num_blocks=2, block_size=4)
        m.allocate("a", 8)
        with pytest.raises(OutOfBlocksError):
            m.allocate("b", 1)

    def test_free_recycles(self):
        m = PagedKVCacheManager(num_blocks=2, block_size=4)
        m.allocate("a", 8)
        m.free_seq("a")
        assert m.blocks_in_use == 0
        m.allocate("b", 8)  # must succeed again

    def test_extend_within_block_allocates_nothing(self):
        m = PagedKVCacheManager(num_blocks=4, block_size=4)
        m.allocate("a", 2)
        assert m.extend("a", 1) == []
        assert m.length("a") == 3

    def test_extend_across_block_boundary(self):
        m = PagedKVCacheManager(num_blocks=4, block_size=4)
        m.allocate("a", 4)
        fresh = m.extend("a", 1)
        assert len(fresh) == 1
        assert m.length("a") == 5


class TestPrefixSharing:
    def test_fork_shares_blocks(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 8)
        used = m.blocks_in_use
        m.fork("parent", "child")
        assert m.blocks_in_use == used  # no copies yet

    def test_cow_on_child_write(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 6)  # 2 blocks, last partially filled
        m.fork("parent", "child")
        fresh = m.extend("child", 1)  # writes into the shared tail block
        assert fresh, "shared tail must be forked before write"
        # parent's blocks unchanged
        assert m.block_table("parent", max_blocks=4)[:2] != \
            m.block_table("child", max_blocks=4)[:2] or True
        pt = m.seqs["parent"].blocks
        ct = m.seqs["child"].blocks
        assert pt[0] == ct[0]  # full prefix block still shared
        assert pt[1] != ct[1]  # tail forked

    def test_free_shared_keeps_refcounted_blocks(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 8)
        m.fork("parent", "child")
        m.free_seq("parent")
        # child still holds the blocks
        assert m.blocks_in_use == 2
        m.free_seq("child")
        assert m.blocks_in_use == 0


class TestGatherSemantics:
    def test_block_table_gather_reconstructs_sequence(self):
        """cache[block_table] must reproduce the logically contiguous KV."""
        bs, nkv, hd = 4, 2, 8
        m = PagedKVCacheManager(num_blocks=8, block_size=bs)
        pool = np.zeros((8, bs, nkv, hd), np.float32)
        tokens = np.random.RandomState(0).randn(10, nkv, hd).astype(np.float32)
        m.allocate("s", 10)
        blocks = m.seqs["s"].blocks
        for t in range(10):
            pool[blocks[t // bs], t % bs] = tokens[t]
        table = m.block_table("s", max_blocks=4)
        gathered = pool[np.asarray(table)].reshape(-1, nkv, hd)
        np.testing.assert_array_equal(gathered[:10], tokens)

    def test_table_is_padded(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("s", 4)
        t = m.block_table("s", max_blocks=5)
        assert len(t) == 5
