"""Paged KV-cache manager: allocation, growth, copy-on-write prefix
sharing, exhaustion (incl. mid-extend failure atomicity), fork/free
ordering, concurrent reserve/release, and the device-side gather
semantics."""

import threading

import numpy as np
import pytest

from repro.serving.kvcache import OutOfBlocksError, PagedKVCacheManager


class TestAllocation:
    def test_blocks_for_lengths(self):
        m = PagedKVCacheManager(num_blocks=16, block_size=4)
        assert len(m.allocate("a", 1)) == 1
        assert len(m.allocate("b", 4)) == 1
        assert len(m.allocate("c", 5)) == 2
        assert m.blocks_in_use == 4

    def test_unique_blocks(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=2)
        blocks = m.allocate("a", 8) + m.allocate("b", 8)
        assert len(set(blocks)) == 8

    def test_exhaustion_raises(self):
        m = PagedKVCacheManager(num_blocks=2, block_size=4)
        m.allocate("a", 8)
        with pytest.raises(OutOfBlocksError):
            m.allocate("b", 1)

    def test_free_recycles(self):
        m = PagedKVCacheManager(num_blocks=2, block_size=4)
        m.allocate("a", 8)
        m.free_seq("a")
        assert m.blocks_in_use == 0
        m.allocate("b", 8)  # must succeed again

    def test_extend_within_block_allocates_nothing(self):
        m = PagedKVCacheManager(num_blocks=4, block_size=4)
        m.allocate("a", 2)
        assert m.extend("a", 1) == []
        assert m.length("a") == 3

    def test_extend_across_block_boundary(self):
        m = PagedKVCacheManager(num_blocks=4, block_size=4)
        m.allocate("a", 4)
        fresh = m.extend("a", 1)
        assert len(fresh) == 1
        assert m.length("a") == 5


class TestPrefixSharing:
    def test_fork_shares_blocks(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 8)
        used = m.blocks_in_use
        m.fork("parent", "child")
        assert m.blocks_in_use == used  # no copies yet

    def test_cow_on_child_write(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 6)  # 2 blocks, last partially filled
        m.fork("parent", "child")
        fresh = m.extend("child", 1)  # writes into the shared tail block
        assert fresh, "shared tail must be forked before write"
        # parent's blocks unchanged
        assert m.block_table("parent", max_blocks=4)[:2] != \
            m.block_table("child", max_blocks=4)[:2] or True
        pt = m.seqs["parent"].blocks
        ct = m.seqs["child"].blocks
        assert pt[0] == ct[0]  # full prefix block still shared
        assert pt[1] != ct[1]  # tail forked

    def test_free_shared_keeps_refcounted_blocks(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 8)
        m.fork("parent", "child")
        m.free_seq("parent")
        # child still holds the blocks
        assert m.blocks_in_use == 2
        m.free_seq("child")
        assert m.blocks_in_use == 0


class TestCowRefcountCorners:
    """Copy-on-write / refcount corner cases the serving hot path leans on."""

    def test_fork_then_free_parent_then_extend_child(self):
        """Freeing the parent first must leave the child's view intact AND
        drop the shared refcounts so the child's tail write no longer
        forks (refcount back to 1)."""
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 6)  # 2 blocks, tail half-full
        m.fork("parent", "child")
        m.free_seq("parent")
        assert m.blocks_in_use == 2  # child keeps both
        fresh = m.extend("child", 1)
        assert fresh == []  # sole owner now: in-place append, no COW fork
        m.free_seq("child")
        assert m.blocks_in_use == 0

    def test_fork_then_free_child_then_parent(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("parent", 8)
        m.fork("parent", "child")
        m.extend("child", 1)  # forks the tail + grows
        in_use = m.blocks_in_use
        m.free_seq("child")
        # the forked tail and the growth block return; shared prefix stays
        assert m.blocks_in_use < in_use
        m.free_seq("parent")
        assert m.blocks_in_use == 0
        assert all(r == 0 for r in m.refcount)

    def test_double_fork_refcounts(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("p", 4)
        m.fork("p", "c1")
        m.fork("p", "c2")
        (b,) = m.seqs["p"].blocks
        assert m.refcount[b] == 3
        for s in ("p", "c1", "c2"):
            m.free_seq(s)
        assert m.blocks_in_use == 0

    def test_multi_token_extend_forks_shared_partial_tail(self):
        """Regression: a multi-block extension must STILL fork a shared,
        partially-filled tail — the fork decision happens before fresh
        blocks are appended, not on whatever block ends up last."""
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("p", 6)  # blocks [b0, b1], b1 half-full
        m.fork("p", "c")
        shared_tail = m.seqs["p"].blocks[1]
        fresh = m.extend("c", 3)  # tokens 6-8: 2 into the tail, 1 overflow
        assert len(fresh) == 2  # forked tail + one growth block
        assert m.seqs["c"].blocks[1] != shared_tail  # tail forked
        assert m.seqs["p"].blocks[1] == shared_tail  # parent untouched
        assert m.refcount[shared_tail] == 1

    def test_full_shared_tail_needs_no_fork(self):
        """A block-aligned shared sequence grows into fresh blocks only —
        the shared blocks are never written, so no fork."""
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("p", 8)  # two FULL blocks
        m.fork("p", "c")
        fresh = m.extend("c", 1)
        assert len(fresh) == 1  # growth block only
        assert m.seqs["c"].blocks[:2] == m.seqs["p"].blocks  # still shared

    def test_out_of_blocks_mid_extend_leaks_nothing(self):
        """A multi-block extend that exhausts the pool midway must leave the
        manager consistent: blocks taken before the failure stay owned by
        the sequence (not lost), and freeing the sequence returns them."""
        m = PagedKVCacheManager(num_blocks=4, block_size=2)
        m.allocate("a", 2)  # 1 block
        m.allocate("other", 4)  # 2 blocks -> 1 block left
        with pytest.raises(OutOfBlocksError):
            m.extend("a", 6)  # needs 3 more blocks, only 1 available
        # length must NOT have advanced past what was committed
        assert m.length("a") == 2
        m.free_seq("a")
        m.free_seq("other")
        assert m.blocks_in_use == 0
        assert sorted(m.free, reverse=True) == list(
            range(m.num_blocks - 1, -1, -1))
        assert all(r == 0 for r in m.refcount)

    def test_out_of_blocks_cow_fork_leaves_share_intact(self):
        """When the COW fork itself hits exhaustion, the shared tail must
        keep its refcount (no half-forked state)."""
        m = PagedKVCacheManager(num_blocks=2, block_size=4)
        m.allocate("p", 6)  # both blocks
        m.fork("p", "c")
        tail = m.seqs["p"].blocks[-1]
        with pytest.raises(OutOfBlocksError):
            m.extend("c", 1)  # tail is shared, fork needs a free block
        assert m.refcount[tail] == 2  # share untouched
        m.free_seq("c")
        m.free_seq("p")
        assert m.blocks_in_use == 0


class TestConcurrentReserveRelease:
    """ServeEngine._kv_reserve/_kv_release from many client threads: the
    engine's lock discipline must keep the manager consistent and reject
    over-subscription cleanly (backpressure, not corruption)."""

    @pytest.fixture(scope="class")
    def engine(self):
        import jax

        from repro.configs.registry import get_config
        from repro.models import model as M
        from repro.serving.engine import ServeEngine

        cfg = get_config("internlm2_1_8b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, max_seq=32, kv_blocks=24,
                          kv_block_size=4)
        yield eng
        eng.close()

    def test_many_streams_reserve_release(self, engine):
        prompt = np.zeros((1, 6), np.int32)  # 6+2 tokens -> 2 blocks each
        errors = []
        admitted = []
        lock = threading.Lock()

        def worker(i):
            try:
                for _ in range(25):
                    sid = engine._kv_reserve(f"t{i}", prompt, steps=2)
                    with lock:
                        admitted.append(sid)
                    engine._kv_release(sid)
            except OutOfBlocksError:
                pass  # backpressure is a legal outcome, corruption is not
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert engine.kv.blocks_in_use == 0  # everything released
        assert all(r == 0 for r in engine.kv.refcount)
        assert len(set(admitted)) == len(admitted)  # unique seq ids


class TestSharedSegments:
    """COW-dedup shared segments (enc-dec cross-attention KV): keyed
    refcounted acquire/release, fork/free ordering, and concurrent release
    corner cases."""

    def _mgr(self, segments=3):
        return PagedKVCacheManager(num_blocks=8, block_size=4,
                                   num_segments=segments, family="encdec")

    def test_same_key_dedups_to_one_segment(self):
        m = self._mgr()
        m.allocate("a", 4, segment_key="frames")
        m.allocate("b", 4, segment_key="frames")
        seg = m.segment("a")
        assert m.segment("b") == seg
        assert m.segments_in_use == 1
        assert m.segment_refcount[seg] == 2

    def test_acquire_reports_freshness_exactly_once(self):
        m = self._mgr()
        seg, fresh = m.acquire_segment("k")
        assert fresh  # first caller must write the contents
        seg2, fresh2 = m.acquire_segment("k")
        assert seg2 == seg and not fresh2  # joiners must NOT rewrite
        m.release_segment(seg)
        m.release_segment(seg)
        # key retired with the last release: the next acquire is fresh again
        seg3, fresh3 = m.acquire_segment("k")
        assert fresh3

    def test_fork_then_free_parent_keeps_segment_live(self):
        """Fork/free ordering: the parent dying first must not retire the
        key while the fork still decodes against it."""
        m = self._mgr()
        m.allocate("base", 8, segment_key="frames")
        m.fork("base", "child")
        seg = m.segment("base")
        assert m.segment("child") == seg
        m.free_seq("base")
        assert m.segments_in_use == 1  # child's reference holds it
        assert m.segments["frames"] == seg
        # a latecomer still joins the live key, no fresh allocation
        m.allocate("late", 4, segment_key="frames")
        assert m.segment("late") == seg
        m.free_seq("child")
        m.free_seq("late")
        assert m.segments_in_use == 0
        assert "frames" not in m.segments

    def test_fork_then_free_child_then_parent(self):
        m = self._mgr()
        m.allocate("base", 8, segment_key="frames")
        m.fork("base", "child")
        m.free_seq("child")
        seg = m.segment("base")
        assert m.segment_refcount[seg] == 1
        m.free_seq("base")
        assert m.segments_in_use == 0

    def test_last_release_recycles_for_new_key(self):
        m = self._mgr(segments=1)
        m.allocate("a", 4, segment_key="k1")
        with pytest.raises(OutOfBlocksError):
            m.allocate("b", 4, segment_key="k2")  # pool of 1, k1 holds it
        m.free_seq("a")
        m.allocate("b", 4, segment_key="k2")  # recycled under the new key
        assert m.segments_in_use == 1
        assert "k1" not in m.segments and "k2" in m.segments

    def test_concurrent_release_frees_exactly_once(self):
        """Many threads racing release_segment on their own references: the
        segment must come back exactly once, never double-freed onto the
        free list."""
        m = self._mgr(segments=2)
        n = 16
        seg, _ = m.acquire_segment("k")
        for _ in range(n - 1):
            m.acquire_segment("k")
        barrier = threading.Barrier(n)
        errors = []

        def worker():
            try:
                barrier.wait()
                m.release_segment(seg)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert m.segment_refcount[seg] == 0
        assert m.free_segments.count(seg) == 1  # exactly once
        assert "k" not in m.segments

    def test_concurrent_stream_churn_over_shared_key(self):
        """Engine-shaped churn: threads allocate/free sequences that all
        share one segment key; afterwards nothing is held and no segment
        id appears twice on the free list."""
        m = self._mgr(segments=2)
        lock = threading.Lock()  # the engine serializes manager calls
        errors = []

        def worker(i):
            try:
                for j in range(50):
                    sid = f"t{i}#{j}"
                    with lock:
                        m.allocate(sid, 4, segment_key="frames")
                    with lock:
                        m.free_seq(sid)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert m.segments_in_use == 0 and m.blocks_in_use == 0
        assert sorted(m.free_segments) == sorted(set(m.free_segments))


class TestGatherSemantics:
    def test_block_table_gather_reconstructs_sequence(self):
        """cache[block_table] must reproduce the logically contiguous KV."""
        bs, nkv, hd = 4, 2, 8
        m = PagedKVCacheManager(num_blocks=8, block_size=bs)
        pool = np.zeros((8, bs, nkv, hd), np.float32)
        tokens = np.random.RandomState(0).randn(10, nkv, hd).astype(np.float32)
        m.allocate("s", 10)
        blocks = m.seqs["s"].blocks
        for t in range(10):
            pool[blocks[t // bs], t % bs] = tokens[t]
        table = m.block_table("s", max_blocks=4)
        gathered = pool[np.asarray(table)].reshape(-1, nkv, hd)
        np.testing.assert_array_equal(gathered[:10], tokens)

    def test_table_is_padded(self):
        m = PagedKVCacheManager(num_blocks=8, block_size=4)
        m.allocate("s", 4)
        t = m.block_table("s", max_blocks=5)
        assert len(t) == 5
