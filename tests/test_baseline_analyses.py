"""Unit tests for the MPCP / FMLP+ baseline analyses and allocation."""

import math
import random

import pytest

from repro.core import fmlp_analysis, mpcp_analysis
from repro.core.allocation import SERVER_NAME, allocate
from repro.core.task_model import GpuSegment, System, Task, server_utilization
from repro.core.taskset_gen import GenParams, assign_rm_priorities, generate_taskset


def _fig2_system() -> System:
    tau_h = Task("tau_h", C=2, T=100, D=100, priority=3, core=1,
                 segments=(GpuSegment(e=1.0, m=2.0),))
    tau_m = Task("tau_m", C=2, T=100, D=100, priority=2, core=1,
                 segments=(GpuSegment(e=1.0, m=2.0),))
    tau_l = Task("tau_l", C=2, T=100, D=100, priority=1, core=2,
                 segments=(GpuSegment(e=2.0, m=2.0),))
    return System(tasks=[tau_h, tau_m, tau_l], num_cores=3, epsilon=0.0)


class TestMPCP:
    def test_covers_fig2_schedule(self):
        """The Figure-2 schedule shows tau_h responding in 9; the MPCP bound
        must be >= 9."""
        sys_ = _fig2_system()
        res = mpcp_analysis.analyze(sys_)
        assert res.wcrt("tau_h") >= 9.0
        assert res.schedulable

    def test_busy_wait_demand(self):
        """An isolated GPU task's WCRT includes its full GPU time (busy-wait)."""
        t = Task("solo", C=1, T=50, D=50, priority=1, core=0,
                 segments=(GpuSegment(e=2.0, m=0.5),))
        sys_ = System(tasks=[t], num_cores=1, epsilon=0.0)
        res = mpcp_analysis.analyze(sys_)
        assert res.wcrt("solo") == pytest.approx(1 + 2.5)

    def test_remote_blocking_priority_ordered(self):
        """Lower-priority GPU task waits for hp requests repeatedly."""
        hp = Task("hp", C=1, T=10, D=10, priority=2, core=0,
                  segments=(GpuSegment(e=2.0, m=0.0),))
        lo = Task("lo", C=1, T=40, D=40, priority=1, core=1,
                  segments=(GpuSegment(e=1.0, m=0.0),))
        sys_ = System(tasks=[hp, lo], num_cores=2, epsilon=0.0)
        b = mpcp_analysis.remote_blocking_per_request(sys_, lo, horizon=40)
        # B0 = 0 (no lp); B1 = (0+1)*2=... iterate: fixpoint of
        # B = (ceil(B/10)+1)*2 -> B=4: ceil(4/10)+1=2 -> 4 ✓
        assert b == pytest.approx(4.0)


class TestFMLP:
    def test_covers_fifo_schedule(self):
        sys_ = _fig2_system()
        res = fmlp_analysis.analyze(sys_)
        # simulated FIFO gives tau_h=9, tau_m=11 (test_simulator.py)
        assert res.wcrt("tau_h") >= 9.0
        assert res.wcrt("tau_m") >= 11.0

    def test_fifo_blocking_counts_all_other_tasks(self):
        sys_ = _fig2_system()
        # tau_h, one request: FIFO bound = max seg of tau_m (3) + tau_l (4) = 7
        assert fmlp_analysis._fifo_request_driven(sys_, sys_.tasks[0]) == pytest.approx(7.0)


class TestAllocation:
    def test_wfd_balances(self):
        tasks = [
            Task("a", C=4, T=10, D=10, priority=4, core=0),
            Task("b", C=4, T=10, D=10, priority=3, core=0),
            Task("c", C=1, T=10, D=10, priority=2, core=0),
            Task("d", C=1, T=10, D=10, priority=1, core=0),
        ]
        sys_ = allocate(tasks, 2, approach="sync")
        by_core = {}
        for t in sys_.tasks:
            by_core.setdefault(t.core, []).append(t.name)
        # WFD: a->0, b->1, c->0/1, d->other
        assert {frozenset(v) for v in by_core.values()} == {
            frozenset({"a", "c"}), frozenset({"b", "d"})} or {
            frozenset(v) for v in by_core.values()} == {
            frozenset({"a", "d"}), frozenset({"b", "c"})}

    def test_server_is_placed(self):
        tasks = assign_rm_priorities([
            Task("g", C=1, T=10, D=10,
                 segments=(GpuSegment(e=1.0, m=0.2),)),
            Task("c", C=2, T=20, D=20),
        ])
        sys_ = allocate(tasks, 2, approach="server", epsilon=0.05)
        assert 0 <= sys_.server_core < 2
        assert sys_.epsilon == 0.05

    def test_packing_util_reflects_approach(self):
        """Under 'server', a GPU-heavy task packs by C/T only."""
        g = Task("g", C=0.1, T=10, D=10, priority=1, core=0,
                 segments=(GpuSegment(e=8.0, m=0.1),))
        assert g.U > 0.8
        sys_ = allocate([g], 1, approach="server", epsilon=0.05)
        assert sys_.tasks[0].core == 0


class TestTasksetGen:
    def test_table2_invariants(self):
        rng = random.Random(7)
        params = GenParams(num_cores=4)
        for _ in range(50):
            tasks = generate_taskset(params, rng)
            n = len(tasks)
            assert 8 <= n <= 20  # [2*4, 5*4]
            n_gpu = sum(1 for t in tasks if t.uses_gpu)
            assert 0 <= n_gpu <= round(0.30 * n) + 1
            for t in tasks:
                assert 30 <= t.T <= 500
                assert t.D == t.T
                assert 0.05 - 1e-9 <= t.U <= 0.2 + 1e-9
                if t.uses_gpu:
                    assert 1 <= t.eta <= 3
                    r = t.G / t.C
                    assert 0.10 - 1e-9 <= r <= 0.30 + 1e-9
                    for seg in t.segments:
                        mr = seg.m / seg.total
                        assert 0.10 - 1e-6 <= mr <= 0.20 + 1e-6
            # unique priorities, RM-ordered
            prios = sorted(tasks, key=lambda t: -t.priority)
            assert all(prios[i].T <= prios[i + 1].T + 1e-12 for i in range(n - 1))

    def test_bimodal(self):
        rng = random.Random(3)
        params = GenParams(num_cores=4, bimodal_large_fraction=1.0)
        tasks = generate_taskset(params, rng)
        for t in tasks:
            assert 0.2 - 1e-9 <= t.U <= 0.5 + 1e-9

    def test_server_utilization_formula(self):
        eps = 0.05
        tasks = [
            Task("a", C=1, T=10, D=10, priority=2, core=0,
                 segments=(GpuSegment(e=1.0, m=0.5), GpuSegment(e=0.5, m=0.25))),
            Task("b", C=1, T=20, D=20, priority=1, core=0),
        ]
        expected = (0.75 + 2 * 2 * eps) / 10
        assert server_utilization(tasks, eps) == pytest.approx(expected)
