"""End-to-end multi-server + continuous-batching serving: >=4 admitted
streams over >=2 servers, batched greedy decode must reproduce the
unbatched engine's tokens exactly (each slot row is computed independently
inside the masked batch step)."""

import threading

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine, StreamSpec

STEPS = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _spec(name, prio, steps=STEPS):
    return StreamSpec(name=name, priority=prio, period_ms=8000.0,
                      deadline_ms=8000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _reference_tokens(cfg, params, prompt):
    eng = ServeEngine(cfg, params, max_seq=32)
    try:
        assert eng.admit(_spec("ref", 1)).admitted
        return eng.generate("ref", prompt, steps=STEPS).tokens
    finally:
        eng.close()


class TestBatchedPoolServing:
    def test_four_streams_two_servers_match_unbatched(self, setup):
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        assert len(want) == STEPS

        eng = ServeEngine(cfg, params, max_seq=32, num_servers=2,
                          batching=True, max_batch=4)
        try:
            names = [f"s{i}" for i in range(4)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 4 - i)).admitted
            # partitioned routing actually used both servers
            servers = {eng.pool.server_of(n) for n in names}
            assert servers == {0, 1}

            results = {}

            def worker(n):
                results[n] = eng.generate(n, prompt, steps=STEPS)

            threads = [threading.Thread(target=worker, args=(n,))
                       for n in names]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

            for n in names:
                assert results[n].tokens == want, n
                assert len(results[n].decode_latencies_s) == STEPS
            # every decode step went through a BatchingServer dispatch
            total_batched = sum(s.stats.batches for s in eng.pool.servers)
            assert total_batched >= 1
            completed = sum(s.stats.completed for s in eng.pool.servers)
            # 4 streams x (prefill + insert + STEPS decodes)
            assert completed == 4 * (2 + STEPS)
        finally:
            eng.close()

    def test_slots_recycled_across_jobs(self, setup):
        """More sequential jobs than slots: slots must free and be reused."""
        cfg, params = setup
        prompt = np.array([[5, 6]], np.int32)
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=2)
        try:
            for i in range(3):
                assert eng.admit(_spec(f"j{i}", 3 - i, steps=2)).admitted
            for i in range(3):  # sequential: each job acquires + releases
                r = eng.generate(f"j{i}", prompt, steps=2)
                assert len(r.tokens) == 2
            assert len(eng._slots[0].free) == 2  # all slots back
        finally:
            eng.close()

    def test_batched_requires_single_row_prompt(self, setup):
        cfg, params = setup
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=2)
        try:
            assert eng.admit(_spec("w", 1)).admitted
            with pytest.raises(ValueError, match="one sequence"):
                eng.generate("w", np.zeros((2, 4), np.int32), steps=1)
        finally:
            eng.close()

    def test_concurrent_streams_coalesce(self, setup):
        """With one server and concurrently decoding streams, at least one
        device call must carry more than one request."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3]], np.int32)
        eng = ServeEngine(cfg, params, max_seq=64, ordering="fifo",
                          num_servers=1, batching=True, max_batch=4)
        try:
            for i in range(4):
                assert eng.admit(_spec(f"c{i}", 4 - i, steps=16)).admitted
            results = {}

            def worker(n):
                results[n] = eng.generate(n, prompt, steps=16)

            threads = [threading.Thread(target=worker, args=(f"c{i}",))
                       for i in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert all(len(r.tokens) == 16 for r in results.values())
            sizes = eng.pool.servers[0].stats.batch_sizes
            assert max(sizes) > 1, sizes
        finally:
            eng.close()
