"""End-to-end multi-server + continuous-batching serving: >=4 admitted
streams over >=2 servers, batched greedy decode must reproduce the
unbatched engine's tokens exactly — for BOTH decode-cache layouts: the
masked-dense slot cache and the paged block-pool layout (slot compaction +
block-table gather + length-bucketed batched prefill)."""

import threading

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine, StreamSpec

STEPS = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _spec(name, prio, steps=STEPS):
    return StreamSpec(name=name, priority=prio, period_ms=8000.0,
                      deadline_ms=8000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _reference_tokens(cfg, params, prompt):
    eng = ServeEngine(cfg, params, max_seq=32)
    try:
        assert eng.admit(_spec("ref", 1)).admitted
        return eng.generate("ref", prompt, steps=STEPS).tokens
    finally:
        eng.close()


class TestBatchedPoolServing:
    def test_four_streams_two_servers_match_unbatched(self, setup):
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        assert len(want) == STEPS

        eng = ServeEngine(cfg, params, max_seq=32, num_servers=2,
                          batching=True, max_batch=4)
        try:
            names = [f"s{i}" for i in range(4)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 4 - i)).admitted
            # partitioned routing actually used both servers
            servers = {eng.pool.server_of(n) for n in names}
            assert servers == {0, 1}

            results = {}

            def worker(n):
                results[n] = eng.generate(n, prompt, steps=STEPS)

            threads = [threading.Thread(target=worker, args=(n,))
                       for n in names]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

            for n in names:
                assert results[n].tokens == want, n
                assert len(results[n].decode_latencies_s) == STEPS
            # every decode step went through a BatchingServer dispatch
            total_batched = sum(s.stats.batches for s in eng.pool.servers)
            assert total_batched >= 1
            completed = sum(s.stats.completed for s in eng.pool.servers)
            # 4 streams x (prefill + insert + STEPS decodes)
            assert completed == 4 * (2 + STEPS)
        finally:
            eng.close()

    def test_slots_recycled_across_jobs(self, setup):
        """More sequential jobs than slots: slots must free and be reused."""
        cfg, params = setup
        prompt = np.array([[5, 6]], np.int32)
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=2)
        try:
            for i in range(3):
                assert eng.admit(_spec(f"j{i}", 3 - i, steps=2)).admitted
            for i in range(3):  # sequential: each job acquires + releases
                r = eng.generate(f"j{i}", prompt, steps=2)
                assert len(r.tokens) == 2
            assert len(eng._slots[0].free) == 2  # all slots back
        finally:
            eng.close()

    def test_batched_requires_single_row_prompt(self, setup):
        cfg, params = setup
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=2)
        try:
            assert eng.admit(_spec("w", 1)).admitted
            with pytest.raises(ValueError, match="one sequence"):
                eng.generate("w", np.zeros((2, 4), np.int32), steps=1)
        finally:
            eng.close()

    @pytest.mark.parametrize("paged", [False, True])
    def test_mixed_prompt_lengths_match_unbatched(self, setup, paged):
        """Streams with different prompt lengths (different prefill buckets,
        different live cache lengths) must each reproduce their own
        unbatched tokens."""
        cfg, params = setup
        prompts = {f"m{i}": np.arange(1, n + 1, dtype=np.int32)[None, :] % 100
                   for i, n in enumerate([2, 5, 9])}
        want = {n: _reference_tokens(cfg, params, p)
                for n, p in prompts.items()}

        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=4, paged=paged)
        try:
            for i, n in enumerate(prompts):
                assert eng.admit(_spec(n, 3 - i)).admitted
            results = {}

            def worker(n):
                results[n] = eng.generate(n, prompts[n], steps=STEPS)

            threads = [threading.Thread(target=worker, args=(n,))
                       for n in prompts]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for n in prompts:
                assert results[n].tokens == want[n], n
        finally:
            eng.close()

    def test_concurrent_streams_coalesce(self, setup):
        """With one server and concurrently decoding streams, at least one
        device call must carry more than one request."""
        cfg, params = setup
        prompt = np.array([[1, 2, 3]], np.int32)
        eng = ServeEngine(cfg, params, max_seq=64, ordering="fifo",
                          num_servers=1, batching=True, max_batch=4)
        try:
            for i in range(4):
                assert eng.admit(_spec(f"c{i}", 4 - i, steps=16)).admitted
            results = {}

            def worker(n):
                results[n] = eng.generate(n, prompt, steps=16)

            threads = [threading.Thread(target=worker, args=(f"c{i}",))
                       for i in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert all(len(r.tokens) == 16 for r in results.values())
            sizes = eng.pool.servers[0].stats.batch_sizes
            assert max(sizes) > 1, sizes
        finally:
            eng.close()


class TestPagedPoolServing:
    """Paged block-pool decode: bit-identical greedy tokens, slot
    compaction, width bucketing, and block accounting."""

    def test_four_streams_two_servers_match_unbatched(self, setup):
        cfg, params = setup
        prompt = np.array([[1, 2, 3, 4]], np.int32)
        want = _reference_tokens(cfg, params, prompt)

        eng = ServeEngine(cfg, params, max_seq=32, num_servers=2,
                          batching=True, max_batch=4, paged=True,
                          kv_block_size=8)
        try:
            names = [f"p{i}" for i in range(4)]
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, 4 - i)).admitted
            assert {eng.pool.server_of(n) for n in names} == {0, 1}

            results = {}

            def worker(n):
                results[n] = eng.generate(n, prompt, steps=STEPS)

            threads = [threading.Thread(target=worker, args=(n,))
                       for n in names]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

            for n in names:
                assert results[n].tokens == want, n
            # every decode call reported its compaction/width decision
            meta = [m for s in eng.pool.servers for m in s.stats.batch_meta]
            decodes = [m for m in meta if m["kind"] == "decode"]
            assert decodes
            # prompt 4 + 6 steps <= 16 tokens -> 2 blocks of 8; width
            # bucketing must never widen past the pow2 cover of that
            assert all(m["width"] <= 2 for m in decodes)
            prefills = [m for m in meta if m["kind"] == "prefill"]
            assert prefills and all(m["bucket"] == 4 for m in prefills)
            # all blocks released at job end (scratch block still held)
            for st in eng._paged:
                assert st.mgr.blocks_in_use == 1
        finally:
            eng.close()

    def test_single_stream_compacts(self, setup):
        """One live stream in an 8-slot server: the device call must shrink
        to a single row (slot compaction at low occupancy)."""
        cfg, params = setup
        prompt = np.array([[7, 8, 9]], np.int32)
        eng = ServeEngine(cfg, params, max_seq=64, num_servers=1,
                          batching=True, max_batch=8, paged=True,
                          kv_block_size=8)
        try:
            assert eng.admit(_spec("solo", 1)).admitted
            res = eng.generate("solo", prompt, steps=4)
            assert len(res.tokens) == 4
            decodes = [m for m in eng.pool.servers[0].stats.batch_meta
                       if m["kind"] == "decode"]
            assert decodes
            assert all(m["padded"] == 1 and m["compacted"] for m in decodes)
        finally:
            eng.close()

    def test_precompile_visits_all_shape_buckets(self, setup):
        """precompile() must warm every (rows, width) pow2 cell so no
        decode step ever hits a cold trace mid-traffic — each distinct
        cell traced ONCE (the jitted step is shared across servers)."""
        cfg, params = setup
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=2,
                          batching=True, max_batch=4, paged=True,
                          kv_block_size=8)
        try:
            # rows in {1,2,4} x widths in {1,2,4} (nb_max=32/8) = 9 decode
            # cells, plus one migrate (gather+scatter) cell per width = 12
            rep = eng.precompile()
            assert rep.compiled == 12 and rep.skipped == 0
            assert rep.migrate_cells == (1, 2, 4)
            # second call: everything already warm -> all deduped away
            rep2 = eng.precompile()
            assert rep2.compiled == 0 and rep2.skipped == 12
            before = eng._decode_paged._cache_size()
            assert eng.admit(_spec("w", 1)).admitted
            res = eng.generate("w", np.array([[1, 2, 3]], np.int32), steps=4)
            assert len(res.tokens) == 4
            assert eng._decode_paged._cache_size() == before  # no cold trace
        finally:
            eng.close()

    def test_precompile_covers_nonpow2_max_batch(self, setup):
        """max_batch=6 makes the runtime clamp produce a SIX-row cell
        (pow2ceil clamped to the cap); the old pow2-only precompile loop
        missed it, leaving (6, w) traces cold.  The ladder must include the
        cap and the report must count the extra row bucket."""
        cfg, params = setup
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=6, paged=True,
                          kv_block_size=8)
        try:
            assert eng._row_buckets == (1, 2, 4, 6)
            rep = eng.precompile()
            # rows {1,2,4,6} x widths {1,2,4} = 12 decode cells, + the 3
            # per-width migrate cells
            assert rep.compiled == 15
            assert (6, 1) in rep.decode_cells
        finally:
            eng.close()

    def test_traffic_aware_precompile_bumps_cold_cells(self, setup):
        """precompile(traffic=...) compiles only the predicted-hit cells
        plus the largest-cell safe fallback; a cold cell at runtime bumps
        UP to a warm cover instead of stalling on XLA compilation."""
        cfg, params = setup
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=4, paged=True,
                          kv_block_size=8)
        try:
            hot = {("decode", 2, 2)}
            rep = eng.precompile(traffic=hot)
            # the hot cell + the (4, 4) fallback + the width-4 migrate
            # fallback (a steal can hit any stream regardless of traffic)
            assert rep.compiled == 3
            assert set(rep.decode_cells) == {(2, 2), (4, 4)}
            assert rep.migrate_cells == (4,)
            assert rep.skipped == (9 - 2) + (3 - 1)
            before = eng._decode_paged._cache_size()
            assert eng.admit(_spec("t", 1)).admitted
            res = eng.generate("t", np.array([[1, 2, 3]], np.int32), steps=4)
            assert len(res.tokens) == 4
            # the 1-row/width-1 steps ran in the warm (2, 2) cell: no new
            # trace was compiled mid-traffic
            assert eng._decode_paged._cache_size() == before
            decodes = [m for m in eng.pool.servers[0].stats.batch_meta
                       if m["kind"] == "decode"]
            assert decodes and all(
                (m["padded"], m["width"]) == (2, 2) and not m["cold"]
                for m in decodes)
        finally:
            eng.close()

    def test_tune_buckets_minimizes_padding_waste(self, setup):
        """Bucket auto-tuning: with max_buckets=2 and short prompts the
        prefill ladder collapses to {tight cover, max_seq} and decode
        widths to {tight cover, nb_max} — and the tuned engine still
        generates correctly (the cover bucket always survives)."""
        cfg, params = setup
        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=4, paged=True,
                          kv_block_size=8)
        try:
            pb, wb = eng.tune_buckets([3, 3, 4], steps_hint=3,
                                      max_buckets=2)
            assert pb == (4, 32)   # tight cover 4 + forced max_seq
            assert wb == (1, 4)    # every need is 1 block + forced nb_max
            rep = eng.precompile()
            # rows {1,2,4} x tuned widths {1,4} = 6 decode cells, + the 2
            # tuned-width migrate cells
            assert rep.compiled == 8
            assert eng.admit(_spec("b", 1)).admitted
            res = eng.generate("b", np.array([[1, 2, 3]], np.int32),
                               steps=4)
            assert len(res.tokens) == 4
        finally:
            eng.close()

    def test_pool_exhaustion_rejects_before_dispatch(self, setup):
        cfg, params = setup
        from repro.serving.kvcache import OutOfBlocksError

        eng = ServeEngine(cfg, params, max_seq=32, num_servers=1,
                          batching=True, max_batch=2, paged=True,
                          kv_block_size=8, kv_blocks=3)  # scratch + 2 blocks
        try:
            assert eng.admit(_spec("big", 1)).admitted
            with pytest.raises(OutOfBlocksError):
                # needs ceil((17+6)/8) = 3 blocks, only 2 available
                eng.generate("big", np.zeros((1, 17), np.int32), steps=6)
            assert eng._paged[0].mgr.blocks_in_use == 1  # nothing leaked
        finally:
            eng.close()

    def test_paged_requires_declared_family(self):
        """A stack whose cache_family declaration is stripped has NO paged
        path — the engine must refuse, never silently fall back to dense."""
        import dataclasses

        from repro.configs.registry import get_config as gc

        cfg = dataclasses.replace(gc("deepseek_v2_lite_16b").reduced(),
                                  cache_family="")
        params = M.init_params(cfg, jax.random.PRNGKey(2))
        with pytest.raises(ValueError, match="paged decode unsupported"):
            ServeEngine(cfg, params, max_seq=32, batching=True, paged=True)
