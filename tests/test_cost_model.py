"""Calibrated step-cost model: measurement intake, surface fitting,
bucket auto-tuning, and the two soundness properties calibrated admission
rests on — (1) calibrated admission accepts a SUPERSET of the tasksets the
worst-case-declared admission accepts (with at least one strict win), and
(2) the per-server analysis bounds still dominate the simulated WCRT when
both run on the same calibrated costs.

``hypothesis`` is optional: ``given(seed=...)`` degrades to a fixed seed
sweep when it is missing (same pattern as test_simulator_property.py).
"""

import math
import random

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    _SETTINGS = dict(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
except ImportError:  # deterministic fallback sampler
    _FALLBACK_SEEDS = list(range(0, 10_000, 401))

    def given(**kwargs):
        names = sorted(kwargs)
        if names != ["seed"]:
            raise NotImplementedError(f"fallback only supports seed=, got {names}")
        return pytest.mark.parametrize("seed", _FALLBACK_SEEDS)

    def settings(**_kwargs):
        return lambda f: f

    class _IntRange:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

    class st:  # noqa: N801 - mimics hypothesis.strategies
        integers = staticmethod(_IntRange)

    _SETTINGS = {}

from repro.analysis.cost_model import (
    StepCostModel,
    TrafficModel,
    autotune_buckets,
    bucket_up,
)
from repro.core import server_analysis, simulator
from repro.core.admission import AdmissionController
from repro.core.allocation import allocate_pool
from repro.core.server_runtime import (
    BATCH_META_CAP,
    CellStats,
    ServerStats,
    cell_key,
)
from repro.core.task_model import GpuSegment, Task
from repro.core.taskset_gen import GenParams, generate_taskset


# -- cell keys and running aggregates (satellite: bounded batch_meta) ------

class TestCellBookkeeping:
    def test_cell_key_maps_batch_meta(self):
        assert cell_key({"kind": "decode", "rows": 3, "padded": 4,
                         "width": 2}) == ("decode", 4, 2)
        assert cell_key({"kind": "prefill", "rows": 1, "padded": 2,
                         "bucket": 16}) == ("prefill", 2, 16)
        assert cell_key({"kind": "decode", "rows": 3}) is None
        assert cell_key({"kind": "insert"}) is None

    def test_batch_meta_ring_buffer_is_bounded(self):
        stats = ServerStats()
        n = BATCH_META_CAP + 500
        for i in range(n):
            stats.record_meta({"kind": "decode", "rows": 1, "padded": 1,
                               "width": 1, "seconds": 0.001})
        # the raw trail is capped ...
        assert len(stats.batch_meta) == BATCH_META_CAP
        # ... but the running aggregate saw every call
        cell = stats.cell_stats[("decode", 1, 1)]
        assert cell.calls == n and cell.timed == n
        assert cell.mean_s == pytest.approx(0.001)

    def test_cell_stats_welford_and_merge(self):
        a, b = CellStats(), CellStats()
        xs, ys = [0.001, 0.002, 0.003], [0.004, 0.005]
        for x in xs:
            a.add({"seconds": x, "rows": 2})
        for y in ys:
            b.add({"seconds": y, "rows": 1})
        a.merge(b)
        allv = xs + ys
        assert a.timed == 5 and a.rows == 8
        assert a.mean_s == pytest.approx(sum(allv) / 5)
        mean = sum(allv) / 5
        assert a.var_s == pytest.approx(
            sum((v - mean) ** 2 for v in allv) / 5)
        assert a.min_s == pytest.approx(min(allv))
        assert a.max_s == pytest.approx(max(allv))

    def test_merge_into_empty(self):
        a, b = CellStats(), CellStats()
        b.add({"seconds": 0.002, "rows": 4})
        a.merge(b)
        assert a.timed == 1 and a.mean_s == pytest.approx(0.002)


# -- fitting and prediction ------------------------------------------------

def _linear_model(a=0.0005, b=0.0001, c=0.00002):
    """Cells sampled exactly from seconds = a + b*rows + c*rows*width."""
    m = StepCostModel(safety=1.0)
    for rows in (1, 2, 4, 8):
        for width in (1, 2, 4):
            m.observe(("decode", rows, width),
                      a + b * rows + c * rows * width, rows=rows)
    return m


class TestStepCostModel:
    def test_fit_recovers_linear_surface(self):
        m = _linear_model()
        coeffs = m.fit()["decode"]
        assert coeffs == pytest.approx([0.0005, 0.0001, 0.00002], rel=1e-6)
        assert m.dispatch_overhead_s("decode") == pytest.approx(0.0005)

    def test_predict_measured_cell_uses_mean(self):
        m = StepCostModel()
        m.observe(("decode", 4, 2), 0.010)
        m.observe(("decode", 4, 2), 0.020)
        assert m.predict("decode", 4, 2) == pytest.approx(0.015)

    def test_predict_unseen_cell_interpolates(self):
        m = _linear_model()
        # (3, 3) was never observed: priced off the fitted surface
        want = 0.0005 + 0.0001 * 3 + 0.00002 * 9
        assert m.predict("decode", 3, 3) == pytest.approx(want, rel=1e-5)

    def test_unmeasured_phase_prices_infinite(self):
        m = _linear_model()
        assert math.isinf(m.predict("prefill", 1, 8))
        assert math.isinf(m.dispatch_overhead_s("prefill"))

    def test_coefficients_never_negative(self):
        m = StepCostModel()
        # adversarial: cost DECREASES with width (noise) — the nnls clamp
        # must zero the width term rather than fit a negative rate
        m.observe(("decode", 1, 1), 0.004)
        m.observe(("decode", 1, 2), 0.003)
        m.observe(("decode", 1, 4), 0.002)
        for coeff in m.fit()["decode"]:
            assert coeff >= 0.0

    def test_ingest_mapping_and_meta_stream(self):
        stats = ServerStats()
        for _ in range(3):
            stats.record_meta({"kind": "decode", "rows": 2, "padded": 2,
                               "width": 1, "seconds": 0.002})
        m = StepCostModel()
        assert m.ingest(stats.cell_stats) == 1
        assert m.predict("decode", 2, 1) == pytest.approx(0.002)
        m2 = StepCostModel()
        n = m2.ingest([
            {"kind": "prefill", "rows": 1, "padded": 1, "bucket": 8,
             "seconds": 0.005},
            {"kind": "decode", "rows": 1, "padded": 1, "width": 1},  # untimed
        ])
        assert n == 1
        assert m2.predict("prefill", 1, 8) == pytest.approx(0.005)

    def test_error_report_scores_surface(self):
        m = _linear_model()
        rep = m.error_report()
        assert rep["n_cells"] == 12
        assert rep["median_rel_err"] < 1e-6  # exact linear data
        assert all(r["rel_err"] < 1e-5 for r in rep["cells"])
        assert "decode" in rep["coeffs"]


# -- admission recosting ---------------------------------------------------

def _task(name="t", *, decode_ms=2.0, steps=3, T=50.0):
    segs = tuple(GpuSegment(e=decode_ms * 0.9, m=decode_ms * 0.1)
                 for _ in range(steps))
    return Task(name=name, C=0.1, T=T, D=T, segments=segs, priority=1)


class TestRecost:
    def test_recost_scales_down_never_up(self):
        m = StepCostModel(safety=1.0)
        m.observe(("decode", 1, 1), 0.0005)  # 0.5 ms, declared 2 ms
        t = _task()
        out = m.recost(t, ("decode", 1, 1))
        for seg in out.segments:
            assert seg.total == pytest.approx(0.5)
            assert seg.e / seg.total == pytest.approx(0.9)  # e/m ratio kept
        # a cell measured ABOVE the declared cost must not inflate it
        m.observe(("decode", 8, 8), 0.050)
        out2 = m.recost(t, ("decode", 8, 8))
        for seg in out2.segments:
            assert seg.total == pytest.approx(2.0)

    def test_recost_safety_margin_applied(self):
        m = StepCostModel(safety=2.0)
        m.observe(("decode", 1, 1), 0.0005)
        out = m.recost(_task(), ("decode", 1, 1))
        assert out.segments[0].total == pytest.approx(1.0)  # 2 * 0.5 ms

    def test_recost_per_segment_cells_with_none(self):
        m = StepCostModel(safety=1.0)
        m.observe(("decode", 1, 1), 0.0005)
        t = _task(steps=3)
        out = m.recost(t, [("decode", 1, 1), None, ("decode", 1, 1)])
        totals = [s.total for s in out.segments]
        assert totals == pytest.approx([0.5, 2.0, 0.5])
        with pytest.raises(ValueError):
            m.recost(t, [("decode", 1, 1)])

    def test_recost_unmeasured_phase_keeps_declared(self):
        m = StepCostModel()
        out = m.recost(_task(), ("decode", 1, 1))
        assert [s.total for s in out.segments] == pytest.approx([2.0] * 3)


# -- bucket auto-tuning ----------------------------------------------------

class TestAutotune:
    def test_bucket_up(self):
        assert bucket_up(3, (1, 2, 4, 8)) == 4
        assert bucket_up(4, (1, 2, 4, 8)) == 4
        assert bucket_up(9, (1, 2, 4, 8)) == 8  # clamp to cover

    def test_minimizes_padding_waste(self):
        got = autotune_buckets([3, 5, 9, 17], (1, 2, 4, 8, 16, 32),
                               max_buckets=3)
        assert got == (8, 16, 32)

    def test_cover_always_kept(self):
        got = autotune_buckets([1, 1, 2], (1, 2, 4, 8, 16), max_buckets=2)
        assert got[-1] == 16
        assert 16 in autotune_buckets([1], (1, 16), max_buckets=1)

    def test_value_above_cover_rejected(self):
        with pytest.raises(ValueError):
            autotune_buckets([33], (1, 2, 4, 8, 16, 32), max_buckets=2)

    def test_cost_model_pricing_changes_choice(self):
        # waste says bucket 8 is harmless for value 5; a pricing where 8
        # is catastrophically expensive pushes 5 into its own bucket set
        def price(bucket, value):
            return 1000.0 if bucket == 8 else float(bucket - value)

        waste = autotune_buckets([5, 5, 5], (1, 2, 4, 8, 16), max_buckets=2)
        priced = autotune_buckets([5, 5, 5], (1, 2, 4, 8, 16),
                                  max_buckets=2, cost_of=price)
        assert waste == (8, 16)
        assert priced == (16,) or priced[0] != 8

    def test_empty_values_returns_cover(self):
        assert autotune_buckets([], (1, 2, 4), max_buckets=2) == (4,)


class TestTrafficModel:
    def test_hot_cells_share_threshold(self):
        t = TrafficModel({("decode", 1, 1): 90, ("decode", 8, 8): 10,
                          ("prefill", 1, 16): 5})
        assert t.hot_cells() == {("decode", 1, 1), ("decode", 8, 8),
                                 ("prefill", 1, 16)}
        hot = t.hot_cells(min_share=0.5)
        assert ("decode", 1, 1) in hot
        assert ("decode", 8, 8) not in hot
        assert ("prefill", 1, 16) in hot  # 100% of its own phase

    def test_from_stats(self):
        c = CellStats()
        c.add({"seconds": 0.001, "rows": 1})
        t = TrafficModel.from_stats({("decode", 1, 1): c})
        assert t.counts == {("decode", 1, 1): 1}


# -- property: calibrated admission is a sound superset --------------------

def _calibrated_model(tasks, *, factor=0.25):
    """A model whose measured cell prices every task's decode segment at
    ``factor`` of its declared cost — the shape of real calibration, where
    declared WCETs are the full-width worst case and the measured bucket
    is cheaper."""
    m = StepCostModel(safety=1.0)
    worst = max((seg.total for t in tasks for seg in t.segments),
                default=1.0)
    m.observe(("decode", 1, 1), worst * factor * 1e-3)
    return m


def _admits_all(tasks, *, cost_model=None, cell=None) -> bool:
    ctl = AdmissionController(2, epsilon_ms=0.05, cost_model=cost_model)
    return all(ctl.try_admit(t, cell=cell).admitted for t in tasks)


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_calibrated_admission_is_superset(seed):
    """Every taskset the worst-case-declared admission accepts, calibrated
    admission accepts too: recosting is min(declared, predicted), and
    Eqs (1)-(6) are monotone in segment costs."""
    rng = random.Random(seed)
    params = GenParams(num_cores=2, num_tasks=(3, 8), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    model = _calibrated_model(tasks)
    declared = _admits_all(tasks)
    calibrated = _admits_all(tasks, cost_model=model, cell=("decode", 1, 1))
    if declared:
        assert calibrated, "calibrated admission rejected a declared-admissible set"


def test_calibrated_admission_strictly_wins():
    """At least one workload is rejected under declared worst-case costs
    but admitted under calibrated per-bucket costs (the perf payoff)."""
    # 6 streams, each declaring 8 ms/step x 4 steps every 40 ms: declared
    # device demand alone is 4.8x the period — hopeless under Eqs (1)-(6)
    tasks = [_task(f"s{i}", decode_ms=8.0, steps=4, T=40.0)
             for i in range(6)]
    model = StepCostModel(safety=1.0)
    model.observe(("decode", 2, 2), 0.0004)  # measured: 0.4 ms per step
    assert not _admits_all(tasks)
    assert _admits_all(tasks, cost_model=model, cell=("decode", 2, 2))


@given(seed=st.integers(0, 10_000))
@settings(**_SETTINGS)
def test_calibrated_bounds_dominate_simulated_wcrt(seed):
    """Soundness under calibration: run the per-server pool analysis AND
    the batched simulator on the SAME calibrated costs — the analysis
    bound must still dominate the simulated WCRT (calibration shrinks both
    sides coherently; it never lets execution outrun the proof)."""
    rng = random.Random(seed)
    params = GenParams(num_cores=4, num_tasks=(4, 8), epsilon_ms=0.05)
    tasks = generate_taskset(params, rng)
    model = _calibrated_model(tasks, factor=0.3)
    recosted = [model.recost(t, ("decode", 1, 1)) for t in tasks]
    for orig, cal in zip(tasks, recosted):
        assert cal.G <= orig.G + 1e-12  # never re-priced upward
    system = allocate_pool(recosted, 2, 2, epsilon=params.epsilon_ms)
    res = server_analysis.analyze_pool(system)
    horizon = 3.0 * max(t.T for t in system.tasks)
    sim = simulator.simulate(system, mode="server_batched",
                             horizon_ms=horizon, batch_max=4)
    for t in system.tasks:
        bound = res.wcrt(t.name)
        if not math.isinf(bound):
            assert sim.wcrt(t.name) <= bound + 1e-3, (
                f"{t.name}: simulated {sim.wcrt(t.name)} > calibrated "
                f"bound {bound}")
