"""Small-mesh dry-run integration test: the exact lower+compile pipeline of
launch/dryrun.py on a (2, 8) host-device mesh with reduced configs.  Runs in
a subprocess (device count must be set before jax initializes)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=16 "
                               "--xla_cpu_strict_dot_conv_math=false")
    import dataclasses
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.analysis import hlo_cost
    from repro.configs.registry import get_config, ShapeSpec
    from repro.distributed import sharding as shd
    from repro.launch import steps as steps_mod
    from repro.models import model as M
    from repro.training import optimizer as opt
    from repro.training import train_step as ts

    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 8), ("data", "model"))
    rules = shd.ShardingRules(mesh=mesh, batch_axes=("data",), fsdp=True)

    def sds(tree):
        return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)

    for arch in ("internlm2_1_8b", "qwen3_moe_235b_a22b", "mamba2_780m"):
        cfg = dataclasses.replace(get_config(arch).reduced(), vocab_size=256)
        shape = ShapeSpec("t", seq_len=32, global_batch=4, kind="train")
        params_shape = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                      jax.random.PRNGKey(0))
        batch_shapes = M.input_specs(cfg, shape)
        settings = ts.TrainSettings()
        step = steps_mod.build_train_step(cfg, rules, settings, batch_shapes)
        opt_shape = jax.eval_shape(lambda p: opt.init(p, settings.adamw),
                                   params_shape)
        lowered = step.lower(params_shape, sds(opt_shape), batch_shapes)
        compiled = lowered.compile()
        cost = hlo_cost.analyze_text(compiled.as_text())
        assert cost.flops > 0, arch
        assert cost.hbm_bytes > 0, arch
        print(f"TRAIN-OK {arch} flops={cost.flops:.3g}")

        # decode step against a cache
        dshape = ShapeSpec("d", seq_len=64, global_batch=8, kind="decode")
        cache_shapes = jax.eval_shape(
            lambda: M.init_cache(cfg, dshape.global_batch, dshape.seq_len))
        dstep = steps_mod.build_decode(cfg, rules, max_seq=dshape.seq_len,
                                       batch=dshape.global_batch,
                                       batch_shapes=M.input_specs(cfg, dshape),
                                       cache_shapes=sds(cache_shapes))
        dstep.lower(params_shape, M.input_specs(cfg, dshape),
                    sds(cache_shapes)).compile()
        print(f"DECODE-OK {arch}")
""")


@pytest.mark.slow
def test_dryrun_pipeline_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    for arch in ("internlm2_1_8b", "qwen3_moe_235b_a22b", "mamba2_780m"):
        assert f"TRAIN-OK {arch}" in res.stdout
        assert f"DECODE-OK {arch}" in res.stdout
