"""Block-pool (paged) decode cache vs the dense masked decode cache: same
model, same prompt, the two layouts must produce the same logits/tokens.

The paged layout stores KV in per-layer pools (num_blocks, block_size, n_kv,
head_dim) addressed by a block table; masked columns contribute exactly zero
to the softmax, so the gathered-view attention matches the dense masked
attention row for row."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import model as M

BLOCK = 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _insert_prefill(pools, cache_row, blocks, block_size):
    """Scatter a (1, S, ...) prefill cache into the pool at ``blocks``."""
    n = len(blocks)
    tbl = jnp.asarray(blocks, jnp.int32)

    def one(pool, leaf):
        # leaf (L, 1, S, nkv, hd) -> (L, n, BS, nkv, hd) rows for n blocks
        rows = leaf[:, 0, : n * block_size]
        rows = rows.reshape(leaf.shape[0], n, block_size, *leaf.shape[3:])
        return pool.at[:, tbl].set(rows.astype(pool.dtype))

    return {"layers": jax.tree.map(one, pools["layers"], cache_row["layers"])}


class TestPagedDecodeMatchesDense:
    @pytest.mark.parametrize("prompt_len,steps", [(5, 6), (12, 3)])
    def test_greedy_tokens_identical(self, setup, prompt_len, steps):
        cfg, params = setup
        max_seq = 32
        prompt = np.arange(1, prompt_len + 1, dtype=np.int32)[None, :] % 100

        # dense masked path
        batch = {"tokens": jnp.asarray(prompt), "max_seq": max_seq}
        logits, dense_cache, _ = M.apply(cfg, params, batch, mode="prefill")
        tok_d = int(jnp.argmax(logits[0, -1]))
        dense_tokens = [tok_d]
        for _ in range(steps):
            logits, dense_cache, _ = M.apply(
                cfg, params, {"tokens": jnp.full((1, 1), dense_tokens[-1],
                                                 jnp.int32)},
                mode="decode", cache=dense_cache)
            dense_tokens.append(int(jnp.argmax(logits[0, -1])))

        # paged path: same prefill, scattered into a block pool
        assert M.supports_paged(cfg)
        need = -(-(prompt_len + steps) // BLOCK)
        pools = M.init_paged_cache(cfg, num_blocks=need + 3, block_size=BLOCK)
        _, row_cache, _ = M.apply(cfg, params, batch, mode="prefill")
        blocks = list(range(2, 2 + need))  # deliberately not starting at 0
        pools = _insert_prefill(pools, row_cache, blocks, BLOCK)
        tables = jnp.asarray([blocks], jnp.int32)
        length = prompt_len
        paged_tokens = [tok_d]
        for _ in range(steps):
            cache = {"layers": pools["layers"],
                     "pos": jnp.asarray([length], jnp.int32),
                     "block_tables": tables}
            logits, cache, _ = M.apply(
                cfg, params, {"tokens": jnp.full((1, 1), paged_tokens[-1],
                                                 jnp.int32)},
                mode="decode", cache=cache)
            pools = {"layers": cache["layers"]}
            length += 1
            paged_tokens.append(int(jnp.argmax(logits[0, -1])))

        assert paged_tokens == dense_tokens

    def test_rows_write_disjoint_blocks(self, setup):
        """Two rows decoding in one paged call touch only their own blocks."""
        cfg, params = setup
        pools = M.init_paged_cache(cfg, num_blocks=6, block_size=BLOCK)
        marker = jax.tree.map(lambda p: p + 7.0, pools["layers"])
        tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
        cache = {"layers": marker, "pos": jnp.asarray([3, 9], jnp.int32),
                 "block_tables": tables}
        _, new_cache, _ = M.apply(cfg, params,
                                  {"tokens": jnp.asarray([[1], [2]],
                                                         jnp.int32)},
                                  mode="decode", cache=cache)
        for leaf, old in zip(jax.tree.leaves(new_cache["layers"]),
                             jax.tree.leaves(marker)):
            # blocks 4..5 belong to nobody: must be untouched
            np.testing.assert_array_equal(np.asarray(leaf[:, 4:]),
                                          np.asarray(old[:, 4:]))
            # row 0 writes block 0 offset 3; row 1 writes block 1 (=table
            # entry 1 of row 1 -> pool block 3) offset 1
            assert not np.array_equal(np.asarray(leaf[:, 0, 3]),
                                      np.asarray(old[:, 0, 3]))
            assert not np.array_equal(np.asarray(leaf[:, 3, 1]),
                                      np.asarray(old[:, 3, 1]))

    def test_undeclared_families_raise(self):
        """Stripping the declared cache_family must kill the paged path —
        there is NO silent dense fallback for non-GQA stacks."""
        import dataclasses

        for arch in ("deepseek_v2_lite_16b", "mamba2_780m", "zamba2_7b",
                     "whisper_medium"):
            cfg = dataclasses.replace(get_config(arch).reduced(),
                                      cache_family="")
            assert M.cache_family(cfg) is None
            assert not M.supports_paged(cfg)
            with pytest.raises(NotImplementedError):
                M.init_paged_cache(cfg, num_blocks=4, block_size=8)


# --------------------------------------------------------------------------
# every cache family: paged greedy decode == dense greedy decode
# --------------------------------------------------------------------------


def _stage(cfg, pools, views, *, blocks, block_size, slab, seg):
    """Generic per-kind scatter of a 1-row prefill cache into the pools —
    the same staging the serving engine performs, family-agnostic."""
    kinds = M.paged_pool_kinds(cfg)
    tbl = jnp.asarray(blocks, jnp.int32)
    n = len(blocks)

    def block_scatter(pool, leaf):
        rows = leaf[:, 0, : n * block_size]
        rows = rows.reshape(leaf.shape[0], n, block_size, *leaf.shape[3:])
        return pool.at[:, tbl].set(rows.astype(pool.dtype))

    def row_scatter(idx):
        def f(pool, leaf):
            return pool.at[:, idx].set(leaf[:, 0].astype(pool.dtype))
        return f

    out = {}
    for key, kind in kinds.items():
        f = block_scatter if kind == "block" else row_scatter(
            slab if kind == "slab" else seg)
        out[key] = jax.tree.map(f, pools[key], views[key])
    return out


def _decode_cache(cfg, pools, *, length, tables, slab, seg):
    kinds = set(M.paged_pool_kinds(cfg).values())
    cache = dict(pools)
    cache["pos"] = jnp.asarray([length], jnp.int32)
    if "block" in kinds:
        cache["block_tables"] = tables
    if "slab" in kinds:
        cache["slab_ids"] = jnp.asarray([slab], jnp.int32)
    if "segment" in kinds:
        cache["segment_ids"] = jnp.asarray([seg], jnp.int32)
    return cache


class TestPagedFamiliesMatchDense:
    """The tentpole bar: for EVERY cache family, greedy decode through the
    pooled layout must produce the same tokens as the dense masked path."""

    @pytest.mark.parametrize("arch,family", [
        ("internlm2_1_8b", "gqa"),
        ("deepseek_v2_lite_16b", "mla"),
        ("mamba2_780m", "ssm"),
        ("zamba2_7b", "hybrid"),
        ("whisper_medium", "encdec"),
    ])
    def test_greedy_tokens_identical(self, arch, family):
        cfg = get_config(arch).reduced()
        assert M.cache_family(cfg) == family
        assert M.supports_paged(cfg)
        params = M.init_params(cfg, jax.random.PRNGKey(42))
        prompt_len, steps, max_seq = 5, 6, 32
        prompt = np.arange(1, prompt_len + 1, dtype=np.int32)[None, :] % 100

        batch = {"tokens": jnp.asarray(prompt), "max_seq": max_seq}
        if cfg.family == "encdec":
            frames = jax.random.normal(
                jax.random.PRNGKey(7), (1, cfg.encoder_seq, cfg.d_model),
                jnp.float32) * 0.1
            batch["frames"] = frames.astype(jnp.dtype(cfg.dtype))

        # dense masked path
        logits, dcache, _ = M.apply(cfg, params, batch, mode="prefill")
        tok0 = int(jnp.argmax(logits[0, -1]))
        dense_tokens = [tok0]
        for _ in range(steps):
            logits, dcache, _ = M.apply(
                cfg, params,
                {"tokens": jnp.full((1, 1), dense_tokens[-1], jnp.int32)},
                mode="decode", cache=dcache)
            dense_tokens.append(int(jnp.argmax(logits[0, -1])))

        # paged path: same prefill staged into the pools
        need = -(-(prompt_len + steps) // BLOCK)
        pools = M.init_paged_cache(cfg, num_blocks=need + 3, block_size=BLOCK,
                                   num_slabs=4, num_segments=2)
        _, row_cache, _ = M.apply(cfg, params, batch, mode="prefill")
        views = M.paged_insert_views(cfg, row_cache)
        blocks = list(range(2, 2 + need))  # deliberately not block 0
        slab, seg = 2, 1                   # deliberately not slot 0
        pools = _stage(cfg, pools, views, blocks=blocks, block_size=BLOCK,
                       slab=slab, seg=seg)
        tables = jnp.asarray([blocks], jnp.int32)
        kinds = M.paged_pool_kinds(cfg)
        length = prompt_len
        paged_tokens = [tok0]
        for _ in range(steps):
            cache = _decode_cache(cfg, pools, length=length, tables=tables,
                                  slab=slab, seg=seg)
            logits, cache, _ = M.apply(
                cfg, params,
                {"tokens": jnp.full((1, 1), paged_tokens[-1], jnp.int32)},
                mode="decode", cache=cache)
            pools = {k: cache[k] for k in kinds}
            length += 1
            paged_tokens.append(int(jnp.argmax(logits[0, -1])))

        assert paged_tokens == dense_tokens
