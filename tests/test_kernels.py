"""Pallas kernel correctness: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in kernels/ref.py, plus an end-to-end SSD equivalence check
against a naive recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_decode_attention import (paged_decode_attention,
                                                  paged_mla_decode_attention)
from repro.kernels.ssd_scan import ssd_intra, ssd_slab_decode


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,nq,nkv,s,h", [
        (1, 4, 4, 128, 64),    # MHA
        (2, 4, 2, 128, 64),    # GQA
        (1, 4, 1, 256, 128),   # MQA, two kv blocks per q row
        (1, 2, 2, 512, 64),    # multiple q and kv blocks
    ])
    def test_causal_matches_ref(self, b, nq, nkv, s, h, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = _rand(ks[0], (b, nq, s, h), dtype)
        k = _rand(ks[1], (b, nkv, s, h), dtype)
        v = _rand(ks[2], (b, nkv, s, h), dtype)
        got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = _rand(ks[0], (1, 2, 256, 64), jnp.float32)
        k = _rand(ks[1], (1, 2, 256, 64), jnp.float32)
        v = _rand(ks[2], (1, 2, 256, 64), jnp.float32)
        got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_scale_override(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = _rand(ks[0], (1, 1, 128, 64), jnp.float32)
        k = _rand(ks[1], (1, 1, 128, 64), jnp.float32)
        v = _rand(ks[2], (1, 1, 128, 64), jnp.float32)
        got = flash_attention(q, k, v, causal=True, scale=0.5, block_q=64,
                              block_k=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, scale=0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,nq,nkv,smax,h", [
        (2, 4, 4, 256, 64),
        (2, 8, 2, 512, 64),
        (1, 4, 1, 1024, 128),
    ])
    def test_matches_ref(self, b, nq, nkv, smax, h, dtype):
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = _rand(ks[0], (b, nq, h), dtype)
        k = _rand(ks[1], (b, nkv, smax, h), dtype)
        v = _rand(ks[2], (b, nkv, smax, h), dtype)
        lengths = jax.random.randint(ks[3], (b,), 1, smax + 1)
        got = decode_attention(q, k, v, lengths, block_k=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_ragged_lengths_skip_blocks(self):
        """Tiny lengths: only the masked prefix participates."""
        b, nq, smax, h = 3, 2, 512, 64
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = _rand(ks[0], (b, nq, h), jnp.float32)
        k = _rand(ks[1], (b, nq, smax, h), jnp.float32)
        v = _rand(ks[2], (b, nq, smax, h), jnp.float32)
        lengths = jnp.array([1, 7, 130])
        got = decode_attention(q, k, v, lengths, block_k=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestPagedDecodeAttention:
    """Block-table KV gather: the paged kernel must equal dense decode
    attention over the gathered contiguous view (kernels/ref.py oracle)."""

    @staticmethod
    def _make(key, b, nq, nkv, h, nb, bs, w, dtype):
        ks = jax.random.split(key, 4)
        q = _rand(ks[0], (b, nq, h), dtype)
        k_pool = _rand(ks[1], (nb, bs, nkv, h), dtype)
        v_pool = _rand(ks[2], (nb, bs, nkv, h), dtype)
        # each row gets w distinct pool blocks, deliberately out of order
        perm = jax.random.permutation(ks[3], nb)[: b * w]
        tables = perm.reshape(b, w).astype(jnp.int32)
        return q, k_pool, v_pool, tables

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,nq,nkv,h,nb,bs,w", [
        (2, 4, 4, 64, 16, 16, 4),    # MHA
        (2, 8, 2, 64, 32, 32, 6),    # GQA
        (1, 4, 1, 128, 8, 64, 3),    # MQA
    ])
    def test_matches_ref(self, b, nq, nkv, h, nb, bs, w, dtype):
        key = jax.random.PRNGKey(11)
        q, kp, vp, tables = self._make(key, b, nq, nkv, h, nb, bs, w, dtype)
        lengths = jax.random.randint(jax.random.fold_in(key, 1), (b,), 1,
                                     w * bs + 1)
        got = paged_decode_attention(q, kp, vp, tables, lengths,
                                     interpret=True)
        want = ref.paged_decode_attention_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])

    def test_short_lengths_skip_blocks(self):
        """Rows whose length covers only the first block(s): remaining table
        entries may point anywhere (pad blocks) without affecting output."""
        b, nq, nkv, h, nb, bs, w = 3, 2, 2, 64, 12, 16, 4
        q, kp, vp, tables = self._make(jax.random.PRNGKey(12), b, nq, nkv, h,
                                       nb, bs, w, jnp.float32)
        lengths = jnp.array([1, 16, 17], jnp.int32)
        got = paged_decode_attention(q, kp, vp, tables, lengths,
                                     interpret=True)
        want = ref.paged_decode_attention_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # scribbling on the dead tail blocks must not change the output
        tables2 = tables.at[0, 1:].set(0).at[1, 1:].set(0)
        got2 = paged_decode_attention(q, kp, vp, tables2, lengths,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(got[:2]),
                                      np.asarray(got2[:2]))

    def test_matches_masked_dense_kernel(self):
        """Paged and masked-dense kernels share the online-softmax core: on
        the same logical cache they must agree to fp tolerance."""
        b, nq, nkv, h, bs, w = 2, 4, 2, 64, 32, 4
        smax = bs * w
        ks = jax.random.split(jax.random.PRNGKey(13), 3)
        q = _rand(ks[0], (b, nq, h), jnp.float32)
        k = _rand(ks[1], (b, nkv, smax, h), jnp.float32)
        v = _rand(ks[2], (b, nkv, smax, h), jnp.float32)
        lengths = jnp.array([smax, 37], jnp.int32)
        # identity paging: row b uses blocks [b*w, b*w+1, ...)
        tables = (jnp.arange(b)[:, None] * w + jnp.arange(w)[None, :]
                  ).astype(jnp.int32)
        kp = jnp.swapaxes(k, 1, 2).reshape(b * w, bs, nkv, h)
        vp = jnp.swapaxes(v, 1, 2).reshape(b * w, bs, nkv, h)
        dense = decode_attention(q, k, v, lengths, block_k=bs, interpret=True)
        paged = paged_decode_attention(q, kp, vp, tables, lengths,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                                   rtol=1e-6, atol=1e-6)


class TestPagedMLADecodeAttention:
    """Latent block pools: the absorbed-MLA paged kernel must equal the
    gathered-view oracle (key = latent‖rope, value = latent)."""

    @staticmethod
    def _make(key, b, nq, r, pr, nb, bs, w, dtype):
        ks = jax.random.split(key, 5)
        q_lat = _rand(ks[0], (b, nq, r), dtype)
        q_rope = _rand(ks[1], (b, nq, pr), dtype)
        ckv = _rand(ks[2], (nb, bs, r), dtype)
        krope = _rand(ks[3], (nb, bs, pr), dtype)
        perm = jax.random.permutation(ks[4], nb)[: b * w]
        tables = perm.reshape(b, w).astype(jnp.int32)
        return q_lat, q_rope, ckv, krope, tables

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,nq,r,pr,nb,bs,w", [
        (2, 4, 32, 8, 16, 16, 4),
        (1, 8, 64, 16, 12, 32, 3),
    ])
    def test_matches_ref(self, b, nq, r, pr, nb, bs, w, dtype):
        key = jax.random.PRNGKey(21)
        ql, qr, ckv, krope, tables = self._make(key, b, nq, r, pr, nb, bs, w,
                                                dtype)
        lengths = jax.random.randint(jax.random.fold_in(key, 1), (b,), 1,
                                     w * bs + 1)
        got = paged_mla_decode_attention(ql, qr, ckv, krope, tables, lengths,
                                         interpret=True)
        want = ref.paged_mla_decode_attention_ref(ql, qr, ckv, krope, tables,
                                                  lengths)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])

    def test_short_lengths_skip_blocks(self):
        b, nq, r, pr, nb, bs, w = 3, 2, 32, 8, 12, 16, 4
        ql, qr, ckv, krope, tables = self._make(jax.random.PRNGKey(22), b, nq,
                                                r, pr, nb, bs, w, jnp.float32)
        lengths = jnp.array([1, 16, 17], jnp.int32)
        got = paged_mla_decode_attention(ql, qr, ckv, krope, tables, lengths,
                                         interpret=True)
        want = ref.paged_mla_decode_attention_ref(ql, qr, ckv, krope, tables,
                                                  lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        tables2 = tables.at[0, 1:].set(0).at[1, 1:].set(0)
        got2 = paged_mla_decode_attention(ql, qr, ckv, krope, tables2, lengths,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(got[:2]), np.asarray(got2[:2]))

    def test_custom_scale(self):
        b, nq, r, pr, nb, bs, w = 1, 2, 16, 8, 8, 16, 2
        ql, qr, ckv, krope, tables = self._make(jax.random.PRNGKey(23), b, nq,
                                                r, pr, nb, bs, w, jnp.float32)
        lengths = jnp.array([20], jnp.int32)
        # MLA scales by the QK head dim (nope+rope), NOT the latent rank
        got = paged_mla_decode_attention(ql, qr, ckv, krope, tables, lengths,
                                         scale=24 ** -0.5, interpret=True)
        want = ref.paged_mla_decode_attention_ref(ql, qr, ckv, krope, tables,
                                                  lengths, scale=24 ** -0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestSSDSlabDecode:
    """Slab-pool state gather: one recurrent step addressed through slab
    ids must equal ssd_decode_step on the gathered states."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,h,p,n,g,ns", [
        (2, 4, 16, 24, 1, 8),
        (3, 4, 32, 16, 2, 6),
    ])
    def test_matches_ref(self, b, h, p, n, g, ns, dtype):
        ks = jax.random.split(jax.random.PRNGKey(31), 6)
        pool = _rand(ks[0], (ns, h, p, n), jnp.float32)
        slabs = jax.random.permutation(ks[1], ns)[:b].astype(jnp.int32)
        x = _rand(ks[2], (b, h, p), dtype)
        dt = jax.nn.softplus(_rand(ks[3], (b, h), jnp.float32))
        A = -jnp.abs(_rand(ks[4], (h,), jnp.float32)) * 0.5
        B = _rand(ks[5], (b, g, n), dtype)
        C = _rand(jax.random.fold_in(ks[5], 1), (b, g, n), dtype)
        got_y, got_s = ssd_slab_decode(pool, slabs, x, dt, A, B, C,
                                       interpret=True)
        want_y, want_s = ref.ssd_slab_decode_ref(pool, slabs, x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(got_y, np.float32),
                                   np.asarray(want_y, np.float32), **TOL[dtype])
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   rtol=1e-5, atol=1e-5)

    def test_scatter_roundtrip_matches_model_step(self):
        """pool.at[slabs].set(states) after the kernel equals running
        models.ssm.ssd_decode_step on the gathered slabs directly."""
        from repro.models.ssm import ssd_decode_step

        b, h, p, n, ns = 2, 2, 8, 12, 5
        ks = jax.random.split(jax.random.PRNGKey(32), 6)
        pool = _rand(ks[0], (ns, h, p, n), jnp.float32)
        slabs = jnp.array([3, 1], jnp.int32)
        x = _rand(ks[2], (b, h, p), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[3], (b, h), jnp.float32))
        A = -jnp.abs(_rand(ks[4], (h,), jnp.float32)) * 0.5
        B = _rand(ks[5], (b, 1, n), jnp.float32)
        C = _rand(jax.random.fold_in(ks[5], 2), (b, 1, n), jnp.float32)
        y, states = ssd_slab_decode(pool, slabs, x, dt, A, B, C,
                                    interpret=True)
        new_pool = pool.at[slabs].set(states)
        y2, s2 = ssd_decode_step(pool[slabs], x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(new_pool[slabs]),
                                   np.asarray(s2), rtol=1e-5, atol=1e-5)
        # untouched slabs stay bit-identical
        rest = np.setdiff1d(np.arange(ns), np.asarray(slabs))
        np.testing.assert_array_equal(np.asarray(new_pool[rest]),
                                      np.asarray(pool[rest]))


class TestSSDIntra:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("m,h,q,p,n", [
        (2, 2, 64, 32, 32),
        (1, 4, 128, 64, 128),
        (3, 1, 256, 64, 64),
    ])
    def test_matches_ref(self, m, h, q, p, n, dtype):
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        x = _rand(ks[0], (m, h, q, p), dtype)
        dt = jax.nn.softplus(_rand(ks[1], (m, h, q), jnp.float32))
        dA = -jnp.abs(_rand(ks[2], (m, h, q), jnp.float32)) * 0.1
        B = _rand(ks[3], (m, q, n), dtype)
        C = _rand(ks[4], (m, q, n), dtype)
        got_y, got_s = ssd_intra(x, dt, dA, B, C, interpret=True)
        want_y, want_s = ref.ssd_intra_ref(x, dt, dA, B, C)
        np.testing.assert_allclose(np.asarray(got_y, np.float32),
                                   np.asarray(want_y, np.float32), **TOL[dtype])
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                                   atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


class TestSSDAgainstNaiveRecurrence:
    def test_chunked_equals_sequential(self):
        """models/ssm.ssd_chunked must equal the naive per-step recurrence
        h_t = exp(dA_t) h_{t-1} + dt_t B_t x_t^T ; y_t = C_t h_t + 0."""
        from repro.models.ssm import ssd_chunked

        b, s, h, p, g, n = 2, 64, 4, 16, 1, 24
        ks = jax.random.split(jax.random.PRNGKey(6), 5)
        x = _rand(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[1], (b, s, h), jnp.float32))
        A = -jnp.abs(_rand(ks[2], (h,), jnp.float32)) * 0.5
        B = _rand(ks[3], (b, s, g, n), jnp.float32)
        C = _rand(ks[4], (b, s, g, n), jnp.float32)

        y_chunk, final_chunk = ssd_chunked(x, dt, A, B, C, chunk=16)

        # naive sequential reference
        state = np.zeros((b, h, p, n), np.float32)
        ys = []
        xn, dtn = np.asarray(x), np.asarray(dt)
        Bn = np.repeat(np.asarray(B), h // g, axis=2)
        Cn = np.repeat(np.asarray(C), h // g, axis=2)
        An = np.asarray(A)
        for t in range(s):
            dec = np.exp(dtn[:, t] * An)  # (b,h)
            upd = np.einsum("bh,bhp,bhn->bhpn", dtn[:, t], xn[:, t], Bn[:, t])
            state = dec[:, :, None, None] * state + upd
            ys.append(np.einsum("bhpn,bhn->bhp", state, Cn[:, t]))
        y_ref = np.stack(ys, axis=1)

        np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final_chunk), state, rtol=2e-4,
                                   atol=2e-4)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(4, 16, 64), (2, 128), (1, 3, 7, 256)])
    def test_matches_ref(self, shape, dtype):
        from repro.kernels.rmsnorm import rmsnorm
        from repro.models.layers import rms_norm

        ks = jax.random.split(jax.random.PRNGKey(7), 2)
        x = _rand(ks[0], shape, dtype)
        w = _rand(ks[1], shape[-1:], dtype) * 0.1 + 1.0
        got = rmsnorm(x, w, eps=1e-5, block_rows=2, interpret=True)
        want = rms_norm(x, w, 1e-5)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **TOL[dtype])

    def test_odd_row_count(self):
        from repro.kernels.rmsnorm import rmsnorm
        from repro.models.layers import rms_norm

        x = _rand(jax.random.PRNGKey(8), (3, 5, 32), jnp.float32)
        w = jnp.ones((32,))
        got = rmsnorm(x, w, block_rows=4, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(rms_norm(x, w, 1e-5)),
                                   rtol=1e-5, atol=1e-5)
