"""Pipeline parallelism: the GPipe schedule over a mesh axis must equal the
sequential layer stack, forward AND backward.  Subprocess (needs >1 host
device)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.distributed.pipeline import (bubble_fraction, pipeline_apply,
                                            stack_stages)

    L, D, MB, BS = 8, 16, 6, 4   # 8 layers, 6 microbatches of 4
    P_STAGES = 4
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (L, D, D), jnp.float32) * (D ** -0.5)
    bs = jax.random.normal(jax.random.fold_in(key, 1), (L, D), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (MB, BS, D), jnp.float32)

    def layer(w, b, h):
        return jnp.tanh(h @ w + b)

    def sequential(params, xs):
        def body(h, lp):
            return layer(lp[0], lp[1], h), None
        out = []
        for m in range(xs.shape[0]):
            h, _ = jax.lax.scan(body, xs[m], params)
            out.append(h)
        return jnp.stack(out)

    def stage_fn(sparams, h):
        def body(hh, lp):
            return layer(lp[0], lp[1], hh), None
        h, _ = jax.lax.scan(body, h, sparams)
        return h

    mesh = Mesh(np.asarray(jax.devices()[:P_STAGES]), ("pipe",))
    staged = stack_stages((ws, bs), P_STAGES)

    want = sequential((ws, bs), x)
    got = jax.jit(lambda p, xx: pipeline_apply(
        stage_fn, p, xx, mesh=mesh, axis="pipe"))(staged, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("FWD-OK")

    # backward: gradients through the pipeline == sequential gradients
    def loss_pipe(p):
        return jnp.sum(pipeline_apply(stage_fn, p, x, mesh=mesh,
                                      axis="pipe") ** 2)
    def loss_seq(p):
        return jnp.sum(sequential(p, x) ** 2)

    g_pipe = jax.grad(lambda p: loss_pipe(stack_stages(p, P_STAGES)))((ws, bs))
    g_seq = jax.grad(loss_seq)((ws, bs))
    for a, b in zip(jax.tree_util.tree_leaves(g_pipe),
                    jax.tree_util.tree_leaves(g_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    print("BWD-OK")
    assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
    print("DONE")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "FWD-OK" in res.stdout and "BWD-OK" in res.stdout
