"""Engine end-to-end over every cache family: one paged substrate serving
GQA KV blocks, MLA latent blocks, SSM state slabs, hybrid block+slab
stacks, and enc-dec shared cross segments.

The bar, per family: greedy tokens through the paged batched engine are
BIT-IDENTICAL to the unbatched dense path, a live migration mid-decode
keeps them identical, and after the streams drain the per-kind leak probe
(``kv_usage``) reads zero everywhere.
"""

import numpy as np
import pytest

import jax

from repro.configs.registry import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine, StreamSpec

STEPS = 4

FAMILY_ARCHS = [
    ("internlm2_1_8b", "gqa"),
    ("deepseek_v2_lite_16b", "mla"),
    ("mamba2_780m", "ssm"),
    ("zamba2_7b", "hybrid"),
    ("whisper_medium", "encdec"),
]


@pytest.fixture(scope="module", params=FAMILY_ARCHS,
                ids=[f for _, f in FAMILY_ARCHS])
def setup(request):
    arch, family = request.param
    cfg = get_config(arch).reduced()
    assert M.cache_family(cfg) == family
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    return cfg, params, family


def _spec(name, prio, steps=STEPS):
    return StreamSpec(name=name, priority=prio, period_ms=8000.0,
                      deadline_ms=8000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _reference_tokens(cfg, params, prompt, steps=STEPS):
    eng = ServeEngine(cfg, params, max_seq=32)
    try:
        assert eng.admit(_spec("ref", 1, steps=steps)).admitted
        return eng.generate("ref", prompt, steps=steps).tokens
    finally:
        eng.close()


def _paged_engine(cfg, params, *, num_servers=2):
    return ServeEngine(cfg, params, max_seq=32, num_servers=num_servers,
                       batching=True, max_batch=4, paged=True,
                       kv_block_size=8)


KINDS = {"block": "blocks", "slab": "slabs", "segment": "segments"}


class TestPagedFamiliesEngine:
    def test_greedy_tokens_and_migration_bit_identical(self, setup):
        cfg, params, family = setup
        prompt = np.array([[1, 2, 3, 4, 5]], np.int32)
        want = _reference_tokens(cfg, params, prompt)
        eng = _paged_engine(cfg, params)
        try:
            assert eng._family == family
            assert eng.admit(_spec("s0", 1)).admitted
            res = eng.generate("s0", prompt, steps=STEPS)
            assert res.tokens == want
            # live migration at a step boundary: still bit-identical
            src = eng.pool.server_of("s0")
            dst = 1 - src
            decision, d = eng.admission.migrate("s0", dst)
            assert decision.admitted and d == dst
            assert eng.pool.request_migration("s0", dst)
            assert eng.generate("s0", prompt, steps=STEPS).tokens == want
            assert eng.migrations_completed == 1
            assert eng.pool.server_of("s0") == dst
            # drained: every pool kind back to zero (scratch excluded)
            assert eng.kv_usage() == {"blocks": 0, "slabs": 0,
                                      "segments": 0}
            assert eng.kv_blocks_in_use() == 0
        finally:
            eng.close()

    def test_leak_probe_reports_per_kind(self, setup):
        """The kinds the family uses show up in kv_usage() while a
        reservation is live, and ONLY those kinds."""
        cfg, params, family = setup
        eng = _paged_engine(cfg, params, num_servers=1)
        try:
            used_kinds = {KINDS[k] for k in eng._cache_kinds}
            seq_id, table, slab, seg = eng._paged_reserve(
                0, "probe", 5, STEPS, 8)
            usage = eng.kv_usage()
            for kind in ("blocks", "slabs", "segments"):
                if kind in used_kinds:
                    assert usage[kind] > 0, kind
                else:
                    assert usage[kind] == 0, kind
            state = eng._paged[0]
            if "block" in eng._cache_kinds:
                assert table[0] != state.scratch_block
            if "slab" in eng._cache_kinds:
                assert slab != state.scratch_slab
            if "segment" in eng._cache_kinds:
                assert seg != state.scratch_seg
            eng._paged_release(0, seq_id)
            eng.remove("probe")
            assert eng.kv_usage() == {"blocks": 0, "slabs": 0,
                                      "segments": 0}
        finally:
            eng.close()

    def test_shared_segment_dedup_across_streams(self, setup):
        """enc-dec only: two concurrent reservations share ONE cross
        segment (the engine's constant frames stub makes every stream's
        encoder content identical — the COW-dedup case)."""
        cfg, params, family = setup
        if family != "encdec":
            pytest.skip("segment pool is encdec-only")
        eng = _paged_engine(cfg, params, num_servers=1)
        try:
            sid_a, _, _, seg_a = eng._paged_reserve(0, "a", 4, STEPS, 8)
            sid_b, _, _, seg_b = eng._paged_reserve(0, "b", 4, STEPS, 8)
            assert seg_a == seg_b  # deduped by content key
            assert eng.kv_usage()["segments"] == 1
            eng._paged_release(0, sid_a)
            assert eng.kv_usage()["segments"] == 1  # b still holds it
            eng._paged_release(0, sid_b)
            assert eng.kv_usage()["segments"] == 0
            eng.remove("a")
            eng.remove("b")
        finally:
            eng.close()
