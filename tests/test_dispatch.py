"""Dispatch subsystem: ordering policy, BatchingServer coalescing,
ServerPool routing, pool allocation/analysis, and the multi-accelerator +
batched simulator modes."""

import math
import threading
import time

import pytest

from repro.core import server_analysis, simulator
from repro.core.admission import PoolAdmissionController
from repro.core.allocation import allocate, allocate_pool
from repro.core.dispatch import BatchingServer, ServerPool, request_key
from repro.core.task_model import GpuSegment, Task
from repro.core.taskset_gen import assign_rm_priorities


def _tasks(n, *, seg=GpuSegment(e=2.0, m=0.4), T=100.0, C=1.0):
    ts = [Task(name=f"t{i}", C=C, T=T + i, D=T + i, segments=(seg,))
          for i in range(n)]
    return assign_rm_priorities(ts)


class TestPolicy:
    def test_priority_key_orders_descending(self):
        assert request_key("priority", priority=5) < request_key("priority", priority=1)

    def test_edf_key_orders_by_deadline_none_last(self):
        assert request_key("edf", deadline=1.0) < request_key("edf", deadline=2.0)
        assert request_key("edf", deadline=2.0) < request_key("edf")

    def test_fifo_key_constant(self):
        assert request_key("fifo", priority=9) == request_key("fifo", priority=1)

    def test_unknown_ordering_raises(self):
        with pytest.raises(ValueError):
            request_key("lifo")


class TestBatchingServer:
    def test_coalesces_same_key(self):
        with BatchingServer(max_batch=8) as srv:
            gate = threading.Event()

            def blocker():
                gate.wait(5.0)
                return "unblocked"

            blk = srv.submit(blocker)  # occupies the server thread
            time.sleep(0.05)  # let the blocker dequeue first

            def run_batch(payloads):
                return [p * 2 for p in payloads]

            reqs = [srv.submit_batch(i, run_batch=run_batch, batch_key="k")
                    for i in range(5)]
            gate.set()
            assert blk.wait(5.0) == "unblocked"
            assert [r.wait(5.0) for r in reqs] == [0, 2, 4, 6, 8]
            assert srv.stats.batches == 1
            assert srv.stats.batch_sizes == [5]

    def test_different_keys_not_coalesced(self):
        with BatchingServer(max_batch=8) as srv:
            gate = threading.Event()
            srv.submit(lambda: gate.wait(5.0))
            time.sleep(0.05)
            run = lambda ps: list(ps)  # noqa: E731
            ra = [srv.submit_batch(i, run_batch=run, batch_key="a") for i in range(2)]
            rb = [srv.submit_batch(i, run_batch=run, batch_key="b") for i in range(2)]
            gate.set()
            for r in (*ra, *rb):
                r.wait(5.0)
            assert sorted(srv.stats.batch_sizes) == [2, 2]

    def test_max_batch_respected(self):
        with BatchingServer(max_batch=2) as srv:
            gate = threading.Event()
            srv.submit(lambda: gate.wait(5.0))
            time.sleep(0.05)
            reqs = [srv.submit_batch(i, run_batch=lambda ps: list(ps),
                                     batch_key="k") for i in range(5)]
            gate.set()
            for r in reqs:
                r.wait(5.0)
            assert all(s <= 2 for s in srv.stats.batch_sizes)
            assert sum(srv.stats.batch_sizes) == 5

    def test_batch_error_propagates_to_all(self):
        with BatchingServer(max_batch=4) as srv:
            gate = threading.Event()
            srv.submit(lambda: gate.wait(5.0))
            time.sleep(0.05)

            def boom(payloads):
                raise RuntimeError("device fault")

            reqs = [srv.submit_batch(i, run_batch=boom, batch_key="k")
                    for i in range(3)]
            gate.set()
            for r in reqs:
                with pytest.raises(RuntimeError, match="device fault"):
                    r.wait(5.0)

    def test_plain_submit_still_works(self):
        with BatchingServer(max_batch=4) as srv:
            assert srv.submit(lambda: 7).wait(5.0) == 7


class TestServerPool:
    def test_worst_fit_routing(self):
        with ServerPool(2) as pool:
            assert pool.assign("a", utilization=0.5) == 0
            assert pool.assign("b", utilization=0.2) == 1
            assert pool.assign("c", utilization=0.1) == 1  # 0.5 vs 0.2
            assert pool.assign("d", utilization=0.1) == 1  # 0.5 vs 0.3

    def test_priority_tie_break_spreads_high_prio(self):
        with ServerPool(2) as pool:
            pool.assign("hi1", priority=10)
            # equal utilization: the second high-prio stream avoids hi1's server
            s1 = pool.server_of("hi1")
            s2 = pool.assign("hi2", priority=10)
            assert s2 != s1

    def test_pinned_assignment_and_submit(self):
        with ServerPool(2) as pool:
            assert pool.assign("x", server=1) == 1
            assert pool.submit("x", lambda: 3).wait(5.0) == 3
            assert pool.servers[1].stats.completed == 1
            assert pool.servers[0].stats.completed == 0

    def test_duplicate_assign_raises(self):
        with ServerPool(1) as pool:
            pool.assign("x")
            with pytest.raises(ValueError):
                pool.assign("x")

    def test_remove_frees_name(self):
        with ServerPool(1) as pool:
            pool.assign("x")
            pool.remove("x")
            pool.assign("x")  # no raise

    def test_submit_batch_requires_batching_pool(self):
        with ServerPool(1, batching=False) as pool:
            pool.assign("x")
            with pytest.raises(TypeError):
                pool.submit_batch("x", 1, run_batch=lambda p: p, batch_key="k")


class TestAllocatePool:
    def test_partitions_are_core_disjoint(self):
        system = allocate_pool(_tasks(8), 2, 2, epsilon=0.05)
        assert system.num_gpus == 2
        assert system.num_cores == 4
        cores0 = {t.core for t in system.device_tasks(0)}
        cores1 = {t.core for t in system.device_tasks(1)}
        assert cores0 <= {0, 1} and cores1 <= {2, 3}
        assert system.server_cores[0] in (0, 1)
        assert system.server_cores[1] in (2, 3)

    def test_gpu_load_balanced_wfd(self):
        system = allocate_pool(_tasks(6), 3, 2, epsilon=0.05)
        loads = [sum(t.G / t.T for t in system.device_tasks(d))
                 for d in range(3)]
        assert max(loads) - min(loads) < max(loads) + 1e-9  # every device used
        assert all(l > 0 for l in loads)

    def test_single_device_matches_allocate(self):
        tasks = _tasks(5)
        pool_sys = allocate_pool(tasks, 1, 2, epsilon=0.05)
        flat_sys = allocate(tasks, 2, approach="server", epsilon=0.05)
        a = server_analysis.analyze_pool(pool_sys)
        b = server_analysis.analyze(flat_sys)
        for t in tasks:
            assert a.wcrt(t.name) == pytest.approx(b.wcrt(t.name))


class TestAnalyzePool:
    def test_shared_core_across_devices_rejected(self):
        tasks = _tasks(2)
        bad = [tasks[0].with_core(0).with_device(0),
               tasks[1].with_core(0).with_device(1)]
        from repro.core.task_model import System

        system = System(tasks=bad, num_cores=1, epsilon=0.05,
                        server_cores=(0, 0))
        with pytest.raises(ValueError, match="shared across devices"):
            server_analysis.analyze_pool(system)

    def test_two_devices_analyzed_independently(self):
        system = allocate_pool(_tasks(8), 2, 2, epsilon=0.05)
        res = server_analysis.analyze_pool(system)
        assert set(res.response_times) == {t.name for t in system.tasks}
        # each partition's result equals analyzing its subsystem directly
        for d in (0, 1):
            sub = server_analysis.analyze(system.subsystem(d))
            for t in system.device_tasks(d):
                assert res.wcrt(t.name) == pytest.approx(sub.wcrt(t.name))

    def test_amortized_overhead(self):
        t = _tasks(1)[0]
        full = server_analysis.amortized_server_overhead(t, 0.05, 1)
        assert full == pytest.approx(2 * t.eta * 0.05)
        assert server_analysis.amortized_server_overhead(t, 0.05, 4) == (
            pytest.approx(full / 4))
        with pytest.raises(ValueError):
            server_analysis.amortized_server_overhead(t, 0.05, 0)


class TestAmortizedAdmissionMode:
    """PoolAdmissionController(min_batch=b): the optimistic 2*eps/b overhead
    mode for dispatchers that guarantee a minimum coalesced batch size."""

    @staticmethod
    def _heavy_task(name="hog"):
        # 10 requests/job x (e=1, m=0.2): with eps=5ms the full per-job
        # server overhead is 2*10*5 = 100ms — the dominant response term
        segs = (GpuSegment(e=1.0, m=0.2),) * 10
        return Task(name=name, C=1.0, T=200.0, D=50.0, segments=segs)

    def test_admits_set_the_default_mode_rejects(self):
        task = self._heavy_task()
        strict = PoolAdmissionController(1, cores_per_device=2,
                                         epsilon_ms=5.0)
        decision, _ = strict.try_admit(task)
        assert not decision.admitted  # W ~ C+G+100 = 113 > D=50

        amortized = PoolAdmissionController(1, cores_per_device=2,
                                            epsilon_ms=5.0, min_batch=4)
        decision, device = amortized.try_admit(task)
        assert decision.admitted  # W ~ C+G+25 = 38 <= 50
        assert device == 0

    def test_admits_strictly_more_task_sets(self):
        """Sweep generated task sets: every set the default mode admits in
        full, the amortized mode admits too (eps-monotonicity of the
        bounds), and at least one set is admitted ONLY when amortized."""
        import random

        from repro.core.taskset_gen import GenParams, generate_taskset

        strictly_more = 0
        for seed in range(20):
            rng = random.Random(seed)
            tasks = generate_taskset(
                GenParams(num_cores=2, num_tasks=(3, 6), epsilon_ms=5.0),
                rng)
            strict = PoolAdmissionController(1, cores_per_device=2,
                                             epsilon_ms=5.0)
            amort = PoolAdmissionController(1, cores_per_device=2,
                                            epsilon_ms=5.0, min_batch=8)
            n_strict = sum(strict.try_admit(t)[0].admitted for t in tasks)
            n_amort = sum(amort.try_admit(t)[0].admitted for t in tasks)
            assert n_amort >= n_strict, seed
            strictly_more += n_amort > n_strict
        assert strictly_more > 0

    def test_min_batch_validation(self):
        with pytest.raises(ValueError, match="min_batch"):
            PoolAdmissionController(1, min_batch=0)


class TestMultiGpuSimulator:
    def test_two_devices_run_independently(self):
        """A two-device pool must behave exactly like its two single-device
        partitions simulated separately (partition isolation)."""
        system = allocate_pool(_tasks(8), 2, 2, epsilon=0.05)
        pooled = simulator.simulate(system, mode="server", horizon_ms=400)
        for d in (0, 1):
            solo = simulator.simulate(system.subsystem(d), mode="server",
                                      horizon_ms=400)
            for t in system.device_tasks(d):
                assert pooled.wcrt(t.name) == pytest.approx(solo.wcrt(t.name))

    def test_batched_mode_coalesces_same_shape(self):
        seg = GpuSegment(e=4.0, m=0.5)
        tasks = assign_rm_priorities([
            Task(name=f"s{i}", C=1.0, T=100.0, D=100.0, segments=(seg,))
            for i in range(4)
        ])
        system = allocate(tasks, 2, approach="server", epsilon=0.05)
        unb = simulator.simulate(system, mode="server", horizon_ms=100)
        bat = simulator.simulate(system, mode="server_batched",
                                 horizon_ms=100, batch_max=4)
        worst_unb = max(unb.wcrt(t.name) for t in tasks)
        worst_bat = max(bat.wcrt(t.name) for t in tasks)
        assert worst_bat < worst_unb  # e paid once per batch, not per request
        # and batching never makes any task later
        for t in tasks:
            assert bat.wcrt(t.name) <= unb.wcrt(t.name) + 1e-9

    def test_batched_bound_still_dominates(self):
        system = allocate_pool(_tasks(6), 2, 2, epsilon=0.05)
        res = server_analysis.analyze_pool(system)
        sim = simulator.simulate(system, mode="server_batched",
                                 horizon_ms=500, batch_max=4)
        for t in system.tasks:
            bound = res.wcrt(t.name)
            if not math.isinf(bound):
                assert sim.wcrt(t.name) <= bound + 1e-3

    def test_mpcp_multi_device_locks(self):
        tasks = _tasks(4)
        sync = allocate(tasks, 2, approach="sync")
        placed = [t.with_device(i % 2) for i, t in enumerate(sync.tasks)]
        from repro.core.task_model import System

        system = System(tasks=placed, num_cores=2, server_cores=(0, 1))
        res = simulator.simulate(system, mode="mpcp", horizon_ms=400)
        assert all(res.wcrt(t.name) > 0 for t in tasks)


class TestPoolAdmission:
    def _stream(self, name, *, T=100.0, g=10.0, prio=1):
        return Task(name=name, C=1.0, T=T, D=T, priority=prio,
                    segments=(GpuSegment(e=g * 0.9, m=g * 0.1),))

    def test_spreads_across_devices(self):
        adm = PoolAdmissionController(2, cores_per_device=2)
        d1, dev1 = adm.try_admit(self._stream("a", prio=2))
        d2, dev2 = adm.try_admit(self._stream("b", prio=1))
        assert d1.admitted and d2.admitted
        assert {dev1, dev2} == {0, 1}  # WFD: second stream takes the idle device

    def test_rejects_when_all_devices_full(self):
        adm = PoolAdmissionController(2, cores_per_device=2)
        admitted = 0
        rejected = False
        for i in range(40):
            decision, dev = adm.try_admit(
                self._stream(f"s{i}", T=100.0, g=60.0, prio=40 - i))
            if decision.admitted:
                admitted += 1
                assert 0 <= dev < 2
            else:
                rejected = True
                assert dev == -1
                break
        assert admitted >= 2  # one per device at least
        assert rejected

    def test_duplicate_rejected(self):
        adm = PoolAdmissionController(1)
        assert adm.try_admit(self._stream("x"))[0].admitted
        dup, dev = adm.try_admit(self._stream("x"))
        assert not dup.admitted and dev == -1

    def test_remove_frees_capacity(self):
        adm = PoolAdmissionController(1, cores_per_device=2)
        assert adm.try_admit(self._stream("x", g=40.0))[0].admitted
        assert not adm.try_admit(self._stream("y", g=40.0, prio=2))[0].admitted
        adm.remove("x")
        assert adm.try_admit(self._stream("y", g=40.0, prio=2))[0].admitted
