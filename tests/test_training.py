"""Training substrate tests: optimizer, train step, checkpointing, data
pipeline, gradient compression."""

import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import grad_compress as gc
from repro.training import optimizer as opt
from repro.training.train_step import TrainSettings, build_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _data(cfg, b=4, s=16, step=0):
    src = SyntheticLM(DataConfig(cfg.vocab_size, s, b))
    return {k: jnp.asarray(v) for k, v in src.batch(step).items()}


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        c = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
        assert float(opt.schedule(c, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(opt.schedule(c, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
        assert float(opt.schedule(c, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    def test_update_decreases_loss(self, tiny):
        cfg, params = tiny
        c = opt.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
        state = opt.init(params, c)
        batch = _data(cfg)

        def loss(p):
            return M.loss_fn(cfg, p, batch, remat=False)[0]

        l0 = float(loss(params))
        for _ in range(5):
            l, g = jax.value_and_grad(loss)(params)
            params, state, _ = opt.update(g, state, params, c)
        assert float(loss(params)) < l0

    def test_moment_dtype_and_master(self, tiny):
        cfg, params = tiny
        c = opt.AdamWConfig(moment_dtype="bfloat16", master_dtype="float32")
        state = opt.init(params, c)
        assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state["mu"]))
        assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(state["master"]))

    def test_grad_clip(self, tiny):
        cfg, params = tiny
        c = opt.AdamWConfig(grad_clip=1e-9, lr=1.0, warmup_steps=0)
        state = opt.init(params, c)
        grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
        new_params, _, m = opt.update(grads, state, params, c)
        # clip to ~0 -> params ~unchanged apart from weight decay
        diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                         b.astype(jnp.float32))))
                   for a, b in zip(jax.tree.leaves(new_params),
                                   jax.tree.leaves(params)))
        assert diff < 0.2  # weight-decay-only scale
        assert float(m["grad_norm"]) > 0


class TestTrainStep:
    def test_end_to_end_steps(self, tiny):
        cfg, params = tiny
        settings = TrainSettings(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0))
        step = build_train_step(cfg, settings, None)
        state = opt.init(params, settings.adamw)
        losses = []
        for i in range(3):
            params, state, metrics = step(params, state, _data(cfg, step=i))
            losses.append(float(metrics["loss"]))
        assert all(math.isfinite(l) for l in losses)
        assert int(state["step"]) == 3

    def test_grad_accum_matches_full_batch(self, tiny):
        """accumulated microbatch gradients == full-batch gradients (linear
        loss in batch): compare resulting params after one step."""
        cfg, params = tiny
        batch = _data(cfg, b=8)
        s1 = TrainSettings(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0,
                                                 grad_clip=0.0), grad_accum=1)
        s2 = TrainSettings(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0,
                                                 grad_clip=0.0), grad_accum=4)
        st1 = opt.init(params, s1.adamw)
        st2 = opt.init(params, s2.adamw)
        p1, _, m1 = build_train_step(cfg, s1, None)(params, st1, batch)
        p2, _, m2 = build_train_step(cfg, s2, None)(params, st2, batch)
        # CE means differ across microbatches only by masking; tokens are
        # fully unmasked here, so means match.
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-3)


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tiny, tmp_path):
        cfg, params = tiny
        tree = {"params": params, "step": jnp.asarray(7)}
        ckpt.save(tmp_path, 7, tree)
        restored, step = ckpt.restore(tmp_path, tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_last_gc(self, tiny, tmp_path):
        cfg, params = tiny
        tree = {"p": jnp.ones((4,))}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(tmp_path, s, tree, keep_last=2)
        dirs = sorted(p.name for p in pathlib.Path(tmp_path).iterdir()
                      if p.name.startswith("step_"))
        assert dirs == ["step_00000004", "step_00000005"]
        assert ckpt.latest_step(tmp_path) == 5

    def test_structure_mismatch_raises(self, tmp_path):
        ckpt.save(tmp_path, 0, {"a": jnp.ones((2,))})
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path, {"a": jnp.ones((2,)), "b": jnp.ones((2,))})

    def test_shape_mismatch_raises(self, tmp_path):
        ckpt.save(tmp_path, 0, {"a": jnp.ones((2,))})
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path, {"a": jnp.ones((3,))})


class TestData:
    def test_deterministic_and_sharded(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
        full = SyntheticLM(cfg)
        b0 = full.batch(3)
        again = SyntheticLM(cfg).batch(3)
        np.testing.assert_array_equal(b0["tokens"], again["tokens"])
        # labels are next-token
        np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
        # shards are independent slices of the same distribution
        s0 = SyntheticLM(cfg, shard=0, num_shards=2).batch(3)
        s1 = SyntheticLM(cfg, shard=1, num_shards=2).batch(3)
        assert s0["tokens"].shape == (4, 8)
        assert not np.array_equal(s0["tokens"], s1["tokens"])
        assert (b0["tokens"] < 100).all() and (b0["tokens"] >= 0).all()

    def test_prefetcher(self):
        cfg = DataConfig(vocab_size=50, seq_len=4, global_batch=2)
        pf = Prefetcher(SyntheticLM(cfg), start_step=0, prefetch=2)
        try:
            steps = [pf.next()[0] for _ in range(4)]
            assert steps == [0, 1, 2, 3]
        finally:
            pf.close()


class TestGradCompress:
    def test_quantize_bounds(self):
        x = jnp.asarray(np.random.RandomState(0).randn(128, 64).astype(np.float32))
        q, s = gc.quantize_int8(x)
        err = np.abs(np.asarray(gc.dequantize_int8(q, s)) - np.asarray(x))
        assert err.max() <= float(s) / 2 + 1e-7

    def test_error_feedback_accumulates(self):
        """With error feedback, the running sum of dequantized values tracks
        the true running sum (bias-free compression)."""
        rs = np.random.RandomState(1)
        g_true = jnp.asarray(rs.randn(256).astype(np.float32) * 1e-3)
        err = jnp.zeros_like(g_true)
        total_q = np.zeros(256, np.float32)
        for _ in range(50):
            corrected = g_true + err
            q, s = gc.quantize_int8(corrected)
            deq = gc.dequantize_int8(q, s)
            err = corrected - deq
            total_q += np.asarray(deq)
        total_true = np.asarray(g_true) * 50
        np.testing.assert_allclose(total_q, total_true, atol=2 * float(s))
