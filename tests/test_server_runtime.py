"""Tests for the executable AcceleratorServer (threads) and admission control."""

import threading
import time

import pytest

from repro.core.admission import AdmissionController
from repro.core.server_runtime import AcceleratorServer
from repro.core.task_model import GpuSegment, Task


class TestAcceleratorServer:
    def test_basic_roundtrip(self):
        with AcceleratorServer() as srv:
            assert srv.call(lambda: 41 + 1) == 42

    def test_priority_ordering(self):
        """With the server busy, queued requests complete in priority order."""
        order = []
        gate = threading.Event()
        with AcceleratorServer(ordering="priority") as srv:
            srv.submit(lambda: gate.wait(5.0), name="blocker")
            time.sleep(0.05)  # let the blocker start
            reqs = [
                srv.submit(lambda i=i: order.append(i), priority=i, name=f"r{i}")
                for i in (1, 3, 2)
            ]
            gate.set()
            for r in reqs:
                r.wait(timeout=5.0)
        assert order == [3, 2, 1]

    def test_fifo_ordering(self):
        order = []
        gate = threading.Event()
        with AcceleratorServer(ordering="fifo") as srv:
            srv.submit(lambda: gate.wait(5.0))
            time.sleep(0.05)
            reqs = [
                srv.submit(lambda i=i: order.append(i), priority=i)
                for i in (1, 3, 2)
            ]
            gate.set()
            for r in reqs:
                r.wait(timeout=5.0)
        assert order == [1, 3, 2]

    def test_edf_ordering(self):
        order = []
        gate = threading.Event()
        now = time.monotonic()
        with AcceleratorServer(ordering="edf") as srv:
            srv.submit(lambda: gate.wait(5.0))
            time.sleep(0.05)
            reqs = [
                srv.submit(lambda d=d: order.append(d), deadline=now + d)
                for d in (3.0, 1.0, 2.0)
            ]
            gate.set()
            for r in reqs:
                r.wait(timeout=5.0)
        assert order == [1.0, 2.0, 3.0]

    def test_client_suspends_not_busy_waits(self):
        """wait() must block on an Event (suspension), not consume the result
        before completion."""
        with AcceleratorServer() as srv:
            req = srv.submit(lambda: (time.sleep(0.1), "done")[1])
            assert not req.done
            assert req.wait(timeout=5.0) == "done"
            assert req.done

    def test_error_propagates(self):
        with AcceleratorServer() as srv:
            req = srv.submit(lambda: 1 / 0)
            with pytest.raises(ZeroDivisionError):
                req.wait(timeout=5.0)

    def test_nonpreemptive_single_flight(self):
        """The accelerator executes one request at a time."""
        active = []
        peak = []

        def work():
            active.append(1)
            peak.append(len(active))
            time.sleep(0.01)
            active.pop()

        with AcceleratorServer() as srv:
            reqs = [srv.submit(work) for _ in range(8)]
            for r in reqs:
                r.wait(timeout=10.0)
        assert max(peak) == 1

    def test_stats_and_waiting_time(self):
        with AcceleratorServer() as srv:
            req = srv.submit(lambda: None)
            req.wait(timeout=5.0)
            assert req.waiting_time >= 0
            assert req.handling_time >= req.waiting_time
            assert srv.stats.completed == 1


class TestAdmission:
    def test_admits_light_and_rejects_overload(self):
        ac = AdmissionController(num_cores=2, epsilon_ms=0.05)
        light = Task("s1", C=1, T=100, D=100,
                     segments=(GpuSegment(e=5.0, m=0.5),))
        assert ac.try_admit(light).admitted
        # a stream whose GPU demand alone saturates the accelerator
        heavy = Task("s2", C=1, T=10, D=10,
                     segments=(GpuSegment(e=9.5, m=0.4),))
        decision = ac.try_admit(heavy)
        assert not decision.admitted
        # rejected stream must not linger
        assert [t.name for t in ac.streams] == ["s1"]

    def test_duplicate_rejected(self):
        ac = AdmissionController(num_cores=2)
        t = Task("s1", C=1, T=100, D=100)
        assert ac.try_admit(t).admitted
        assert not ac.try_admit(t).admitted

    def test_remove_then_admit(self):
        ac = AdmissionController(num_cores=2)
        t1 = Task("s1", C=1, T=10, D=10, segments=(GpuSegment(e=8.0, m=0.2),))
        t2 = Task("s2", C=1, T=10, D=10, segments=(GpuSegment(e=8.0, m=0.2),))
        assert ac.try_admit(t1).admitted
        assert not ac.try_admit(t2).admitted
        ac.remove("s1")
        assert ac.try_admit(t2).admitted


class TestMultiPodAdmission:
    def test_spills_to_second_pod(self):
        from repro.core.admission import MultiPodAdmission

        mp = MultiPodAdmission(num_pods=2)
        # each stream takes ~60% of one accelerator: two must split pods
        s1 = Task("s1", C=0.5, T=100, D=100, segments=(GpuSegment(e=60, m=1),))
        s2 = Task("s2", C=0.5, T=100, D=100, segments=(GpuSegment(e=60, m=1),))
        s3 = Task("s3", C=0.5, T=100, D=100, segments=(GpuSegment(e=60, m=1),))
        d1, p1 = mp.try_admit(s1)
        d2, p2 = mp.try_admit(s2)
        assert d1.admitted and d2.admitted
        assert p1 != p2  # worst-fit spreads load
        d3, p3 = mp.try_admit(s3)
        assert not d3.admitted and p3 == -1  # both accelerators saturated

    def test_remove_frees_pod(self):
        from repro.core.admission import MultiPodAdmission

        mp = MultiPodAdmission(num_pods=1)
        t = Task("t", C=0.5, T=100, D=100, segments=(GpuSegment(e=60, m=1),))
        u = Task("u", C=0.5, T=100, D=100, segments=(GpuSegment(e=60, m=1),))
        assert mp.try_admit(t)[0].admitted
        assert not mp.try_admit(u)[0].admitted
        mp.remove("t")
        assert mp.try_admit(u)[0].admitted


class TestFifoServerAnalysis:
    def test_bound_covers_fifo_simulation(self):
        import random

        from repro.core import server_analysis, simulator
        from repro.core.allocation import allocate
        from repro.core.taskset_gen import GenParams, generate_taskset

        rng = random.Random(11)
        for _ in range(20):
            tasks = generate_taskset(GenParams(num_cores=2, num_tasks=(3, 6)), rng)
            system = allocate(tasks, 2, approach="server", epsilon=0.05)
            res = server_analysis.analyze_fifo_server(system)
            sim = simulator.simulate(system, mode="server_fifo",
                                     horizon_ms=3 * max(t.T for t in tasks))
            for t in system.tasks:
                bound = res.response_times[t.name]
                import math
                if not math.isinf(bound):
                    assert sim.wcrt(t.name) <= bound + 1e-3, t.name
