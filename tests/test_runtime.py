"""Fault-tolerance runtime tests: checkpoint manager, heartbeat failure
detection, elastic rescale planning, straggler watchdogs, and the full
fail->detect->restore->resume loop."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import MeshPlan, plan_after_failure
from repro.runtime.fault_tolerance import (CheckpointManager, HeartbeatMonitor,
                                           TrainSupervisor)
from repro.runtime.straggler import DeadlineAwarePolicy, StepTimeWatchdog


class TestCheckpointManager:
    def test_interval_policy(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval=5, async_save=False)
        tree = {"w": jnp.ones((8,))}
        saved = [s for s in range(1, 21) if mgr.maybe_save(s, tree)]
        assert saved == [5, 10, 15, 20]
        assert mgr.latest_step() == 20

    def test_async_save_then_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval=1, async_save=True)
        tree = {"w": jnp.arange(16.0)}
        mgr.save(3, tree)
        restored, step = mgr.restore_latest(tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


class TestHeartbeat:
    def test_detects_silent_worker_once(self):
        failures = []
        mon = HeartbeatMonitor(timeout=0.3, poll=0.05,
                               on_failure=failures.append)
        try:
            mon.register("w0")
            mon.register("w1")
            t_end = time.monotonic() + 5.0
            while time.monotonic() < t_end and not failures:
                mon.beat("w0")  # w1 goes silent
                time.sleep(0.02)
            # keep w0 alive a bit longer: no duplicate/extra detections
            for _ in range(10):
                mon.beat("w0")
                time.sleep(0.02)
            assert failures == ["w1"]
            assert mon.alive_workers() == ["w0"]
        finally:
            mon.close()


    def test_context_manager_and_no_callback_after_close(self):
        """close() must guarantee no on_failure fires after it returns —
        the pool tears the monitor down FIRST on shutdown, and a late
        callback would race eviction into a half-closed pool."""
        failures = []
        with HeartbeatMonitor(timeout=0.05, poll=0.01,
                              on_failure=failures.append) as mon:
            mon.register("w0")
        # w0 is now overdue, but the monitor is closed: repeatedly give the
        # (dead) thread a chance to misfire
        time.sleep(0.2)
        assert failures == []
        mon.close()  # idempotent

    def test_unregister_stops_tracking(self):
        failures = []
        mon = HeartbeatMonitor(timeout=0.1, poll=0.02,
                               on_failure=failures.append)
        try:
            mon.register("gone")
            mon.unregister("gone")
            time.sleep(0.3)
            assert failures == []
            assert mon.alive_workers() == []
        finally:
            mon.close()


class TestElastic:
    def test_shrinks_data_axis_only(self):
        plan = plan_after_failure(256, model=16, global_batch=256)
        assert plan.shape == (16, 16)
        degraded = plan_after_failure(240, model=16, global_batch=256)
        # 240/16 = 15 -> largest divisor of 256 <= 15 is 8
        assert degraded.shape == (8, 16)
        assert degraded.axes == ("data", "model")

    def test_multi_pod_plan(self):
        plan = plan_after_failure(512, model=16, global_batch=256, pod=2)
        assert plan.shape == (2, 16, 16)

    def test_model_axis_is_preserved_or_error(self):
        with pytest.raises(ValueError):
            plan_after_failure(8, model=16, global_batch=64)


class TestStraggler:
    def test_watchdog_flags_outlier(self):
        wd = StepTimeWatchdog(factor=3.0, min_samples=5)
        for _ in range(10):
            assert not wd.observe(0.1)
        assert wd.observe(0.5)
        assert len(wd.flagged) == 1

    def test_watchdog_escalates_consecutive_stragglers(self):
        """escalate_after consecutive slow steps flips ``degraded``; one
        healthy step resets the streak."""
        wd = StepTimeWatchdog(factor=3.0, min_samples=5, escalate_after=3)
        for _ in range(10):
            wd.observe(0.1)
        wd.observe(0.5), wd.observe(0.5)
        assert not wd.degraded
        wd.observe(0.1)  # streak broken
        wd.observe(0.5), wd.observe(0.5)
        assert not wd.degraded
        wd.observe(0.5)
        assert wd.degraded

    def test_deadline_policy_boosts_at_risk(self):
        pol = DeadlineAwarePolicy(margin=0.8)
        pol.register("fast", deadline_ms=100)
        pol.register("slow", deadline_ms=100)
        for _ in range(20):
            pol.observe("fast", 10.0)
            pol.observe("slow", 90.0)
        assert pol.at_risk() == ["slow"]
        assert pol.boost("slow", 1) == 101
        assert pol.boost("fast", 1) == 1


class TestRecoveryLoop:
    def test_fail_detect_restore_resume(self, tmp_path):
        """End-to-end: train, checkpoint, 'kill' a worker, detect, restore
        from latest checkpoint, resume at the right step.  Generous timing
        margins: the monitor thread may be starved on a loaded CI host."""
        mgr = CheckpointManager(str(tmp_path), interval=2, async_save=False)
        sup = TrainSupervisor(mgr)
        mon = HeartbeatMonitor(timeout=0.3, poll=0.05,
                               on_failure=sup.on_failure)
        try:
            mon.register("w0")
            mon.register("w1")
            params = {"w": jnp.zeros((4,))}
            step = 0
            # train 5 steps, beating both workers
            for _ in range(5):
                step += 1
                params = {"w": params["w"] + 1.0}
                mgr.maybe_save(step, {"params": params, "step": jnp.asarray(step)})
                mon.beat("w0"), mon.beat("w1")
            # w1 dies
            t_end = time.monotonic() + 5.0
            while time.monotonic() < t_end and not sup.failure_pending:
                mon.beat("w0")
                time.sleep(0.02)
            assert sup.failure_pending
            assert sup.failures == ["w1"]
            # recover: restore latest checkpoint (step 4)
            tree_like = {"params": params, "step": jnp.asarray(0)}
            restored, ck_step = sup.recover(tree_like, mon.alive_workers())
            assert ck_step == 4
            assert float(restored["params"]["w"][0]) == 4.0
            assert not sup.failure_pending
        finally:
            mon.close()
