"""Unit tests for the server-based analysis (paper §5.2) including the
worked example of Figures 2/4."""

import math

import pytest

from repro.core import server_analysis as sa
from repro.core.task_model import GpuSegment, System, Task, server_utilization


def _example_system(eps: float) -> System:
    """The Figure 2/4 taskset: tau_h, tau_m on core 1 (with the server),
    tau_l on core 2.  One GPU segment each, between two 1-unit normal chunks.
    Segment lengths: 4 (tau_l), 3 (tau_h), 3 (tau_m)."""
    tau_h = Task("tau_h", C=2, T=100, D=100, priority=3, core=1,
                 segments=(GpuSegment(e=1.0, m=2.0),))
    tau_m = Task("tau_m", C=2, T=100, D=100, priority=2, core=1,
                 segments=(GpuSegment(e=1.0, m=2.0),))
    tau_l = Task("tau_l", C=2, T=100, D=100, priority=1, core=2,
                 segments=(GpuSegment(e=2.0, m=2.0),))
    return System(tasks=[tau_h, tau_m, tau_l], num_cores=3, epsilon=eps, server_core=1)


class TestRequestDriven:
    def test_no_gpu_task(self):
        sys_ = _example_system(0.05)
        t = Task("cpu_only", C=1, T=10, D=10, priority=0, core=0)
        sys2 = System(tasks=[*sys_.tasks, t], num_cores=3, epsilon=0.05, server_core=1)
        assert sa.request_driven_bound(sys2, t, horizon=10) == 0.0

    def test_highest_priority(self):
        """For the highest-priority task: only the longest lower-priority
        segment blocks (non-preemptive GPU), once, plus one eps."""
        eps = 0.05
        sys_ = _example_system(eps)
        tau_h = sys_.tasks[0]
        # lp segments: 3 (tau_m), 4 (tau_l) -> max 4; +eps
        assert sa.request_driven_bound(sys_, tau_h, horizon=100) == pytest.approx(4 + eps)

    def test_lowest_priority_includes_hp_carry_in(self):
        eps = 0.0
        sys_ = _example_system(eps)
        tau_l = sys_.tasks[2]
        # no lower-priority tasks -> first term 0; hp = tau_h, tau_m with one
        # segment each, periods 100.  B0 = 0; B1 = (ceil(0/100)+1)*3 * 2 = 6;
        # B2 = (ceil(6/100)+1)*3*2 = 12; B3 = 12 (fixpoint: ceil(12/100)=1).
        assert sa.request_driven_bound(sys_, tau_l, horizon=100) == pytest.approx(12.0)

    def test_divergence_returns_inf(self):
        # hp GPU demand exceeding the GPU's capacity -> diverges
        hp = Task("hp", C=0.1, T=1.5, D=1.5, priority=2, core=0,
                  segments=(GpuSegment(e=1.5, m=0.2),))
        lo = Task("lo", C=0.1, T=50, D=50, priority=1, core=0,
                  segments=(GpuSegment(e=1.0, m=0.1),))
        sys_ = System(tasks=[hp, lo], num_cores=2, epsilon=0.05, server_core=1)
        assert math.isinf(sa.request_driven_bound(sys_, lo, horizon=50))


class TestJobDriven:
    def test_formula(self):
        eps = 0.05
        sys_ = _example_system(eps)
        tau_m = sys_.tasks[1]
        # eta=1; lp max = 4+eps (tau_l); hp tau_h: (ceil(W/100)+1)*(3+eps)
        W = 10.0
        expected = (4 + eps) + (1 + 1) * (3 + eps)
        assert sa.job_driven_bound(sys_, tau_m, W) == pytest.approx(expected)

    def test_double_bound_takes_min(self):
        eps = 0.0
        sys_ = _example_system(eps)
        tau_l = sys_.tasks[2]
        rd = sa.request_driven_bound(sys_, tau_l, horizon=100)  # 12
        jd = sa.job_driven_bound(sys_, tau_l, 5.0)  # 0 + 2*(3+3) = ... per-task
        assert sa.waiting_bound(sys_, tau_l, 5.0, horizon=100) == pytest.approx(min(rd, jd))


class TestGpuHandling:
    def test_isolated_task(self):
        """A GPU task alone: B^w = 0, so B^gpu = G + 2*eta*eps (Lemma 2)."""
        eps = 0.05
        t = Task("solo", C=1, T=50, D=50, priority=1, core=0,
                 segments=(GpuSegment(e=2.0, m=0.5), GpuSegment(e=1.0, m=0.5)))
        sys_ = System(tasks=[t], num_cores=2, epsilon=eps, server_core=1)
        expected = t.G + 2 * 2 * eps
        assert sa.gpu_handling_time(sys_, t, 10.0, horizon=50) == pytest.approx(expected)
        # and the response time: C + B^gpu (no interference anywhere)
        res = sa.analyze(sys_)
        assert res.wcrt("solo") == pytest.approx(1 + expected)
        assert res.schedulable


class TestWorkedExample:
    """Figure 2/4 example: the server-based bound must cover the simulated
    6+4eps and stay meaningfully below the MPCP busy-wait response of 9+."""

    def test_tau_h_bound(self):
        eps = 0.05
        sys_ = _example_system(eps)
        res = sa.analyze(sys_)
        w_h = res.wcrt("tau_h")
        # Hand computation of Eq (6): C=2; B^w = B^rd = 4+eps (longest lp
        # segment); B^gpu = (4+eps) + 3 + 2*eps = 7.15.  Server interference:
        # tau_m and tau_l each contribute exec = G^m + 2*eta*eps = 2.1 with
        # jitter D - exec = 97.9, so for W in (2.1, 102.1]:
        # ceil((W+97.9)/100)=2 -> 4.2 each.  Fixpoint: 2 + 7.15 + 8.4 = 17.55.
        assert w_h >= 6 + 4 * eps  # must cover the example's actual schedule
        assert w_h == pytest.approx(17.55)
        assert res.schedulable

    def test_server_utilization_eq8(self):
        eps = 0.05
        sys_ = _example_system(eps)
        # each task: G^m = 2, eta = 1, T = 100
        expected = sum((2 + 2 * eps) / 100 for _ in range(3))
        assert server_utilization(sys_.tasks, eps) == pytest.approx(expected)


class TestAnalyzeOrdering:
    def test_uses_hp_response_for_jitter(self):
        eps = 0.0
        hp = Task("hp", C=2, T=10, D=10, priority=2, core=0)
        lo = Task("lo", C=3, T=30, D=30, priority=1, core=0)
        sys_ = System(tasks=[hp, lo], num_cores=1, epsilon=eps, server_core=0)
        res = sa.analyze(sys_)
        assert res.wcrt("hp") == pytest.approx(2.0)
        # lo: W = 3 + ceil((W + (2-2))/10)*2 -> W = 3+2 = 5 (one hp job)
        assert res.wcrt("lo") == pytest.approx(5.0)

    def test_unschedulable_flag(self):
        hp = Task("hp", C=6, T=10, D=10, priority=2, core=0)
        lo = Task("lo", C=6, T=12, D=12, priority=1, core=0)
        sys_ = System(tasks=[hp, lo], num_cores=1, epsilon=0.0, server_core=0)
        res = sa.analyze(sys_)
        assert not res.schedulable
        assert math.isinf(res.wcrt("lo"))
