"""Continuous-batching throughput: batched vs unbatched decode dispatch.

Drives two ServeEngines over the same reduced model on the CPU backend —
one with plain per-request dispatch (the paper's server, one device call
per decode step) and one with the BatchingServer (same-shape decode steps
from all concurrent streams coalesced into one masked device call) — and
reports decode tokens/s at 1/2/4/8 concurrent streams.

This is the GCAPS/RTGPU observation made concrete: the paper's server
bounds *access*, batching closes the *throughput* gap — per-request
dispatch pays the full device-call overhead (the runtime analogue of
Lemma 1's 2*eps) once per token, batching pays it once per batch.

Both engines run FIFO ordering so streams interleave fairly (priority
ordering would serialize the streams and hide the batching effect behind
starvation).  Writes BENCH_batching.json next to this file.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

STEPS = 24
PROMPT_LEN = 4


def _make_engine(cfg, params, *, batching: bool, max_batch: int):
    from repro.serving.engine import ServeEngine

    return ServeEngine(cfg, params, max_seq=64, ordering="fifo",
                       num_servers=1, batching=batching, max_batch=max_batch)


def _spec(name: str, prio: int):
    from repro.serving.engine import StreamSpec

    return StreamSpec(name=name, priority=prio, period_ms=30_000.0,
                      deadline_ms=30_000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=STEPS)


def _run(engine, num_streams: int) -> dict:
    prompt = np.arange(1, PROMPT_LEN + 1, dtype=np.int32)[None, :]
    names = [f"s{i}" for i in range(num_streams)]
    for i, n in enumerate(names):
        decision = engine.admit(_spec(n, num_streams - i))
        assert decision.admitted, (n, decision.reason)
    results: dict[str, object] = {}

    def worker(n):
        results[n] = engine.generate(n, prompt, steps=STEPS)

    threads = [threading.Thread(target=worker, args=(n,)) for n in names]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    for n in names:
        engine.remove(n)
    tokens = sum(len(results[n].tokens) for n in names)
    server = engine.pool.servers[0]
    sizes = server.stats.batch_sizes
    return {
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "mean_batch": (sum(sizes) / len(sizes)) if sizes else 1.0,
    }


def main() -> dict:
    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    report: dict = {"model": cfg.name, "steps": STEPS, "streams": {}}
    for num_streams in (1, 2, 4, 8):
        row: dict = {}
        for mode, batching in (("unbatched", False), ("batched", True)):
            engine = _make_engine(cfg, params, batching=batching,
                                  max_batch=max(num_streams, 1))
            try:
                # warm-up: trace/compile prefill + decode outside the clock
                _run(engine, 1)
                row[mode] = _run(engine, num_streams)
            finally:
                engine.close()
        row["speedup"] = (row["batched"]["tokens_per_s"]
                          / row["unbatched"]["tokens_per_s"])
        report["streams"][str(num_streams)] = row
        print(f"{num_streams} streams: unbatched "
              f"{row['unbatched']['tokens_per_s']:8.1f} tok/s | batched "
              f"{row['batched']['tokens_per_s']:8.1f} tok/s "
              f"(mean batch {row['batched']['mean_batch']:.2f}) | "
              f"speedup {row['speedup']:.2f}x")

    out = Path(__file__).parent / "BENCH_batching.json"
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
