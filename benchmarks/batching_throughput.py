"""Continuous-batching throughput: batched vs unbatched decode dispatch.

Drives two ServeEngines over the same reduced model on the CPU backend —
one with plain per-request dispatch (the paper's server, one device call
per decode step) and one with the BatchingServer (same-shape decode steps
from all concurrent streams coalesced into one masked device call) — and
reports decode tokens/s at 1/2/4/8 concurrent streams.

This is the GCAPS/RTGPU observation made concrete: the paper's server
bounds *access*, batching closes the *throughput* gap — per-request
dispatch pays the full device-call overhead (the runtime analogue of
Lemma 1's 2*eps) once per token, batching pays it once per batch.

Both engines run FIFO ordering so streams interleave fairly (priority
ordering would serialize the streams and hide the batching effect behind
starvation).  Writes BENCH_batching.json next to this file.

``--paged-sweep`` additionally compares the PAGED block-pool decode layout
against the masked-dense slot cache across occupancy (live streams out of
``max_batch`` slots) and context length (short prompts vs prompts near
max_seq): the masked-dense path pays the full (max_batch, max_seq) buffer
every step; the paged path's device call shrinks with slot compaction and
the block-table gather width, so the gap is widest exactly where central
knowledge says the work is small.  Writes BENCH_paged_decode.json.
"""

from __future__ import annotations

import json
import sys
import threading
import time
from pathlib import Path

import numpy as np

STEPS = 24
PROMPT_LEN = 4


def _make_engine(cfg, params, *, batching: bool, max_batch: int,
                 paged: bool = False, max_seq: int = 64):
    from repro.serving.engine import ServeEngine

    return ServeEngine(cfg, params, max_seq=max_seq, ordering="fifo",
                       num_servers=1, batching=batching, max_batch=max_batch,
                       paged=paged, kv_block_size=16)


def _spec(name: str, prio: int, steps: int = STEPS):
    from repro.serving.engine import StreamSpec

    return StreamSpec(name=name, priority=prio, period_ms=30_000.0,
                      deadline_ms=30_000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _run(engine, num_streams: int, *, steps: int = STEPS,
         prompt_len: int = PROMPT_LEN) -> dict:
    prompt = np.arange(1, prompt_len + 1, dtype=np.int32)[None, :] % 100
    names = [f"s{i}" for i in range(num_streams)]
    for i, n in enumerate(names):
        decision = engine.admit(_spec(n, num_streams - i, steps))
        assert decision.admitted, (n, decision.reason)
    results: dict[str, object] = {}

    def worker(n):
        results[n] = engine.generate(n, prompt, steps=steps)

    threads = [threading.Thread(target=worker, args=(n,)) for n in names]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    for n in names:
        engine.remove(n)
    tokens = sum(len(results[n].tokens) for n in names)
    # decode-phase throughput: all streams prefill first (one bucketed call
    # when batched), so wall minus the slowest prefill is decode-dominated
    prefill_s = max(results[n].prefill_latency_s for n in names)
    decode_wall = max(wall - prefill_s, 1e-9)
    server = engine.pool.servers[0]
    sizes = server.stats.batch_sizes
    return {
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "decode_tokens_per_s": tokens / decode_wall,
        "mean_batch": (sum(sizes) / len(sizes)) if sizes else 1.0,
    }


def _best_of(engine, num_streams: int, *, repeats: int = 3,
             key: str = "tokens_per_s", **kw) -> dict:
    """Best-of-N measurement: one scheduler hiccup or GC pause in a ~100ms
    run swings tokens/s by 2x, and 'fastest clean run' is the number that
    reflects the dispatch path being measured.  ``key`` picks the metric
    the comparison cares about (the paged sweep reports decode rates)."""
    runs = [_run(engine, num_streams, **kw) for _ in range(repeats)]
    return max(runs, key=lambda r: r[key])


def main() -> dict:
    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    report: dict = {"model": cfg.name, "steps": STEPS, "streams": {}}
    for num_streams in (1, 2, 4, 8):
        row: dict = {}
        for mode, batching in (("unbatched", False), ("batched", True)):
            engine = _make_engine(cfg, params, batching=batching,
                                  max_batch=max(num_streams, 1))
            try:
                # compile every decode/prefill shape bucket, then one
                # warm-up run — prefill coalescing widths are timing-
                # dependent, so only precompile makes them deterministic
                if batching:
                    engine.precompile(prompt_buckets=(PROMPT_LEN,))
                _run(engine, num_streams)
                row[mode] = _best_of(engine, num_streams)
            finally:
                engine.close()
        row["speedup"] = (row["batched"]["tokens_per_s"]
                          / row["unbatched"]["tokens_per_s"])
        report["streams"][str(num_streams)] = row
        print(f"{num_streams} streams: unbatched "
              f"{row['unbatched']['tokens_per_s']:8.1f} tok/s | batched "
              f"{row['batched']['tokens_per_s']:8.1f} tok/s "
              f"(mean batch {row['batched']['mean_batch']:.2f}) | "
              f"speedup {row['speedup']:.2f}x")

    out = Path(__file__).parent / "BENCH_batching.json"
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    return report


def paged_sweep(*, smoke: bool = False) -> dict:
    """Paged block-pool vs masked-dense decode across occupancy and context
    length.  ``smoke`` shrinks the grid/steps for a CI-sized run."""
    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    max_batch = 8
    max_seq = 1024  # the masked-dense path pays this buffer every step
    steps = 32
    occupancies = (1, 2) if smoke else (1, 2, 4, 8)
    contexts = {"short": 4}
    if not smoke:
        contexts["long"] = max_seq - steps - 8  # prompts near max_seq
    report: dict = {"model": cfg.name, "max_batch": max_batch,
                    "max_seq": max_seq, "steps": steps, "cells": []}

    for ctx_name, prompt_len in contexts.items():
        for occ in occupancies:
            cell: dict = {"context": ctx_name, "prompt_len": prompt_len,
                          "occupancy": f"{occ}/{max_batch}"}
            for mode, paged in (("masked_dense", False), ("paged", True)):
                engine = _make_engine(cfg, params, batching=True,
                                      max_batch=max_batch, paged=paged,
                                      max_seq=max_seq)
                try:
                    # compile every decode/prefill shape bucket, then one
                    # warm-up run — nothing compiles inside the clock
                    bucket = 1 << (prompt_len - 1).bit_length()
                    engine.precompile(
                        prompt_buckets=(min(bucket, max_seq),))
                    _run(engine, occ, steps=steps, prompt_len=prompt_len)
                    cell[mode] = _best_of(engine, occ, steps=steps,
                                          prompt_len=prompt_len,
                                          key="decode_tokens_per_s")
                finally:
                    engine.close()
            cell["speedup"] = (cell["paged"]["decode_tokens_per_s"]
                               / cell["masked_dense"]["decode_tokens_per_s"])
            report["cells"].append(cell)
            print(f"{ctx_name:>5} ctx, {occ}/{max_batch} live: masked "
                  f"{cell['masked_dense']['decode_tokens_per_s']:8.1f} tok/s"
                  f" | paged {cell['paged']['decode_tokens_per_s']:8.1f} "
                  f"tok/s | speedup {cell['speedup']:.2f}x")

    # the smoke grid must not clobber the committed full-grid artifact
    name = "BENCH_paged_decode_smoke.json" if smoke else "BENCH_paged_decode.json"
    out = Path(__file__).parent / name
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    return report


FAMILY_ARCHS = (
    ("internlm2_1_8b", "gqa"),
    ("deepseek_v2_lite_16b", "mla"),
    ("mamba2_780m", "ssm"),
    ("zamba2_7b", "hybrid"),
    ("whisper_medium", "encdec"),
)


def family_sweep(*, smoke: bool = False) -> dict:
    """One paged-vs-masked-dense cell per CACHE FAMILY (the same serving
    engine, five pool layouts: GQA KV blocks, MLA latent blocks, SSM state
    slabs, hybrid block+slab, enc-dec shared cross segments), plus the
    MLA latent pool's block-size sensitivity — the latent rows are narrow
    (r + rope, not n_kv*hd), so the gather-width/bucket-waste tradeoff
    sits at a different block size than plain GQA."""
    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    max_batch = 4
    max_seq = 64
    steps = 12 if smoke else 24
    occ = 2
    repeats = 2 if smoke else 3
    report: dict = {"max_batch": max_batch, "max_seq": max_seq,
                    "steps": steps, "occupancy": occ, "families": {},
                    "mla_block_size": []}

    for arch, family in FAMILY_ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        cell: dict = {"arch": arch}
        for mode, paged in (("masked_dense", False), ("paged", True)):
            engine = _make_engine(cfg, params, batching=True,
                                  max_batch=max_batch, paged=paged,
                                  max_seq=max_seq)
            try:
                engine.precompile(prompt_buckets=(PROMPT_LEN,))
                _run(engine, occ, steps=steps)
                cell[mode] = _best_of(engine, occ, steps=steps,
                                      repeats=repeats,
                                      key="decode_tokens_per_s")
            finally:
                engine.close()
        cell["speedup"] = (cell["paged"]["decode_tokens_per_s"]
                           / cell["masked_dense"]["decode_tokens_per_s"])
        report["families"][family] = cell
        print(f"{family:>7}: masked "
              f"{cell['masked_dense']['decode_tokens_per_s']:8.1f} tok/s | "
              f"paged {cell['paged']['decode_tokens_per_s']:8.1f} tok/s | "
              f"speedup {cell['speedup']:.2f}x")

    cfg = get_config("deepseek_v2_lite_16b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    for bs in ((8, 32) if smoke else (8, 16, 32)):
        from repro.serving.engine import ServeEngine

        engine = ServeEngine(cfg, params, max_seq=max_seq, ordering="fifo",
                             num_servers=1, batching=True,
                             max_batch=max_batch, paged=True,
                             kv_block_size=bs)
        try:
            engine.precompile(prompt_buckets=(PROMPT_LEN,))
            _run(engine, occ, steps=steps)
            r = _best_of(engine, occ, steps=steps, repeats=repeats,
                         key="decode_tokens_per_s")
        finally:
            engine.close()
        report["mla_block_size"].append(
            {"block_size": bs,
             "decode_tokens_per_s": r["decode_tokens_per_s"]})
        print(f"mla bs={bs:3d}: {r['decode_tokens_per_s']:8.1f} tok/s")

    out = Path(__file__).parent / "BENCH_paged_families.json"
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    if "--paged-sweep" in sys.argv:
        paged_sweep(smoke="--smoke" in sys.argv)
        family_sweep(smoke="--smoke" in sys.argv)
    else:
        main()
