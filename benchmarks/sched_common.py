"""Shared engine for the schedulability experiments (paper §6.3).

Each figure sweeps one generator parameter and reports the percentage of
schedulable tasksets under: the server-based approach (this paper), MPCP and
FMLP+ (synchronization-based baselines).  The paper uses 10,000 tasksets per
point; the default here is smaller for wall-clock reasons (set
REPRO_BENCH_TASKSETS or --full to raise it).
"""

from __future__ import annotations

import dataclasses
import os
import random
from dataclasses import dataclass

from repro.core import fmlp_analysis, mpcp_analysis, server_analysis
from repro.core.allocation import allocate
from repro.core.taskset_gen import GenParams, generate_taskset

APPROACHES = ("server", "mpcp", "fmlp")


def scenario_rows(name: str, seeds: list[int]) -> list[str]:
    """Run one named scenario from the ``repro.scenarios`` registry across
    ``seeds`` (the `--scenario` CLI path).  Unknown names raise
    ``RegistryError`` listing the available presets."""
    from repro.scenarios import SCENARIOS, default_cost_model, run

    cost_model = default_cost_model()
    rows = [f"scenario,{name}", "seed,num_tasks,schedulable,any_miss,"
            "max_wcrt_ms,min_bound_slack_ms"]
    for seed in seeds:
        s = run(SCENARIOS.create(name, seed=seed),
                cost_model=cost_model).summary()
        rows.append(f"{seed},{s['num_tasks']},{s['schedulable']},"
                    f"{s['any_miss']},{s['max_wcrt_ms']},"
                    f"{s['min_bound_slack_ms']}")
    return rows


def num_tasksets(full: bool) -> int:
    env = os.environ.get("REPRO_BENCH_TASKSETS")
    if env:
        return int(env)
    return 10_000 if full else 300


@dataclass
class Point:
    x: float | str
    num_cores: int
    sched_pct: dict[str, float]  # approach -> % schedulable


def sched_pct(params: GenParams, n_sets: int, seed: int = 0) -> dict[str, float]:
    rng = random.Random(seed)
    wins = {a: 0 for a in APPROACHES}
    for _ in range(n_sets):
        tasks = generate_taskset(params, rng)
        sync_sys = allocate(tasks, params.num_cores, approach="sync")
        if mpcp_analysis.analyze(sync_sys).schedulable:
            wins["mpcp"] += 1
        if fmlp_analysis.analyze(sync_sys).schedulable:
            wins["fmlp"] += 1
        server_sys = allocate(
            tasks, params.num_cores, approach="server", epsilon=params.epsilon_ms
        )
        if server_analysis.analyze(server_sys).schedulable:
            wins["server"] += 1
    return {a: 100.0 * wins[a] / n_sets for a in APPROACHES}


def sweep(
    name: str,
    base: GenParams,
    xs: list,
    mutate,
    *,
    full: bool,
    cores=(4, 8),
) -> list[str]:
    """Run one figure's sweep.  ``mutate(params, x) -> GenParams`` applies the
    swept value.  Returns CSV rows: fig,N_P,x,server,mpcp,fmlp."""
    n_sets = num_tasksets(full)
    rows = [f"# {name}: % schedulable tasksets, {n_sets} tasksets/point"]
    rows.append(f"{name},N_P,x,server,mpcp,fmlp")
    for np_ in cores:
        for x in xs:
            params = mutate(dataclasses.replace(base, num_cores=np_), x)
            pct = sched_pct(params, n_sets, seed=hash((name, np_, repr(x))) & 0xFFFF)
            rows.append(
                f"{name},{np_},{x},{pct['server']:.1f},{pct['mpcp']:.1f},{pct['fmlp']:.1f}"
            )
    return rows
