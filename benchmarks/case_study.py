"""Case study (paper §6.2, Table 1, Figure 7): the self-driving-car taskset
on a 2-core platform, one hyperperiod (3000 ms) simulated under both
approaches.

Paper's headline observation: cpu_matmul1's worst response time is 520.68 ms
under the synchronization-based approach vs 219.09 ms under the server-based
approach, because workzone busy-waits through its 142 ms of GPU time on
core 0 under sync.
"""

from __future__ import annotations

from repro.core import simulator
from repro.core.task_model import GpuSegment, System, Task

MISC_RATIO = 0.10  # G^m share of each GPU segment (Table-2 lower bound)
EPS = 0.045  # measured 44.97us total server delay (paper §6.2) -> ~0.045ms


def _seg(total: float) -> GpuSegment:
    return GpuSegment(e=total * (1 - MISC_RATIO), m=total * MISC_RATIO)


def table1_tasks() -> list[Task]:
    return [
        Task("workzone", C=20, T=300, D=300, priority=70, core=0,
             segments=(_seg(95.0), _seg(47.0))),
        Task("cpu_matmul1", C=215, T=750, D=750, priority=67, core=0),
        Task("cpu_matmul2", C=102, T=300, D=300, priority=69, core=1),
        Task("gpu_matmul1", C=0.15, T=600, D=600, priority=68, core=1,
             segments=(_seg(19.0),)),
        Task("gpu_matmul2", C=0.15, T=1000, D=1000, priority=66, core=1,
             segments=(_seg(38.0),)),
    ]


def run(full: bool = False) -> list[str]:
    tasks = table1_tasks()
    hyper = 3000.0
    rows = ["# case_study: worst observed response time (ms) over one hyperperiod"]
    rows.append("case_study,task,sync_mpcp_ms,server_ms")

    sync_sys = System(tasks=tasks, num_cores=2, epsilon=0.0)
    sync = simulator.simulate(sync_sys, mode="mpcp", horizon_ms=hyper)

    server_sys = System(tasks=tasks, num_cores=2, epsilon=EPS, server_core=1)
    server = simulator.simulate(server_sys, mode="server", horizon_ms=hyper)

    for t in tasks:
        rows.append(
            f"case_study,{t.name},{sync.wcrt(t.name):.2f},{server.wcrt(t.name):.2f}"
        )

    # the paper's headline: cpu_matmul1 ~520 ms (sync) vs ~219 ms (server)
    ratio = sync.wcrt("cpu_matmul1") / max(server.wcrt("cpu_matmul1"), 1e-9)
    rows.append(f"case_study,cpu_matmul1_sync_over_server,{ratio:.2f},")
    return rows
