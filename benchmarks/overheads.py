"""Server overhead measurement (paper §6.2, Figures 5/6) on *this* platform.

The paper measures, over 100k samples on the i.MX6: MPCP lock acquire/release
overhead (total 14.0us at p99.9) and the server path: wake-up, execution
delay (priority-queue ops), completion notification (total 44.97us at
p99.9).  We measure the equivalent operations for our runtime:

  * lock path  : threading.Lock acquire+release handoff between two threads
  * server path: AcceleratorServer submit -> dequeue (wake-up), fn-done ->
                 client wakeable (notify)

The p99.9 of the server path is the measured eps for the analysis; the
schedulability experiments use eps = 50us, which should comfortably bound it.
"""

from __future__ import annotations

import threading
import time

from repro.core.server_runtime import AcceleratorServer


def _pct(values: list[float], q: float) -> float:
    vs = sorted(values)
    idx = min(int(q * len(vs)), len(vs) - 1)
    return vs[idx]


def _measure_lock(n: int) -> list[float]:
    """Lock handoff latency between a holder thread and a waiter."""
    lock = threading.Lock()
    lat: list[float] = []
    start_t = [0.0]
    go = threading.Event()
    done = threading.Event()

    def holder():
        for _ in range(n):
            go.wait()
            go.clear()
            with lock:
                start_t[0] = time.perf_counter_ns()
                time.sleep(0)  # release the GIL so the waiter can block
            done.wait()
            done.clear()

    th = threading.Thread(target=holder, daemon=True)
    th.start()
    for _ in range(n):
        go.set()
        while start_t[0] == 0.0:
            pass
        with lock:
            lat.append((time.perf_counter_ns() - start_t[0]) / 1e3)
        start_t[0] = 0.0
        done.set()
    th.join(timeout=5)
    return lat


def run(full: bool = False) -> list[str]:
    n = 100_000 if full else 5_000
    rows = [f"# overheads: us, {n} samples (paper §6.2 analogue)"]
    rows.append("overheads,metric,mean_us,p999_us")

    with AcceleratorServer() as srv:
        for _ in range(n):
            srv.call(lambda: None)
        wake = [v * 1e6 for v in srv.stats.wakeup_latencies]
        notify = [v * 1e6 for v in srv.stats.notify_latencies]

    lock = _measure_lock(min(n, 2_000))

    def emit(name: str, vals: list[float]) -> None:
        rows.append(
            f"overheads,{name},{sum(vals)/len(vals):.2f},{_pct(vals, 0.999):.2f}"
        )

    emit("server_wakeup", wake)
    emit("server_notify", notify)
    emit("server_total_eps", [a + b for a, b in zip(wake, notify)])
    emit("lock_handoff", lock)
    return rows
