"""Roofline table: reads the dry-run artifacts produced by
``repro.launch.dryrun`` and prints the three roofline terms per
(architecture x shape) on the single-pod mesh.

Run ``PYTHONPATH=src python -m repro.launch.dryrun --all`` first; artifacts
land in ``artifacts/dryrun/*.json``.
"""

from __future__ import annotations

import json
import pathlib

ARTIFACTS = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run(full: bool = False) -> list[str]:
    rows = ["# roofline_table: terms in ms per step (single-pod 16x16 mesh)"]
    rows.append(
        "roofline,arch,shape,compute_ms,memory_ms,collective_ms,bottleneck,"
        "model_flops_ratio,roofline_fraction"
    )
    files = sorted(ARTIFACTS.glob("*.json")) if ARTIFACTS.exists() else []
    if not files:
        rows.append("roofline,SKIP,no dry-run artifacts found; run repro.launch.dryrun,,,,,,")
        return rows
    for f in files:
        d = json.loads(f.read_text())
        if d.get("mesh") != "single_pod":
            continue
        r = d.get("roofline", {})
        if not r:
            continue
        variant = d.get("variant", "baseline")
        shape = d["shape"] if variant == "baseline" else f"{d['shape']}[{variant}]"
        rows.append(
            "roofline,{arch},{shape},{c:.3f},{m:.3f},{k:.3f},{b},{mr:.3f},{rf:.3f}".format(
                arch=d["arch"], shape=shape,
                c=r["compute_ms"], m=r["memory_ms"], k=r["collective_ms"],
                b=r["bottleneck"], mr=r.get("model_flops_ratio", 0.0),
                rf=r.get("roofline_fraction", 0.0),
            )
        )
    return rows
