"""Paper Figures 8-15: schedulability experiments (one function per figure).

Every figure reproduces the corresponding sweep in §6.3 using the Table-2
base parameters.  Expected qualitative outcomes (the paper's claims):

  fig8  : server > {mpcp, fmlp} as GPU segment length ratio grows
  fig9  : server >> baselines as % of GPU-using tasks grows (paper: up to
          +38% vs MPCP, +27% vs FMLP+ at 70%, N_P=4)
  fig10 : server advantage grows with task count (esp. N_P=8)
  fig11 : server advantage grows with #GPU segments per task
  fig12 : all approaches degrade as the share of large tasks grows
  fig13 : server degrades as eps grows; baselines flat
  fig14 : server degrades as misc ratio grows; crossover vs FMLP+ around
          ~60% (N_P=4) / ~90% (N_P=8)
  fig15 : FIFO (FMLP+) overtakes the priority-ordered server for large
          T_min (paper: ~80ms at N_P=4, ~160ms at N_P=8)
"""

from __future__ import annotations

import dataclasses

from repro.core.taskset_gen import GenParams

from .sched_common import sweep

BASE = GenParams()


def fig08_gpu_segment_ratio(full: bool) -> list[str]:
    def mutate(p: GenParams, x: float) -> GenParams:
        return dataclasses.replace(p, gpu_ratio=(x - 0.05, x + 0.05))

    return sweep("fig08_gpu_seg_ratio", BASE, [0.1, 0.2, 0.3, 0.4, 0.6, 0.8, 1.0],
                 mutate, full=full)


def fig09_pct_gpu_tasks(full: bool) -> list[str]:
    def mutate(p: GenParams, x: float) -> GenParams:
        return dataclasses.replace(p, pct_gpu_tasks=(x, x))

    return sweep("fig09_pct_gpu_tasks", BASE,
                 [0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0], mutate, full=full)


def fig10_num_tasks(full: bool) -> list[str]:
    def mutate(p: GenParams, x: int) -> GenParams:
        n = x * p.num_cores
        return dataclasses.replace(p, num_tasks=(n, n))

    # x = tasks per core
    return sweep("fig10_num_tasks", BASE, [2, 3, 4, 5, 6], mutate, full=full)


def fig11_num_gpu_segments(full: bool) -> list[str]:
    def mutate(p: GenParams, x: int) -> GenParams:
        return dataclasses.replace(p, num_segments=(x, x))

    return sweep("fig11_num_gpu_segments", BASE, [1, 2, 3, 4, 6, 8], mutate, full=full)


def fig12_bimodal(full: bool) -> list[str]:
    def mutate(p: GenParams, x: float) -> GenParams:
        return dataclasses.replace(p, bimodal_large_fraction=x)

    # x = fraction of "large" tasks (paper sweeps small:large ratio)
    return sweep("fig12_bimodal", BASE, [0.0, 0.1, 0.25, 0.5, 0.75, 1.0],
                 mutate, full=full)


def fig13_server_overhead(full: bool) -> list[str]:
    def mutate(p: GenParams, x: float) -> GenParams:
        return dataclasses.replace(p, epsilon_ms=x)

    # eps in ms: 50us (base) up to 5ms (far beyond practical)
    return sweep("fig13_server_overhead", BASE, [0.0, 0.05, 0.5, 1.0, 2.0, 5.0],
                 mutate, full=full)


def fig14_misc_ratio(full: bool) -> list[str]:
    def mutate(p: GenParams, x: float) -> GenParams:
        return dataclasses.replace(p, misc_ratio=(x, x))

    return sweep("fig14_misc_ratio", BASE,
                 [0.1, 0.2, 0.4, 0.6, 0.8, 0.9], mutate, full=full)


def fig15_min_period(full: bool) -> list[str]:
    def mutate(p: GenParams, x: float) -> GenParams:
        return dataclasses.replace(p, period_ms=(x, 500.0))

    return sweep("fig15_min_period", BASE, [20, 40, 80, 160, 320], mutate, full=full)


ALL_FIGURES = [
    fig08_gpu_segment_ratio,
    fig09_pct_gpu_tasks,
    fig10_num_tasks,
    fig11_num_gpu_segments,
    fig12_bimodal,
    fig13_server_overhead,
    fig14_misc_ratio,
    fig15_min_period,
]
