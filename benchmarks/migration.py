"""Live migration economics: steal win, move latency, elastic ramp.

Three measurements over the paged multi-server engine:

  * steal win — an adversarially imbalanced workload (every stream pinned
    onto server 0, arrivals in MMPP-style bursts) served with pinned
    routing vs with work stealing enabled; reports the tokens/s ratio.
    The rebalancer should recover most of the idle servers' capacity —
    the acceptance line is >= 1.3x on a 4-device pool.  (A server thread
    serializes its own Python-side dispatch with its XLA steps, so
    spreading a pinned burst wins wall-clock even single-core.)
  * migration latency vs blocks moved — wall time of the two-phase
    gather -> host hop -> scatter for growing sequence lengths, on the
    precompiled pow2-bucketed migrate cells (no mid-traffic traces).
  * elastic ramp — tokens/s of a fixed workload at each target of a
    ``LoadTrajectory`` as the ``ElasticPoolController`` scales the pool
    up and back down, with correctness guarded bit-exactly throughout.

Writes BENCH_migration.json next to this file.  ``--smoke`` shrinks the
sweep for CI.
"""

from __future__ import annotations

import json
import sys
import threading
import time
from pathlib import Path

import numpy as np

STEPS = 24
PROMPT_LEN = 4


def _spec(name: str, prio: int, steps: int = STEPS):
    from repro.serving.engine import StreamSpec

    return StreamSpec(name=name, priority=prio, period_ms=30_000.0,
                      deadline_ms=30_000.0, prefill_ms=50.0, decode_ms=5.0,
                      decode_steps=steps)


def _make_engine(cfg, params, *, num_servers: int, max_batch: int = 4,
                 kv_block_size: int = 16):
    from repro.serving.engine import ServeEngine

    eng = ServeEngine(cfg, params, max_seq=64, ordering="fifo",
                      num_servers=num_servers, batching=True,
                      max_batch=max_batch, paged=True,
                      kv_block_size=kv_block_size)
    eng.enable_fault_tolerance(heartbeat_timeout_s=30.0)
    return eng


def _burst_offsets(num_streams: int, seed: int = 20260808) -> list[float]:
    """MMPP-style start offsets (seconds): bursts of back-to-back arrivals
    separated by idle dwells — the imbalanced-arrival shape the stealer
    is priced against."""
    rng = np.random.default_rng(seed)
    offsets, t, bursty = [], 0.0, True
    for _ in range(num_streams):
        offsets.append(t)
        t += rng.uniform(0.001, 0.004) if bursty else rng.uniform(0.05, 0.12)
        if rng.random() < (0.3 if bursty else 0.5):
            bursty = not bursty
    return offsets


def _run(eng, names, prompt, *, steps: int = STEPS, offsets=None):
    results: dict[str, object] = {}

    def worker(n, delay):
        if delay:
            time.sleep(delay)
        try:
            results[n] = eng.generate(n, prompt, steps=steps)
        except Exception as e:  # noqa: BLE001 - recorded, asserted by caller
            results[n] = e

    offsets = offsets or [0.0] * len(names)
    threads = [threading.Thread(target=worker, args=(n, d))
               for n, d in zip(names, offsets)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results, time.perf_counter() - t0


def _throughput(results, wall: float) -> float:
    tokens = sum(len(r.tokens) for r in results.values()
                 if not isinstance(r, Exception))
    return tokens / wall if wall > 0 else 0.0


def _pin_all(eng, names, si: int = 0) -> None:
    """Adversarial placement: force every stream onto one server, in both
    the admission partition and the pool routing."""
    for n in names:
        if eng.admission.device_of(n) != si:
            eng.admission.migrate(n, si)
        eng.pool.reassign(n, si, priority=eng._streams[n].priority)


def bench_steal_win(cfg, params, *, num_servers: int, streams: int,
                    steps: int) -> dict:
    prompt = np.arange(1, PROMPT_LEN + 1, dtype=np.int32)[None, :] % 100
    names = [f"s{i}" for i in range(streams)]
    offsets = _burst_offsets(streams)

    runs = {}
    for mode in ("pinned", "stealing"):
        eng = _make_engine(cfg, params, num_servers=num_servers)
        try:
            for i, n in enumerate(names):
                assert eng.admit(_spec(n, streams - i, steps)).admitted
            _pin_all(eng, names, 0)
            # warmup pass: compile every cell both modes will touch, so the
            # timed run compares routing policy, not trace cache state
            warm, _ = _run(eng, names, prompt, steps=steps)
            assert not any(isinstance(r, Exception) for r in warm.values())
            _pin_all(eng, names, 0)
            if mode == "stealing":
                eng.enable_work_stealing(interval_s=0.01)
            results, wall = _run(eng, names, prompt, steps=steps,
                                 offsets=offsets)
            bad = [n for n in names if isinstance(results[n], Exception)]
            assert not bad, f"{mode}: streams failed: {bad}"
            runs[mode] = {
                "tokens_per_s": _throughput(results, wall),
                "wall_s": wall,
                "migrations": eng.migrations_completed,
                "tokens": {n: results[n].tokens for n in names},
            }
            assert eng.kv_blocks_in_use() == 0
        finally:
            eng.close()

    mism = [n for n in names
            if runs["pinned"]["tokens"][n] != runs["stealing"]["tokens"][n]]
    assert not mism, f"stealing changed tokens: {mism}"
    assert runs["stealing"]["migrations"] >= 1, "no steal fired"
    win = runs["stealing"]["tokens_per_s"] / runs["pinned"]["tokens_per_s"]
    return {
        "num_servers": num_servers,
        "num_streams": streams,
        "steps": steps,
        "pinned_tokens_per_s": round(runs["pinned"]["tokens_per_s"], 2),
        "stealing_tokens_per_s": round(runs["stealing"]["tokens_per_s"], 2),
        "steals_completed": runs["stealing"]["migrations"],
        "steal_win": round(win, 4),
    }


def bench_migration_latency(cfg, params, *, lengths, reps: int) -> dict:
    from repro.models import model as M

    eng = _make_engine(cfg, params, num_servers=2, kv_block_size=8)
    rows = []
    try:
        for tokens in lengths:
            assert eng.admit(_spec("mv0", 1, 4)).admitted
            samples = []
            blocks = None
            for rep in range(reps + 1):  # rep 0 is an untimed warmup
                seq_id, _, _, _ = eng._paged_reserve(0, "mv0", tokens, 0, 8)
                src = eng._paged[0]
                if src.pools is None:
                    src.pools = M.init_paged_cache(cfg, src.mgr.num_blocks,
                                                   src.mgr.block_size)
                blocks = len(src.mgr.seqs[seq_id].blocks)
                t0 = time.perf_counter()
                eng._execute_migration("mv0", seq_id, 0, 1, 0)
                if rep:
                    samples.append(1e3 * (time.perf_counter() - t0))
                eng._paged_release(1, seq_id)
            eng.remove("mv0")
            assert eng.kv_blocks_in_use() == 0
            rows.append({
                "tokens": tokens,
                "blocks_moved": blocks,
                "latency_ms": {
                    "min": round(min(samples), 3),
                    "mean": round(float(np.mean(samples)), 3),
                    "max": round(max(samples), 3),
                },
            })
    finally:
        eng.close()
    return {"kv_block_size": 8, "reps": reps, "points": rows}


def bench_elastic_ramp(cfg, params, *, steps: int) -> dict:
    from repro.runtime.elastic import ElasticPoolController, LoadTrajectory

    prompt = np.arange(1, PROMPT_LEN + 1, dtype=np.int32)[None, :] % 100
    names = [f"s{i}" for i in range(4)]
    traj = LoadTrajectory(((0.0, 1), (1.0, 3), (2.0, 1)))

    eng = _make_engine(cfg, params, num_servers=1)
    phases = []
    want = None
    try:
        for i, n in enumerate(names):
            assert eng.admit(_spec(n, len(names) - i, steps)).admitted
        ctl = ElasticPoolController(eng, min_servers=1, max_servers=4)
        warm, _ = _run(eng, names, prompt, steps=steps)  # compile warmup
        assert not any(isinstance(r, Exception) for r in warm.values())
        for t in (0.0, 1.0, 2.0):
            ctl.scale_to(traj.target_at(t))
            results, wall = _run(eng, names, prompt, steps=steps)
            bad = [n for n in names if isinstance(results[n], Exception)]
            assert not bad, f"ramp t={t}: streams failed: {bad}"
            got = {n: results[n].tokens for n in names}
            if want is None:
                want = got
            else:
                assert got == want, f"ramp t={t}: tokens diverged"
            phases.append({
                "t_s": t,
                "target_servers": traj.target_at(t),
                "live_servers": len(ctl.live()),
                "tokens_per_s": round(_throughput(results, wall), 2),
            })
        assert eng.kv_blocks_in_use() == 0
    finally:
        eng.close()
    return {"num_streams": len(names), "steps": steps,
            "trajectory": [list(p) for p in traj.points], "phases": phases}


def main() -> None:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    smoke = "--smoke" in sys.argv

    import jax

    from repro.configs.registry import get_config
    from repro.models import model as M

    cfg = get_config("internlm2_1_8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    steps = 12 if smoke else STEPS
    streams = 4 if smoke else 6
    # tokens per point; capped by max_seq=64 (kv_block_size=8 -> <=8 blocks)
    lengths = (8, 32) if smoke else (8, 16, 32, 64)
    reps = 3 if smoke else 10

    out = {
        "config": "internlm2_1_8b.reduced",
        "mode": "smoke" if smoke else "full",
        "steal": bench_steal_win(cfg, params, num_servers=4,
                                 streams=streams, steps=steps),
        "latency": bench_migration_latency(cfg, params, lengths=lengths,
                                           reps=reps),
        "elastic": bench_elastic_ramp(cfg, params, steps=steps),
    }
    path = Path(__file__).resolve().parent / "BENCH_migration.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if out["steal"]["steal_win"] < 1.3:
        print(f"WARNING: steal win {out['steal']['steal_win']} < 1.3x",
              file=sys.stderr)


if __name__ == "__main__":
    main()
