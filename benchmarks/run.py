"""Benchmark harness: one entry per paper table/figure, plus the roofline
table from the multi-pod dry-run artifacts.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME[,NAME...]]

Output: CSV rows on stdout (also mirrored into bench_output.txt by the
top-level run command).  --full uses the paper's 10,000 tasksets per point.
"""

from __future__ import annotations

import argparse
import sys
import time


def _registry():
    from . import case_study, fig16_fifo_server, overheads, roofline_table
    from .figures import ALL_FIGURES

    entries: dict[str, object] = {f.__name__: f for f in ALL_FIGURES}
    entries["fig16_fifo_server"] = fig16_fifo_server.run
    entries["case_study"] = case_study.run
    entries["overheads"] = overheads.run
    entries["roofline_table"] = roofline_table.run
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 10,000 tasksets per point")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    entries = _registry()
    names = [n for n in args.only.split(",") if n] or list(entries)
    unknown = [n for n in names if n not in entries]
    if unknown:
        sys.exit(f"unknown benchmarks: {unknown}; available: {list(entries)}")

    for name in names:
        t0 = time.perf_counter()
        try:
            rows = entries[name](args.full)
        except Exception as e:  # noqa: BLE001 - keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        for row in rows:
            print(row)
        dt = time.perf_counter() - t0
        print(f"# {name} took {dt:.1f}s")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
