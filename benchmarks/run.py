"""Benchmark harness: one entry per paper table/figure, plus the roofline
table from the multi-pod dry-run artifacts.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME[,NAME...]]
    PYTHONPATH=src python -m benchmarks.run --scenario NAME [--seeds 0,1,2]

Output: CSV rows on stdout (also mirrored into bench_output.txt by the
top-level run command).  --full uses the paper's 10,000 tasksets per point.
--scenario resolves NAME through the ``repro.scenarios`` registry (any CI
matrix preset, e.g. flash_crowd) and prints bound-vs-WCRT per seed.
"""

from __future__ import annotations

import argparse
import sys
import time


def _registry():
    from . import (case_study, fig16_fifo_server, overheads, roofline_table,
                   scenario_matrix)
    from .figures import ALL_FIGURES

    entries: dict[str, object] = {f.__name__: f for f in ALL_FIGURES}
    entries["fig16_fifo_server"] = fig16_fifo_server.run
    entries["case_study"] = case_study.run
    entries["overheads"] = overheads.run
    entries["roofline_table"] = roofline_table.run
    entries["scenario_matrix"] = scenario_matrix.run
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 10,000 tasksets per point")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated benchmark names")
    ap.add_argument("--scenario", type=str, default="",
                    help="run one named scenario from the repro.scenarios "
                         "registry instead of the benchmark sweep")
    ap.add_argument("--seeds", type=str, default="0,1,2",
                    help="comma-separated seeds for --scenario")
    args = ap.parse_args()

    if args.scenario:
        from .sched_common import scenario_rows

        seeds = [int(s) for s in args.seeds.split(",") if s]
        for row in scenario_rows(args.scenario, seeds):
            print(row)
        return

    entries = _registry()
    names = [n for n in args.only.split(",") if n] or list(entries)
    unknown = [n for n in names if n not in entries]
    if unknown:
        sys.exit(f"unknown benchmarks: {unknown}; available: {list(entries)}")

    for name in names:
        t0 = time.perf_counter()
        try:
            rows = entries[name](args.full)
        except Exception as e:  # noqa: BLE001 - keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        for row in rows:
            print(row)
        dt = time.perf_counter() - t0
        print(f"# {name} took {dt:.1f}s")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
