"""Price the CI scenario matrix into BENCH_scenarios.json.

Runs every ``repro.scenarios.CI_MATRIX`` preset (diurnal load, flash
crowd, adversarial long-context mix, multi-tenant priority-inversion
attempt, replayed fault, measured costs, the alternative queue orderings,
the sync baselines, and the LP-allocated pool) across a handful of seeds,
pairing every task's analysis bound with its simulated WCRT.  Two claims
are checked while reporting:

  * bound dominance — in every cell the per-server analysis bound must sit
    at or above the simulated WCRT (within the simulator's 1e-3 ms
    nanosecond-quantization tolerance); a violation fails the benchmark,
    mirroring `make test-scenarios`;
  * allocation quality — the LP-relaxation baseline
    (``scenarios.lp_alloc``) vs the greedy WFD packer on the same pool
    tasksets, both compared against the LP's fractional optimum ``z*`` (a
    true lower bound on any packing), so the JSON carries real optimality
    gaps rather than a heuristic-vs-heuristic shrug.

Writes BENCH_scenarios.json next to this file.  ``--smoke`` shrinks the
seed sweep for CI (`make bench-smoke`); ``--full`` widens it.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

# the simulator clock is integer nanoseconds; analyses are float ms
NS_TOL_MS = 1e-3


def run_matrix(seeds: list[int]) -> tuple[list[dict], int]:
    from repro.scenarios import CI_MATRIX, SCENARIOS, default_cost_model, run

    cost_model = default_cost_model()
    cells: list[dict] = []
    violations = 0
    for name in CI_MATRIX:
        for seed in seeds:
            t0 = time.perf_counter()
            res = run(SCENARIOS.create(name, seed=seed),
                      cost_model=cost_model)
            cell = res.summary()
            cell["elapsed_s"] = round(time.perf_counter() - t0, 3)
            slack = cell["min_bound_slack_ms"]
            cell["bound_dominates"] = slack is None or slack >= -NS_TOL_MS
            if not cell["bound_dominates"]:
                violations += 1
            cells.append(cell)
    return cells, violations


def compare_allocators(seeds: list[int], *, num_devices: int = 3,
                       cores_per_device: int = 2) -> dict:
    from repro.core.allocation import allocate_pool
    from repro.core.taskset_gen import GenParams, generate_taskset
    from repro.scenarios import rng_stream
    from repro.scenarios.lp_alloc import HAVE_SCIPY, allocate_lp, lp_pack

    params = GenParams(num_cores=cores_per_device,
                       num_tasks=(3 * num_devices, 5 * num_devices),
                       pct_gpu_tasks=(0.3, 0.6), epsilon_ms=0.05)
    rows = []
    for seed in seeds:
        tasks = generate_taskset(params, rng_stream(seed, "alloc_compare"))
        gpu_items = [(t.name, t.G / t.T) for t in tasks if t.uses_gpu]
        pack = lp_pack(gpu_items, num_devices)

        def max_device_load(system) -> float:
            load = [0.0] * num_devices
            for t in system.tasks:
                if t.uses_gpu:
                    load[t.device] += t.G / t.T
            return max(load)

        wfd_sys = allocate_pool(tasks, num_devices, cores_per_device,
                                epsilon=params.epsilon_ms)
        lp_sys = allocate_lp(tasks, num_devices, cores_per_device,
                             epsilon=params.epsilon_ms)
        wfd_load, lp_load = max_device_load(wfd_sys), max_device_load(lp_sys)
        rows.append({
            "seed": seed,
            "num_gpu_tasks": len(gpu_items),
            "lp_bound": round(pack.lp_bound, 6),
            "wfd_max_load": round(wfd_load, 6),
            "lp_max_load": round(lp_load, 6),
            "wfd_gap": round(wfd_load - pack.lp_bound, 6),
            "lp_gap": round(lp_load - pack.lp_bound, 6),
        })
    n = len(rows)
    return {
        "num_devices": num_devices,
        "cores_per_device": cores_per_device,
        "used_lp": HAVE_SCIPY,
        "mean_wfd_gap": round(sum(r["wfd_gap"] for r in rows) / n, 6),
        "mean_lp_gap": round(sum(r["lp_gap"] for r in rows) / n, 6),
        "lp_no_worse_pct": round(
            100.0 * sum(r["lp_max_load"] <= r["wfd_max_load"] + 1e-9
                        for r in rows) / n, 1),
        "tasksets": rows,
    }


def run(full: bool = False) -> list[str]:
    """benchmarks.run registry adapter: CSV rows, JSON written as a side
    effect (the BENCH_*.json convention)."""
    out = build(full)
    rows = ["scenario,seed,num_tasks,schedulable,any_miss,"
            "min_bound_slack_ms,bound_dominates"]
    for c in out["cells"]:
        rows.append(
            f"{c['scenario']},{c['config']['seed']},{c['num_tasks']},"
            f"{c['schedulable']},{c['any_miss']},{c['min_bound_slack_ms']},"
            f"{c['bound_dominates']}")
    a = out["allocation"]
    rows.append(f"# allocation: mean gap to LP lower bound — "
                f"wfd {a['mean_wfd_gap']}, lp {a['mean_lp_gap']} "
                f"(lp no worse on {a['lp_no_worse_pct']}% of tasksets)")
    return rows


def build(full: bool) -> dict:
    seeds = list(range(10)) if full else [0, 1, 2]
    cells, violations = run_matrix(seeds)
    out = {
        "mode": "full" if full else "smoke",
        "seeds": seeds,
        "ns_tolerance_ms": NS_TOL_MS,
        "num_cells": len(cells),
        "bound_violations": violations,
        "allocation": compare_allocators(seeds),
        "cells": cells,
    }
    path = Path(__file__).resolve().parent / "BENCH_scenarios.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path} ({len(cells)} cells, {violations} violations)")
    return out


def main() -> None:
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    out = build("--full" in sys.argv)
    for cell in out["cells"]:
        print(f"{cell['scenario']:28s} seed={cell['config']['seed']} "
              f"sched={cell['schedulable']} miss={cell['any_miss']} "
              f"slack={cell['min_bound_slack_ms']}")
    if out["bound_violations"]:
        sys.exit(f"{out['bound_violations']} cells violate bound >= sim WCRT")


if __name__ == "__main__":
    main()
